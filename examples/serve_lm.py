"""Serve a small model with batched requests: prefill once, decode with a
sequence-sharded KV cache (the decode_32k code path, scaled down to CPU).

    PYTHONPATH=src python examples/serve_lm.py --tokens 24
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.common import SMOKE_TOPO
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced()
    engine = ServeEngine(cfg, SMOKE_TOPO,
                         max_len=args.prompt_len + args.tokens + 4)
    params = engine.init_params(jax.random.key(0))

    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32) * 0.02

    t0 = time.perf_counter()
    out = engine.generate(params, batch, args.tokens, greedy=False,
                          key=jax.random.key(1))
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} generated={args.tokens}")
    print("sampled ids (first request):", out[0].tolist())
    print(f"prefill tokens: {engine.stats.prefill_tokens}  "
          f"decode steps: {engine.stats.decode_steps}  "
          f"wall: {dt:.2f}s ({args.batch*args.tokens/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
