"""Fleet what-if: admit a job mix onto a heterogeneous, variability-aware
pod under a shared power budget (the paper's POLCA-style oversubscription
use case, §4.3 — now cluster-wide), all through the declarative
``MinosSession`` facade.

    PYTHONPATH=src:. python examples/fleet_power_planner.py

``MinosSession.from_config`` builds the whole session from one dict — the
persisted reference store (warm classifier), a seeded ``DeviceInventory``
(two chip generations, per-device silicon variability), and the policy
names.  Every job's single uncapped profiling run is then one ``submit``;
``session.run()`` multiplexes the telemetry, caps each job early on its own
device, and re-packs the pod (heterogeneity-aware first-fit-decreasing) the
moment any cap lands.  The single shipped reference library — built on the
nominal v5e — serves every device through effective-TDP normalization.
"""
from benchmarks.common import STORE, reference_library
from repro.api import MinosSession, holdout_streams, reference_streams


def main() -> None:
    lib = reference_library()      # ensures the on-disk store exists
    session = MinosSession.from_config({
        "library": STORE,
        "devices": {"tpu-v5e": 4, "tpu-v5p": 2},
        "variability": {},         # published default sigmas
        "seed": 3,
        "objective": "powercentric",
        "actuator": "sim",
        "quantile": "p99",
        "gates": {"min_confidence": 0.2},
    })
    inventory = session.inventory
    print(f"fleet: {len(inventory)} devices "
          f"({', '.join(inventory.models)}; built_on={lib.built_on!r})")
    for d in inventory:
        print(f"  {d.device_id:14s} perf x{d.spec.perf_scale:.3f} "
              f"power x{d.spec.power_scale:.3f} "
              f"eff-TDP {d.effective_tdp_w:5.1f} W")

    # a queue of jobs, round-robined onto devices
    queue = [
        ("command-r-35b:train_4k", 256),
        ("deepseek-v2-236b:decode_32k", 256),
        ("vector-search", 64),
        ("granite-moe-3b-a800m:decode_32k", 64),
        ("lsms-like", 32),
    ]
    nameplate = sum(chips * inventory[i % len(inventory)].nameplate_w
                    for i, (_, chips) in enumerate(queue))
    budget = 0.75 * nameplate      # an oversubscribed pod
    session.set_budget(budget)
    print(f"\npod: {sum(c for _, c in queue)} chips, nameplate "
          f"{nameplate / 1e3:.0f} kW, budget {budget / 1e3:.0f} kW "
          f"(75% oversubscription)")

    streams = {s.name: s for s in reference_streams() + holdout_streams()}
    for i, (name, chips) in enumerate(queue):
        session.submit(streams[name], device=inventory[i % len(inventory)],
                       chips=chips, seed=i)

    report = session.run()
    print(f"\nmultiplexed run: {report.early_decisions}/{len(queue)} jobs "
          f"capped early, {report.repacks} re-packs, "
          f"{report.chunks_dropped} telemetry chunks saved")
    for job_id, d in report.decisions.items():
        when = f"{d.fraction:4.0%} of trace" if d.early else "full trace"
        print(f"  {job_id:48s} cap=f{d.cap:.2f} ({when})")

    res = report.schedule
    print(f"\nfinal packing: {len(res.placed)} jobs placed, "
          f"{len(res.deferred)} deferred:")
    for j in res.placed:
        print(f"  {j.name:36s} chips={j.chips:4d} cap=f{j.cap:.2f} "
              f"{report.quantile}={j.predicted_p90_w:5.0f} W/chip "
              f"on {j.device_id} (neighbor: {j.selection.power_neighbor})")
    for name in res.deferred:
        print(f"  deferred: {name}")
    print(f"\nplanned power: {res.planned_power_w / 1e3:.0f} kW "
          f"({res.planned_power_w / budget:.0%} of budget); headroom "
          f"reclaimed vs TDP provisioning: "
          f"{res.headroom_reclaimed_w / 1e3:+.1f} kW")


if __name__ == "__main__":
    main()
