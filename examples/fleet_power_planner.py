"""Fleet what-if: pack a job mix into a pod power budget using Minos
predictions (the paper's POLCA-style oversubscription use case, §4.3) — with
jobs admitted one at a time through the online pipeline.

    PYTHONPATH=src python examples/fleet_power_planner.py

Each queued job streams its single uncapped profiling run through
``OnlineCapController``; as soon as the controller is confident it issues the
cap and the pod is re-packed (deterministic first-fit-decreasing) around the
new job's predicted p90 power.
"""
from benchmarks.common import reference_library
from repro.analysis.hardware import V5E
from repro.pipeline import OnlineCapController, ProfileBuilder
from repro.sched import PowerAwareScheduler
from repro.telemetry import TPUPowerModel, stream_telemetry
from repro.telemetry.workloads import holdout_streams, reference_streams


def main() -> None:
    lib = reference_library()
    clf = lib.classifier()          # warm-started from the on-disk cache
    sched = PowerAwareScheduler(clf, tdp_w=V5E.tdp_w,
                                objective="powercentric")
    controller = OnlineCapController(clf, objective="powercentric",
                                     min_confidence=0.2)

    # a queue of jobs: each streams one uncapped profiling run
    model = TPUPowerModel()
    streams = {s.name: s for s in reference_streams() + holdout_streams()}
    queue = [
        ("command-r-35b:train_4k", 256),
        ("deepseek-v2-236b:decode_32k", 256),
        ("vector-search", 64),
        ("granite-moe-3b-a800m:decode_32k", 64),
        ("lsms-like", 32),
    ]
    total_chips = sum(c for _, c in queue)
    nameplate = total_chips * V5E.tdp_w
    budget = 0.75 * nameplate   # an oversubscribed pod
    print(f"pod: {total_chips} chips, nameplate {nameplate/1e3:.0f} kW, "
          f"budget {budget/1e3:.0f} kW (75% oversubscription)")

    admitted = []
    res = None
    for i, (name, chips) in enumerate(queue):
        meta, chunks = stream_telemetry(streams[name], 1.0, model, seed=i)
        builder = ProfileBuilder(meta, V5E.tdp_w)
        decision = None
        for chunk in chunks:
            builder.ingest(chunk)
            decision = controller.observe(builder)
            if decision is not None:
                break
        if decision is None:
            decision = controller.finalize(builder)
        profile = builder.snapshot() if decision.early \
            else builder.finalize()
        admitted.append((profile, chips))
        # cap decided -> re-pack the pod around the new power picture
        res = controller.repack(sched, admitted, budget_w=budget)
        when = f"{decision.fraction:4.0%} of trace" if decision.early \
            else "full trace"
        print(f"  + {name:36s} cap=f{decision.cap:.2f} ({when})  "
              f"-> {len(res.placed)} placed / {len(res.deferred)} deferred, "
              f"{res.planned_power_w/1e3:5.0f} kW planned")

    # res already holds the re-pack from the last admission
    print(f"\nfinal packing: {len(res.placed)} jobs placed, "
          f"{len(res.deferred)} deferred:")
    for j in res.placed:
        print(f"  {j.name:36s} chips={j.chips:4d} cap=f{j.cap:.2f} "
              f"p90={j.predicted_p90_w:5.0f} W/chip "
              f"(neighbor: {j.selection.power_neighbor})")
    for name in res.deferred:
        print(f"  deferred: {name}")
    print(f"\nplanned p90 power: {res.planned_power_w/1e3:.0f} kW "
          f"({res.planned_power_w/budget:.0%} of budget; a TDP-provisioned "
          f"scheduler would reserve {nameplate/1e3:.0f} kW)")


if __name__ == "__main__":
    main()
