"""Fleet what-if: pack a job mix into a pod power budget using Minos
predictions (the paper's POLCA-style oversubscription use case, §4.3).

    PYTHONPATH=src python examples/fleet_power_planner.py
"""
import numpy as np

from benchmarks.common import reference_library
from repro.analysis.hardware import V5E
from repro.core import MinosClassifier
from repro.sched import PowerAwareScheduler
from repro.telemetry import TPUPowerModel, profile_once
from repro.telemetry.workloads import holdout_streams, reference_streams


def main() -> None:
    refs = reference_library()
    clf = MinosClassifier(refs)
    sched = PowerAwareScheduler(clf, tdp_w=V5E.tdp_w, objective="powercentric")

    # a queue of jobs: profiles from one uncapped run each
    model = TPUPowerModel()
    streams = {s.name: s for s in reference_streams() + holdout_streams()}
    queue = [
        ("command-r-35b:train_4k", 256),
        ("deepseek-v2-236b:decode_32k", 256),
        ("vector-search", 64),
        ("granite-moe-3b-a800m:decode_32k", 64),
        ("lsms-like", 32),
    ]
    jobs = [(profile_once(streams[name], model, V5E.tdp_w, seed=i), chips)
            for i, (name, chips) in enumerate(queue)]
    jobs = [(p, c) for (p, c) in jobs]

    total_chips = sum(c for _, c in queue)
    nameplate = total_chips * V5E.tdp_w
    budget = 0.75 * nameplate   # an oversubscribed pod
    print(f"pod: {total_chips} chips, nameplate {nameplate/1e3:.0f} kW, "
          f"budget {budget/1e3:.0f} kW (75% oversubscription)")

    res = sched.schedule(jobs, budget_w=budget)
    print(f"\nplaced {len(res.placed)} jobs, deferred {len(res.deferred)}:")
    for j in res.placed:
        print(f"  {j.name:36s} chips={j.chips:4d} cap=f{j.cap:.2f} "
              f"p90={j.predicted_p90_w:5.0f} W/chip "
              f"(neighbor: {j.selection.power_neighbor})")
    for name in res.deferred:
        print(f"  deferred: {name}")
    print(f"\nplanned p90 power: {res.planned_power_w/1e3:.0f} kW "
          f"({res.planned_power_w/budget:.0%} of budget; a TDP-provisioned "
          f"scheduler would reserve {nameplate/1e3:.0f} kW)")


if __name__ == "__main__":
    main()
