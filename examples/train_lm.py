"""End-to-end driver: train a ~100M-parameter GLM-family model for a few
hundred steps on CPU with the full stack — synthetic byte corpus, AdamW,
checkpointing, straggler monitor, and Minos telemetry classification of the
run itself.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses
import tempfile

from repro.configs import ARCHS
from repro.configs.base import RunConfig, ShapeConfig
from repro.core import MinosClassifier, select_optimal_freq
from repro.data import ByteCorpus
from repro.models.common import SMOKE_TOPO
from repro.pipeline import stream_profile_once, stream_profile_workload
from repro.telemetry import TPUPowerModel
from repro.telemetry.kernel_stream import build_stream, micro_gemm, \
    micro_spmv_memory, micro_idle_burst
from repro.train import Trainer


def hundred_m_config():
    """~100M params in the glm4 family (exact: printed at startup)."""
    return ARCHS["glm4-9b"].reduced(
        num_layers=10, d_model=640, num_heads=10, num_kv_heads=2,
        head_dim=64, d_ff=2560, vocab_size=32768)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    cfg = hundred_m_config()
    print(f"model: {cfg.param_count()/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model} ff={cfg.d_ff})")
    shape = ShapeConfig("train_demo", args.seq_len, args.batch, "train")
    run = RunConfig(total_steps=args.steps, warmup_steps=20,
                    learning_rate=3e-3, checkpoint_every=100,
                    checkpoint_dir=tempfile.mkdtemp(prefix="repro_100m_"))

    telemetry_log = []
    trainer = Trainer(cfg, shape, run, SMOKE_TOPO,
                      data=ByteCorpus(cfg, shape),
                      telemetry_hook=lambda s, dt, m: telemetry_log.append((s, dt, m)))
    res = trainer.run()
    n = len(res.losses)
    for i in range(0, n, max(n // 10, 1)):
        print(f"  step {i+1:4d}  loss {res.losses[i]:.4f}  "
              f"({res.step_durations[i]*1e3:.0f} ms/step)")
    print(f"  final loss {res.losses[-1]:.4f} (start {res.losses[0]:.4f})")

    # classify THIS training job with Minos (via its kernel-stream signature)
    model = TPUPowerModel()
    refs = [stream_profile_workload(s, model, (0.6, 0.8, 1.0),
                                    model.spec.tdp_w, seed=i,
                                    target_duration=1.0)
            for i, s in enumerate([micro_gemm(), micro_spmv_memory(),
                                   micro_idle_burst()])]
    clf = MinosClassifier(refs)
    job_profile = stream_profile_once(build_stream(cfg, shape, n_chips=1),
                                      model, model.spec.tdp_w)
    sel = select_optimal_freq(job_profile, clf)
    print(f"\nMinos classification of this job: power-neighbor="
          f"{sel.power_neighbor}, PowerCentric cap f={sel.f_pwr:.2f}")


if __name__ == "__main__":
    main()
