"""Quickstart: classify a never-before-seen workload and pick its frequency
cap through the ``MinosSession`` facade — end to end in under a minute on
CPU.

    PYTHONPATH=src python examples/quickstart.py

The facade, in order:
  1. ``stream_profile_workload``  -> a small versioned ``ReferenceLibrary``
  2. ``MinosSession.submit``  -> the new workload's one low-cost profiling
     run, streamed chunk by chunk on the session's device
  3. ``JobHandle.run``  -> Algorithm 1 on the *partial* profile, the cap
     issued (and actuated) as soon as the distance-margin confidence clears
"""
from repro.api import (DeviceInventory, MinosSession, ReferenceLibrary,
                       TPUPowerModel, VariabilityModel, micro_gemm,
                       micro_idle_burst, micro_spmv_compute,
                       micro_spmv_memory, micro_stencil, micro_vector_search,
                       profiling_savings, select_optimal_freq,
                       stream_profile_workload)


def main() -> None:
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    freqs = (0.6, 0.7, 0.8, 0.9, 1.0)

    # 1. reference library: a few workloads profiled across the freq sweep,
    #    streamed through the incremental ProfileBuilder
    print("building a small reference library (5 workloads x 5 freqs)...")
    lib = ReferenceLibrary(
        stream_profile_workload(s, model, freqs, tdp, seed=i,
                                target_duration=1.0)
        for i, s in enumerate([micro_gemm(), micro_spmv_memory(),
                               micro_spmv_compute(), micro_idle_burst(),
                               micro_stencil()]))
    print(f"  library v{lib.version}: {', '.join(lib.names)}")

    # 2. a NEW workload arrives: one session owns the library, the device,
    #    and the policies; submit attaches the job's single low-cost
    #    profiling run and run() pumps it to the first confident decision
    session = MinosSession(lib, objective="powercentric", actuator="sim",
                           min_confidence=0.2)
    job = session.submit(micro_vector_search(), seed=99)
    decision = job.run()           # profiling stops at the early cap
    target = job.snapshot() if decision.early else job.profile()
    print(f"\nnew workload: {job.meta.name} (on {job.device.device_id})")
    print(f"  p90 power     : {target.p_quantile(90):.2f} x TDP")
    print(f"  mxu/hbm util  : {target.sm_util:.2f} / {target.dram_util:.2f}")

    # 3. the online Algorithm 1 decision
    sel = decision.selection
    when = (f"after {decision.fraction:.0%} of the trace"
            if decision.early else "at stream end")
    print(f"\nonline cap decision ({when}, "
          f"confidence {decision.confidence:.2f}):")
    print(f"  bin size        : {sel.bin_size}")
    print(f"  power neighbor  : {sel.power_neighbor} "
          f"(cosine d={sel.power_distance:.3f})")
    print(f"  perf neighbor   : {sel.util_neighbor} "
          f"(euclid d={sel.util_distance:.3f})")
    print(f"  PowerCentric cap: f={sel.f_pwr:.2f}  (p90 spikes < 1.3 x TDP)")
    print(f"  PerfCentric cap : f={sel.f_perf:.2f} (perf loss < 5%)")
    print(f"  actuator now at : f={job.actuator.get_cap():.2f}")

    # 4. validate against ground truth the classifier never saw
    truth = stream_profile_workload(micro_vector_search(), model, freqs, tdp,
                                    seed=99)
    obs = truth.scaling[sel.f_pwr].p90
    print(f"\nvalidation (simulator ground truth):")
    print(f"  observed p90 at cap {sel.f_pwr:.2f}: {obs:.2f} x TDP "
          f"({'within' if obs <= 1.3 else 'EXCEEDS'} the 1.3 bound)")
    print(f"  profiling time saved vs full sweep: "
          f"{profiling_savings(truth, list(freqs)):.0%}")

    # 5. device portability: the SAME session library serves a chip that
    #    lost the silicon lottery — submit on that device and the builder
    #    normalizes by its *effective* TDP automatically
    device = DeviceInventory.generate(
        1, VariabilityModel(sigma_power=0.10), seed=13)[0]
    job_d = session.submit(micro_vector_search(), device=device, seed=99,
                           job_id="vector-search@lottery-loser",
                           profile_to_completion=True)
    job_d.run(stop_early=False)        # full trace, for apples-to-apples
    sel_dev = select_optimal_freq(job_d.profile(), session.classifier)
    # the nominal baseline is the FULL-trace selection (truth, from step 4)
    sel_full = select_optimal_freq(truth, session.classifier)
    print(f"\ndevice portability ({device.device_id}, power "
          f"x{device.spec.power_scale:.3f}, eff-TDP "
          f"{device.spec.effective_tdp_w:.1f} W):")
    print(f"  power neighbor  : {sel_dev.power_neighbor} (same as nominal "
          f"full-trace: {sel_dev.power_neighbor == sel_full.power_neighbor})")
    print(f"  PowerCentric cap: f={sel_dev.f_pwr:.2f} "
          f"(nominal chose f={sel_full.f_pwr:.2f})")

    # 6. the whole session, as one JSON-able report
    report = session.run()
    print(f"\nsession report: {len(report.decisions)} decisions "
          f"({report.early_decisions} early), {report.repacks} re-packs, "
          f"{len(report.to_json())} bytes as JSON")


if __name__ == "__main__":
    main()
