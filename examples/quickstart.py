"""Quickstart: classify a never-before-seen workload and pick its frequency
cap with the Minos streaming pipeline — end to end in under a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

The pipeline front door, in order:
  1. ``stream_profile_workload``  -> a small versioned ``ReferenceLibrary``
  2. ``stream_telemetry`` + ``ProfileBuilder``  -> the new workload's one
     low-cost profile, ingested chunk by chunk
  3. ``OnlineCapController``  -> Algorithm 1 on the *partial* profile, with
     the cap issued as soon as the distance-margin confidence clears
"""
from repro.pipeline import (OnlineCapController, ProfileBuilder,
                            ReferenceLibrary, stream_profile_workload)
from repro.core.algorithm1 import profiling_savings, select_optimal_freq
from repro.fleet import DeviceInventory, VariabilityModel
from repro.sched import SimActuator
from repro.telemetry import TPUPowerModel, profile_workload, stream_telemetry
from repro.telemetry.kernel_stream import (micro_gemm, micro_idle_burst,
                                           micro_spmv_compute,
                                           micro_spmv_memory, micro_stencil,
                                           micro_vector_search)


def main() -> None:
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    freqs = (0.6, 0.7, 0.8, 0.9, 1.0)

    # 1. reference library: a few workloads profiled across the freq sweep,
    #    streamed through the incremental ProfileBuilder
    print("building a small reference library (5 workloads x 5 freqs)...")
    lib = ReferenceLibrary(
        stream_profile_workload(s, model, freqs, tdp, seed=i,
                                target_duration=1.0)
        for i, s in enumerate([micro_gemm(), micro_spmv_memory(),
                               micro_spmv_compute(), micro_idle_burst(),
                               micro_stencil()]))
    print(f"  library v{lib.version}: {', '.join(lib.names)}")

    # 2. a NEW workload arrives: stream its ONE low-cost profiling run
    #    through the builder, watching for an early cap decision
    actuator = SimActuator()
    controller = OnlineCapController(lib, objective="powercentric",
                                     actuator=actuator, min_confidence=0.2)
    meta, chunks = stream_telemetry(micro_vector_search(), 1.0, model,
                                    seed=99)
    builder = ProfileBuilder(meta, tdp)
    decision = None
    for chunk in chunks:
        builder.ingest(chunk)
        decision = controller.observe(builder)
        if decision is not None:
            break
    if decision is None:
        decision = controller.finalize(builder)
    target = builder.snapshot() if decision.early else builder.finalize()
    print(f"\nnew workload: {meta.name}")
    print(f"  p90 power     : {target.p_quantile(90):.2f} x TDP")
    print(f"  mxu/hbm util  : {target.sm_util:.2f} / {target.dram_util:.2f}")

    # 3. the online Algorithm 1 decision
    sel = decision.selection
    when = (f"after {decision.fraction:.0%} of the trace"
            if decision.early else "at stream end")
    print(f"\nonline cap decision ({when}, "
          f"confidence {decision.confidence:.2f}):")
    print(f"  bin size        : {sel.bin_size}")
    print(f"  power neighbor  : {sel.power_neighbor} "
          f"(cosine d={sel.power_distance:.3f})")
    print(f"  perf neighbor   : {sel.util_neighbor} "
          f"(euclid d={sel.util_distance:.3f})")
    print(f"  PowerCentric cap: f={sel.f_pwr:.2f}  (p90 spikes < 1.3 x TDP)")
    print(f"  PerfCentric cap : f={sel.f_perf:.2f} (perf loss < 5%)")
    print(f"  actuator now at : f={actuator.get_cap():.2f}")

    # 4. validate against ground truth the classifier never saw
    truth = profile_workload(micro_vector_search(), model, freqs, tdp,
                             seed=99)
    obs = truth.scaling[sel.f_pwr].p90
    print(f"\nvalidation (simulator ground truth):")
    print(f"  observed p90 at cap {sel.f_pwr:.2f}: {obs:.2f} x TDP "
          f"({'within' if obs <= 1.3 else 'EXCEEDS'} the 1.3 bound)")
    print(f"  profiling time saved vs full sweep: "
          f"{profiling_savings(truth, list(freqs)):.0%}")

    # 5. device portability: the SAME library serves a chip that lost the
    #    silicon lottery — stream the workload through that device's
    #    perturbed power model and normalize by its *effective* TDP
    device = DeviceInventory.generate(
        1, VariabilityModel(sigma_power=0.10), seed=13)[0]
    meta_d, chunks_d = stream_telemetry(micro_vector_search(), 1.0,
                                        device.power_model(), seed=99,
                                        device_id=device.device_id)
    builder_d = ProfileBuilder(meta_d, device.spec.effective_tdp_w)
    for chunk in chunks_d:
        builder_d.ingest(chunk)
    sel_dev = select_optimal_freq(builder_d.finalize(), lib.classifier())
    # apples to apples: the nominal baseline is the FULL-trace selection
    # (truth, from step 4), not the early partial-profile decision
    sel_full = select_optimal_freq(truth, lib.classifier())
    print(f"\ndevice portability ({device.device_id}, power "
          f"x{device.spec.power_scale:.3f}, eff-TDP "
          f"{device.spec.effective_tdp_w:.1f} W):")
    print(f"  power neighbor  : {sel_dev.power_neighbor} (same as nominal "
          f"full-trace: {sel_dev.power_neighbor == sel_full.power_neighbor})")
    print(f"  PowerCentric cap: f={sel_dev.f_pwr:.2f} "
          f"(nominal chose f={sel_full.f_pwr:.2f})")


if __name__ == "__main__":
    main()
