"""Quickstart: classify a never-before-seen workload and pick its frequency
cap with Minos — end to end in under a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.analysis.hardware import FREQ_SWEEP
from repro.core import MinosClassifier, select_optimal_freq
from repro.core.algorithm1 import profiling_savings
from repro.telemetry import TPUPowerModel, profile_once, profile_workload
from repro.telemetry.kernel_stream import (micro_gemm, micro_idle_burst,
                                           micro_spmv_compute,
                                           micro_spmv_memory, micro_stencil,
                                           micro_vector_search)


def main() -> None:
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    freqs = (0.6, 0.7, 0.8, 0.9, 1.0)

    # 1. reference library: a few profiled-once-per-frequency workloads
    print("building a small reference library (5 workloads x 5 freqs)...")
    refs = [profile_workload(s, model, freqs, tdp, seed=i, target_duration=1.0)
            for i, s in enumerate([micro_gemm(), micro_spmv_memory(),
                                   micro_spmv_compute(), micro_idle_burst(),
                                   micro_stencil()])]
    clf = MinosClassifier(refs)

    # 2. a NEW workload arrives: profile it ONCE, at the default clock
    target = profile_once(micro_vector_search(), model, tdp, seed=99)
    print(f"\nnew workload: {target.name}")
    print(f"  p90 power     : {target.p_quantile(90):.2f} x TDP")
    print(f"  mxu/hbm util  : {target.sm_util:.2f} / {target.dram_util:.2f}")

    # 3. Algorithm 1: pick the frequency cap from the nearest neighbors
    sel = select_optimal_freq(target, clf)
    print(f"\nAlgorithm 1 selection:")
    print(f"  bin size        : {sel.bin_size}")
    print(f"  power neighbor  : {sel.power_neighbor} (cosine d={sel.power_distance:.3f})")
    print(f"  perf neighbor   : {sel.util_neighbor} (euclid d={sel.util_distance:.3f})")
    print(f"  PowerCentric cap: f={sel.f_pwr:.2f}  (p90 spikes < 1.3 x TDP)")
    print(f"  PerfCentric cap : f={sel.f_perf:.2f} (perf loss < 5%)")

    # 4. validate against ground truth the classifier never saw
    truth = profile_workload(micro_vector_search(), model, freqs, tdp, seed=99)
    obs = truth.scaling[sel.f_pwr].p90
    print(f"\nvalidation (simulator ground truth):")
    print(f"  observed p90 at cap {sel.f_pwr:.2f}: {obs:.2f} x TDP "
          f"({'within' if obs <= 1.3 else 'EXCEEDS'} the 1.3 bound)")
    print(f"  profiling time saved vs full sweep: "
          f"{profiling_savings(truth, list(freqs)):.0%}")


if __name__ == "__main__":
    main()
