import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in-process; never globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _install_hypothesis_shim() -> None:
    """Make ``from hypothesis import given, settings, strategies`` work in
    containers without hypothesis installed.

    The shim is a deliberately tiny stand-in: ``@given`` draws a fixed number
    of pseudo-random examples from the strategies (deterministic seed, no
    shrinking, no edge-case bias) — enough to keep the property tests
    meaningful and the suite collectible.  When the real hypothesis is
    importable it is always preferred.
    """
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass

    import types

    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def floats(min_value=0.0, max_value=1.0, **_ignored):
        return _Strategy(
            lambda r: float(min_value + (max_value - min_value) * r.random()))

    def integers(min_value=0, max_value=100):
        return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[int(r.integers(len(elements)))])

    def lists(elements, min_size=0, max_size=10):
        def draw(r):
            size = int(r.integers(min_size, max_size + 1))
            return [elements.draw(r) for _ in range(size)]
        return _Strategy(draw)

    def just(value):
        return _Strategy(lambda r: value)

    def tuples(*strategies):
        return _Strategy(lambda r: tuple(s.draw(r) for s in strategies))

    def one_of(*strategies):
        strategies = [s for group in strategies
                      for s in (group if isinstance(group, (list, tuple))
                                else (group,))]
        return _Strategy(
            lambda r: strategies[int(r.integers(len(strategies)))].draw(r))

    def given(*strategies):
        def deco(fn):
            # zero-arg wrapper (not functools.wraps): the strategy parameters
            # must not leak into the signature pytest inspects for fixtures
            def wrapper():
                rng = _np.random.default_rng(0)
                for _ in range(getattr(fn, "_shim_max_examples", 20)):
                    fn(*[s.draw(rng) for s in strategies])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__dict__.update(fn.__dict__)   # keep pytest marks
            return wrapper
        return deco

    def settings(max_examples=20, deadline=None, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.__doc__ = "pytest-time fallback shim (see tests/conftest.py)"
    st_mod = types.ModuleType("hypothesis.strategies")
    for f in (floats, integers, sampled_from, lists, just, tuples, one_of):
        setattr(st_mod, f.__name__, f)
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


_install_hypothesis_shim()

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
