import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in-process; never globally here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)
