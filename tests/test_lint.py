"""Tests for the minoslint contract checker (ISSUE 10 tentpole).

Three layers:

* **fixtures** — each ``tests/lint_fixtures/bad_*.py`` snippet must make
  the CLI exit non-zero with exactly the expected rule family, and its
  ``good_*.py`` twin must exit 0 (the fixtures carry ``minoslint: path=``
  pragmas so scoped rules apply);
* **tree** — ``python -m repro.lint`` exits 0 on the merged tree, with
  every suppression counted in the JSON report;
* **regressions** — deleting one ``_journal`` call (fleet retire) or one
  replay handler (session RETIRE case) from the *real* sources must trip
  the write-ahead / exhaustiveness pass, which is the acceptance
  criterion that the checker guards the architecture, not just the
  fixtures.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.lint import RULES, run
from repro.lint.core import (LintContext, SourceFile, discover_files,
                             load_context)

REPO = Path(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
FIXTURES = REPO / "tests" / "lint_fixtures"

#: bad fixture -> rule ids it must (exactly) trigger
BAD_FIXTURES = {
    "bad_writeahead.py": {"W101"},
    "bad_record_kinds.py": {"W201", "W202", "W203"},
    "bad_determinism.py": {"W301", "W302", "W303", "W304"},
    "bad_layering.py": {"W401", "W403"},
    "bad_facade.py": {"W402"},
    "bad_floatcontract.py": {"W501", "W502"},
}

GOOD_FIXTURES = [
    "good_writeahead.py", "good_record_kinds.py", "good_determinism.py",
    "good_layering.py", "good_facade.py", "good_floatcontract.py",
]


def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.lint", *args],
        cwd=REPO, env=env, capture_output=True, text=True)


# -- fixtures ------------------------------------------------------------

def test_every_rule_has_a_bad_fixture():
    covered = set().union(*BAD_FIXTURES.values())
    assert covered == set(RULES), (
        f"rules without fixture coverage: {set(RULES) - covered}")


@pytest.mark.parametrize("name,expected",
                         sorted(BAD_FIXTURES.items()))
def test_bad_fixture_fails(name, expected):
    proc = _cli("--format", "json", str(FIXTURES / name))
    assert proc.returncode != 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    got = {f["rule"] for f in report["findings"]}
    assert got == expected, f"{name}: expected {expected}, got {got}"


@pytest.mark.parametrize("name", GOOD_FIXTURES)
def test_good_fixture_passes(name):
    proc = _cli(str(FIXTURES / name))
    assert proc.returncode == 0, proc.stdout + proc.stderr


# -- tree ----------------------------------------------------------------

def test_tree_is_clean():
    """Zero non-suppressed findings on the merged tree — the state every
    PR must restore before landing."""
    proc = _cli("--format", "json")
    report = json.loads(proc.stdout)
    assert proc.returncode == 0, "\n".join(
        f"{f['path']}:{f['line']}: {f['rule']} {f['message']}"
        for f in report["findings"])
    assert report["ok"] is True
    assert report["counts"]["findings"] == 0


def test_suppressions_are_counted():
    """Inline ``minoslint: disable=`` pragmas are visible in the report —
    suppression is auditable, not silent."""
    proc = _cli("--format", "json")
    report = json.loads(proc.stdout)
    assert report["counts"]["suppressed"] >= 7  # the justified sites
    rules = {f["rule"] for f in report["suppressed"]}
    assert {"W301", "W304"} <= rules
    for f in report["suppressed"]:
        assert f["path"] and f["line"] > 0


def test_report_artifact_written(tmp_path):
    out = tmp_path / "lint_report.json"
    proc = _cli("--format", "json", "--output", str(out))
    assert proc.returncode == 0
    assert json.loads(out.read_text()) == json.loads(proc.stdout)


def test_fixtures_excluded_from_default_scan():
    scanned = {p.relative_to(REPO).as_posix()
               for p in discover_files(REPO)}
    assert not any(p.startswith("tests/lint_fixtures/") for p in scanned)
    assert "tests/test_lint.py" in scanned
    assert "src/repro/fleet/controller.py" in scanned


# -- regressions against the real sources --------------------------------

def _ctx_with_replacement(path: str, old: str, new: str) -> LintContext:
    files = []
    replaced = False
    for p in discover_files(REPO):
        rel = p.relative_to(REPO).as_posix()
        text = p.read_text()
        if rel == path:
            assert old in text, f"expected snippet missing from {path}"
            text = text.replace(old, new)
            replaced = True
        files.append(SourceFile(rel, text))
    assert replaced, f"{path} not in the default scan"
    return LintContext(files, root=str(REPO))


def _active(findings):
    return [f for f in findings if not f.suppressed]


def test_deleting_a_journal_call_trips_writeahead():
    """Remove fleet retire's write-ahead record: the following
    ``self.jobs.pop`` becomes an unjournaled mutation (W101), and the
    RETIRE replay handler goes dead (W202)."""
    ctx = _ctx_with_replacement(
        "src/repro/fleet/controller.py",
        "self._journal(kinds.RETIRE, job_id=job_id)", "pass")
    rules = {f.rule for f in _active(run(ctx))}
    assert "W101" in rules
    assert "W202" in rules


def test_deleting_a_replay_handler_trips_exhaustiveness():
    """Remove the RETIRE case from ``_apply_record``: the kind is still
    emitted, so resume would silently drop it — W201."""
    ctx = _ctx_with_replacement(
        "src/repro/api/session.py",
        '            case kinds.RETIRE:\n'
        '                self.retire(data["job_id"])\n', "")
    findings = _active(run(ctx))
    assert any(f.rule == "W201" and "retire" in f.message
               for f in findings)


def test_emitting_an_unregistered_kind_trips_registry():
    """A new emit site with a kind missing from store/kinds.py -> W203."""
    ctx = _ctx_with_replacement(
        "src/repro/fleet/controller.py",
        "self._journal(kinds.RETIRE, job_id=job_id)",
        'self._journal("vanish", job_id=job_id)')
    rules = {f.rule for f in _active(run(ctx))}
    assert "W203" in rules
    assert "W201" in rules  # and nothing replays it either


def test_clean_tree_via_api():
    """API parity with the CLI: load_context + run on the real tree."""
    findings = _active(run(load_context(REPO)))
    assert findings == [], "\n".join(f.render() for f in findings)
