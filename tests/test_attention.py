"""Chunked/flash jnp attention and decode attention vs exact oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models.attention import chunked_attention, decode_attention
from repro.models.common import SMOKE_TOPO


@pytest.mark.parametrize("sq,skv,H,KV,dh", [
    (128, 128, 8, 2, 32), (96, 96, 4, 4, 64), (64, 192, 6, 3, 32)])
def test_chunked_matches_exact(sq, skv, H, KV, dh):
    b = 2
    ks = jax.random.split(jax.random.key(sq + H), 3)
    q = jax.random.normal(ks[0], (b, sq, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, KV, dh), jnp.float32)
    causal = sq == skv
    qpos = jnp.arange(sq, dtype=jnp.int32) + (skv - sq)
    kpos = jnp.arange(skv, dtype=jnp.int32)
    out = chunked_attention(q, k, v, causal=causal, q_positions=qpos,
                            kv_positions=kpos, topo=SMOKE_TOPO,
                            heads_sharded=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_decode_matches_last_row_of_full_attention():
    b, S, H, KV, dh = 2, 64, 8, 2, 32
    ks = jax.random.split(jax.random.key(0), 3)
    q_all = jax.random.normal(ks[0], (b, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, S, KV, dh), jnp.float32)
    full = ref.flash_attention_ref(q_all, k, v, causal=True)
    # decode for the last position must equal the last row
    out = decode_attention(q_all[:, -1] * (dh ** -0.5) / (dh ** -0.5),
                           k, v, jnp.int32(S - 1), SMOKE_TOPO)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, -1]),
                               rtol=3e-5, atol=3e-5)


def test_decode_mask_ignores_future_cache():
    b, S, H, KV, dh = 1, 32, 4, 4, 16
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, S, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, S, KV, dh), jnp.float32)
    t = jnp.int32(10)
    out1 = decode_attention(q, k, v, t, SMOKE_TOPO)
    # scribble on cache beyond t: result must not change
    k2 = k.at[:, 11:].set(99.0)
    v2 = v.at[:, 11:].set(-99.0)
    out2 = decode_attention(q, k2, v2, t, SMOKE_TOPO)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))
