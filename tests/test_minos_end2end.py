"""End-to-end Minos behaviour on simulated telemetry (small, fast zoo)."""
import numpy as np
import pytest

from repro.analysis.hardware import V5E
from repro.core import MinosClassifier, select_optimal_freq
from repro.core.algorithm1 import cap_power_centric
from repro.core.baselines import mean_power_neighbor
from repro.core.reference_store import load_profiles, save_profiles
from repro.pipeline import stream_profile_once, stream_profile_workload
from repro.telemetry import TPUPowerModel
from repro.telemetry.kernel_stream import (micro_gemm, micro_idle_burst,
                                           micro_spmv_compute,
                                           micro_spmv_memory, micro_stencil,
                                           micro_vector_search)

FREQS = (0.6, 0.8, 1.0)


@pytest.fixture(scope="module")
def small_refs():
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    streams = [micro_gemm(), micro_spmv_memory(), micro_spmv_compute(),
               micro_idle_burst(), micro_stencil()]
    return [stream_profile_workload(s, model, FREQS, tdp, seed=i,
                             target_duration=1.0)
            for i, s in enumerate(streams)]


def test_power_neighbor_is_sane(small_refs):
    model = TPUPowerModel()
    clf = MinosClassifier(small_refs)
    target = stream_profile_once(micro_vector_search(), model, model.spec.tdp_w, seed=42)
    nn, d = clf.power_neighbor(target)
    # FAISS-like batched distance GEMMs look like compute-bound workloads
    assert nn.name in ("sgemm-25k", "mpsdns-like", "pagerank-gunrock")
    assert d < 0.5


def test_util_classes_separate_compute_from_memory(small_refs):
    clf = MinosClassifier(small_refs)
    util = {r.name: r.util_point for r in small_refs}
    assert util["sgemm-25k"][1] > 0.9          # SM util high
    assert util["pagerank-pannotia"][0] > 0.9  # DRAM util high
    labels, centers, k, _ = clf.util_classes(k=2)
    by_name = dict(zip([r.name for r in small_refs], labels))
    assert by_name["sgemm-25k"] != by_name["pagerank-pannotia"]


def test_full_selection_and_prediction_accuracy(small_refs):
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    clf = MinosClassifier(small_refs)
    observed = stream_profile_once(micro_vector_search(), model, tdp, seed=7)
    sel = select_optimal_freq(observed, clf)
    assert sel.f_pwr in FREQS and sel.f_perf in FREQS
    # ground truth (never shown to Minos): profile the target at the cap
    truth = stream_profile_workload(micro_vector_search(), model, FREQS, tdp, seed=7)
    pred_p90 = next(r for r in small_refs if r.name == sel.power_neighbor
                    ).scaling[sel.f_pwr].p90
    true_p90 = truth.scaling[sel.f_pwr].p90
    assert abs(pred_p90 - true_p90) < 0.25


def test_minos_beats_or_matches_mean_power_on_bursty(small_refs):
    """The bursty LSMS-like workload is the paper's counterexample to
    mean-power classification."""
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    clf = MinosClassifier(small_refs)
    target = stream_profile_once(micro_idle_burst(bursts=5, gap_s=0.1), model, tdp, seed=3)
    target.name = "idle-burst-variant"
    nn_minos, _ = clf.power_neighbor(target)
    nn_mean, _ = mean_power_neighbor(target, small_refs)
    assert nn_minos.name == "lsms-like"
    # evaluate p90 prediction quality at uncapped freq
    err_minos = abs(target.p_quantile(90) - nn_minos.p_quantile(90))
    err_mean = abs(target.p_quantile(90) - nn_mean.p_quantile(90))
    assert err_minos <= err_mean + 0.05


def test_reference_store_roundtrip(small_refs, tmp_path):
    save_profiles(small_refs, str(tmp_path))
    loaded = load_profiles(str(tmp_path))
    assert {r.name for r in loaded} == {r.name for r in small_refs}
    a = next(r for r in loaded if r.name == "sgemm-25k")
    b = next(r for r in small_refs if r.name == "sgemm-25k")
    assert a.scaling[1.0].p90 == pytest.approx(b.scaling[1.0].p90, rel=1e-5)
    np.testing.assert_allclose(a.power_trace, b.power_trace, rtol=1e-5)
