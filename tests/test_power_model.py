"""Power model invariants (the simulator's physics)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.hardware import V5E
from repro.telemetry.kernel_stream import Kernel
from repro.telemetry.power_model import TPUPowerModel


@pytest.fixture(scope="module")
def model():
    return TPUPowerModel()


def test_calibration_points(model):
    tdp = V5E.tdp_w
    assert model.steady_power(1.0, 0.2, 1.0) == pytest.approx(1.3 * tdp, rel=1e-6)
    assert model.steady_power(0.15, 0.9, 1.0) == pytest.approx(0.75 * tdp, rel=1e-6)
    assert model.steady_power(0.0, 0.0, 1.0) == pytest.approx(V5E.idle_w)


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0),
       st.floats(V5E.f_min, V5E.f_max))
@settings(max_examples=60, deadline=None)
def test_power_monotone_in_util_and_freq(uc, um, f):
    m = TPUPowerModel()
    p = m.steady_power(uc, um, f)
    assert p >= V5E.idle_w - 1e-9
    assert m.steady_power(min(uc + 0.1, 1.0), um, f) >= p - 1e-9
    assert m.steady_power(uc, min(um + 0.1, 1.0), f) >= p - 1e-9
    assert m.steady_power(uc, um, min(f + 0.05, 1.0)) >= p - 1e-9


def test_compute_bound_kernel_scales_with_freq(model):
    k = Kernel("gemm", flops=1e12, bytes=1e9)
    full = model.exec_kernel(k, 1.0)
    slow = model.exec_kernel(k, 0.6)
    assert slow.duration == pytest.approx(full.duration / 0.6, rel=1e-3)
    assert full.util_c > 0.95


def test_memory_bound_kernel_invariant_to_cap(model):
    k = Kernel("stream", flops=1e9, bytes=1e12)
    full = model.exec_kernel(k, 1.0)
    slow = model.exec_kernel(k, 0.6)
    assert slow.duration == pytest.approx(full.duration, rel=1e-3)
    assert full.util_m > 0.95
    assert slow.power <= full.power + 1e-9


@given(st.floats(V5E.idle_w, 1.3 * V5E.tdp_w),
       st.floats(V5E.idle_w, 1.3 * V5E.tdp_w))
@settings(max_examples=60, deadline=None)
def test_overshoot_respects_ocp_ceiling(p_prev, p_new):
    m = TPUPowerModel()
    amp = m.overshoot(p_prev, p_new)
    if amp is not None:
        assert p_new - p_prev >= 30.0
        assert amp <= V5E.max_excursion * V5E.tdp_w + 1e-9
        assert amp >= p_new - 1e-9
