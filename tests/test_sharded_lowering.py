"""Sharded lowering smoke (subprocess: needs its own XLA device count)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import ARCHS
    from repro.configs.base import MeshConfig, RunConfig, ShapeConfig
    from repro.models import build_model, input_pspecs, input_specs
    from repro.models.common import Topo, make_mesh_from_config
    from repro.train.step import make_train_step, state_pspecs, state_shapes

    mcfg = MeshConfig(shape=(4, 4), axis_names=("data", "model"))
    mesh = make_mesh_from_config(mcfg)
    topo = Topo(mcfg)
    out = {}
    for arch in ["glm4-9b", "falcon-mamba-7b", "deepseek-v2-236b",
                 "phi3-medium-14b"]:
        cfg = ARCHS[arch].reduced(num_layers=2, d_model=256, num_heads=8,
                                  head_dim=32, d_ff=512, vocab_size=1024)
        shape = ShapeConfig("small", seq_len=128, global_batch=8, kind="train")
        model = build_model(cfg, topo, kind="train")
        step = make_train_step(model, RunConfig(microbatches=2), topo)
        ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                    is_leaf=lambda x: isinstance(x, P))
        with mesh:
            compiled = jax.jit(
                step,
                in_shardings=(ns(state_pspecs(model, topo)),
                              ns(input_pspecs(cfg, shape, topo))),
                out_shardings=(ns(state_pspecs(model, topo)), None),
                donate_argnums=(0,),
            ).lower(state_shapes(model, RunConfig()),
                    input_specs(cfg, shape)).compile()
        txt = compiled.as_text()
        out[arch] = {
            "collectives": sum(txt.count(k) > 0 for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all")),
            "flops": compiled.cost_analysis().get("flops", 0.0),
        }
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
@pytest.mark.xfail(
    strict=False,
    reason="pre-existing seed failure: make_mesh_from_config uses "
           "jax.sharding.AxisType, which this container's jax does not "
           "expose (AttributeError in the lowering subprocess); passes on "
           "newer jax, so not strict")
def test_reduced_models_lower_on_4x4_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert set(out) == {"glm4-9b", "falcon-mamba-7b", "deepseek-v2-236b",
                        "phi3-medium-14b"}
    for arch, rec in out.items():
        assert rec["collectives"] >= 1, arch   # SPMD actually partitioned
        assert rec["flops"] > 0
