"""Facade tests (PR 4 acceptance): MinosSession decisions byte-identical to
the direct pipeline/fleet paths on the 28-workload zoo, dynamic
submit->feed->retire->submit lifecycle re-packing without re-classification
(classifier call-count pinned), JSON round-trips of the typed result
objects, plugin registries, and declarative from_config construction."""
import json
import math

import numpy as np
import pytest

from repro.api import (ACTUATORS, OBJECTIVES, QUANTILES, CapDecision,
                       DeviceInventory, FleetCapController, FleetTelemetryMux,
                       FrequencyActuator, JobPlan, MinosSession,
                       OnlineCapController, ReferenceLibrary, SessionReport,
                       TPUPowerModel, VariabilityModel, from_dict, from_json,
                       micro_gemm, micro_idle_burst, micro_spmv_compute,
                       micro_spmv_memory, micro_stencil, reference_streams,
                       count_classifier_calls as _count_classifier_calls,
                       register_actuator, register_objective,
                       register_quantile, stream_profile_workload,
                       stream_telemetry, to_dict, to_json)

MODEL = TPUPowerModel()
TDP = MODEL.spec.tdp_w
FREQS = (0.6, 0.8, 1.0)
GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)


@pytest.fixture(scope="module")
def micro_library():
    return ReferenceLibrary(
        (stream_profile_workload(s, MODEL, FREQS, TDP, seed=i,
                                 target_duration=0.5)
         for i, s in enumerate([micro_gemm(), micro_idle_burst(),
                                micro_spmv_memory(), micro_stencil()])),
        built_on="tpu-v5e")


def _assert_same_decision(got: CapDecision, expect: CapDecision):
    """Byte-identity on everything except the device tag (the facade always
    runs on a device; the direct single-job path has none)."""
    assert got.selection == expect.selection      # neighbor + bin + caps
    assert got.cap == expect.cap
    assert got.objective == expect.objective
    assert got.confidence == expect.confidence
    assert got.fraction == expect.fraction
    assert got.n_samples == expect.n_samples
    assert got.early == expect.early


# ---------------------------------------------------------------------------
# acceptance pin: facade == direct paths, across the whole zoo
# ---------------------------------------------------------------------------
def test_session_byte_identical_to_online_controller_on_zoo(micro_library):
    """Every workload in the 28-stream zoo gets the byte-identical decision
    whether it goes through MinosSession.submit/run or the direct
    OnlineCapController.run path."""
    streams = reference_streams()
    assert len(streams) == 28                    # the paper-scale zoo
    session = MinosSession(micro_library, **GATES)
    for i, stream in enumerate(streams):
        handle = session.submit(
            stream_telemetry(stream, 1.0, MODEL, seed=100 + i,
                             target_duration=0.5))
        got = handle.run()
        single = OnlineCapController(micro_library, **GATES)
        meta, chunks = stream_telemetry(stream, 1.0, MODEL, seed=100 + i,
                                        target_duration=0.5)
        expect = single.run(meta, chunks, TDP)
        _assert_same_decision(got, expect)
        assert got.device_id == handle.device.device_id
        assert handle.decided and handle.plan() is not None


def test_session_byte_identical_to_fleet_controller(micro_library):
    """A heterogeneous variability-on fleet run through the facade equals
    the direct FleetCapController + FleetTelemetryMux path byte-for-byte:
    decisions, packing, repack and drop counters."""
    inv = DeviceInventory.generate({"tpu-v5e": 2, "tpu-v5p": 1},
                                   VariabilityModel(), seed=5)
    jobs = [(micro_gemm, 8), (micro_spmv_memory, 4), (micro_spmv_compute, 2)]
    budget = 0.6 * sum(chips * inv[i % len(inv)].nameplate_w
                       for i, (_, chips) in enumerate(jobs))

    def streams_for(i, dev):
        fn, _ = jobs[i]
        return stream_telemetry(fn(), 1.0, dev.power_model(), seed=40 + i,
                                target_duration=0.5, chunk_samples=100,
                                device_id=dev.device_id)

    fleet = FleetCapController(micro_library, budget_w=budget, **GATES)
    mux = FleetTelemetryMux()
    for i, (fn, chips) in enumerate(jobs):
        dev = inv[i % len(inv)]
        meta, chunks = streams_for(i, dev)
        mux.add_job(fleet.admit(dev, meta, chips), meta, chunks)
    direct = fleet.run(mux)

    session = MinosSession(micro_library, inventory=inv, budget_w=budget,
                           **GATES)
    for i, (fn, chips) in enumerate(jobs):
        dev = inv[i % len(inv)]
        session.submit(streams_for(i, dev), device=dev, chips=chips)
    report = session.run()

    assert report.decisions == direct.decisions  # full dataclass equality
    assert list(report.decisions) == list(direct.decisions)
    assert report.schedule.placed == direct.schedule.placed
    assert report.schedule.deferred == direct.schedule.deferred
    assert report.repacks == direct.repacks
    assert report.chunks_dropped == direct.chunks_dropped
    assert report.budget_w == direct.budget_w


# ---------------------------------------------------------------------------
# acceptance pin: dynamic lifecycle never re-classifies on re-pack
# ---------------------------------------------------------------------------
def test_submit_feed_retire_submit_repacks_without_reclassify(micro_library):
    session = MinosSession(micro_library, **GATES)
    calls = _count_classifier_calls(session.classifier)

    job_a = session.submit(stream_telemetry(micro_gemm(), 1.0, MODEL, seed=1,
                                            target_duration=0.5), chips=4)
    job_b = session.submit(stream_telemetry(micro_spmv_memory(), 1.0, MODEL,
                                            seed=2, target_duration=0.5),
                           chips=4)
    job_a.run()
    job_b.run()
    assert calls["n"] > 0                         # deciding DID classify
    n_decided = calls["n"]
    repacks_decided = session.report().repacks

    # shrink the budget so only the hungrier job fits: repack, no classify
    w_a = job_a.plan().predicted_p90_w * job_a.plan().chips
    w_b = job_b.plan().predicted_p90_w * job_b.plan().chips
    big, small = (job_a, job_b) if w_a >= w_b else (job_b, job_a)
    session.set_budget(max(w_a, w_b) + 0.5 * min(w_a, w_b))
    rep = session.report()
    assert [p.job_id for p in rep.schedule.placed] == [big.job_id]
    assert rep.schedule.deferred == [small.plan().name]
    assert calls["n"] == n_decided

    # retire the placed job: its budget is released and the deferred job
    # packs into the freed headroom — again without a single classification
    retired_plan = big.retire()
    assert retired_plan is not None and retired_plan.job_id == big.job_id
    rep = session.report()
    assert [p.job_id for p in rep.schedule.placed] == [small.job_id]
    assert rep.schedule.deferred == []
    assert big.job_id in rep.retired
    assert calls["n"] == n_decided
    assert rep.repacks > repacks_decided

    # the retired handle keeps its cached artifacts but refuses telemetry
    assert big.decision(finalize=False) is not None
    assert big.plan() is not None
    with pytest.raises(ValueError, match="retired"):
        big.feed([])
    with pytest.raises(KeyError, match="unknown or already-retired"):
        session.retire(big.job_id)

    # a fresh submit after the retirement starts clean; retiring it before
    # any decision releases nothing and still never classifies
    meta, _ = stream_telemetry(micro_stencil(), 1.0, MODEL,
                               target_duration=0.5)
    job_c = session.submit(meta)
    assert session.retire(job_c.job_id) is None
    assert job_c.decision() is None               # nothing cached: no raise
    assert job_c.plan() is None
    assert calls["n"] == n_decided


# ---------------------------------------------------------------------------
# satellite: JSON round-trips of the typed result objects
# ---------------------------------------------------------------------------
def _fleet_report(micro_library) -> SessionReport:
    inv = DeviceInventory.generate({"tpu-v5e": 1, "tpu-v6e": 1},
                                   VariabilityModel(), seed=9)
    session = MinosSession(micro_library, inventory=inv,
                           budget_w=1e9, **GATES)
    for i, fn in enumerate([micro_gemm, micro_idle_burst]):
        session.submit(stream_telemetry(fn(), 1.0, inv[i].power_model(),
                                        seed=i, target_duration=0.5,
                                        device_id=inv[i].device_id),
                       device=inv[i], chips=2 + i)
    session.run()
    session.retire(list(session.jobs)[0])
    return session.report()


def test_json_roundtrip_session_report(micro_library):
    report = _fleet_report(micro_library)
    assert report.decisions and report.retired    # both maps populated
    text = report.to_json()
    back = SessionReport.from_json(text)
    assert back == report
    # order stability: job insertion order survives the round trip
    assert list(back.decisions) == list(report.decisions)
    assert [p.job_id for p in back.schedule.placed] == \
        [p.job_id for p in report.schedule.placed]
    # dtype stability: ints stay ints, floats stay (exact) floats, device
    # tags survive on fleet plans
    plan = back.schedule.placed[0]
    assert isinstance(plan.chips, int)
    assert isinstance(plan.predicted_p90_w, float)
    assert plan.device_id.startswith("tpu-")
    d = next(iter(back.decisions.values()))
    assert isinstance(d.n_samples, int) and isinstance(d.early, bool)
    assert isinstance(d.selection.bin_size, float)
    # a second encode is byte-identical (deterministic field order)
    assert back.to_json() == text


def test_json_roundtrip_decision_and_plan(micro_library):
    report = _fleet_report(micro_library)
    decision = next(iter(report.decisions.values()))
    assert from_json(to_json(decision)) == decision
    plan = report.schedule.placed[0]
    back = from_json(to_json(plan))
    assert back == plan and isinstance(back, JobPlan)
    assert back.selection == plan.selection       # nested FreqSelection
    # json text itself parses as plain data with stable keys
    raw = json.loads(to_json(plan))
    assert raw["__type__"] == "JobPlan"
    assert raw["selection"]["__type__"] == "FreqSelection"


def test_unbounded_budget_serializes_as_strict_json(micro_library):
    session = MinosSession(micro_library, **GATES)     # budget_w = inf
    session.submit(stream_telemetry(micro_gemm(), 1.0, MODEL, seed=1,
                                    target_duration=0.5)).run()
    report = session.run()
    assert math.isinf(report.budget_w)
    text = report.to_json()
    assert "Infinity" not in text                      # RFC-parseable text
    back = SessionReport.from_json(text)
    assert math.isinf(back.budget_w) and back == report


def test_codec_rejects_unknown_payloads():
    with pytest.raises(TypeError, match="not serializable"):
        to_dict(object())
    with pytest.raises(TypeError, match="string dict keys"):
        to_dict({1: "x"})
    with pytest.raises(ValueError, match="unknown serialized type"):
        from_dict({"__type__": "Exploit", "x": 1})
    with pytest.raises(TypeError, match="SessionReport"):
        SessionReport.from_json(to_json({"just": "a dict"}))


# ---------------------------------------------------------------------------
# plugin registries
# ---------------------------------------------------------------------------
def test_custom_objective_flows_through_decisions(micro_library):
    register_objective("api-test-mincap",
                       lambda sel: min(sel.f_pwr, sel.f_perf), replace=True)
    session = MinosSession(micro_library, objective="api-test-mincap",
                           **GATES)
    d = session.submit(stream_telemetry(micro_gemm(), 1.0, MODEL, seed=3,
                                        target_duration=0.5)).run()
    assert d.objective == "api-test-mincap"
    assert d.cap == min(d.selection.f_pwr, d.selection.f_perf)
    # the scheduler plans with the same custom cap
    plan = session.jobs[d.target + "@tpu-v5e/000"].plan()
    assert plan.cap == d.cap
    with pytest.raises(ValueError, match="already registered"):
        register_objective("api-test-mincap", lambda sel: sel.f_pwr)
    with pytest.raises(KeyError, match="unknown objective"):
        MinosSession(micro_library, objective="nope")


def test_custom_quantile_scales_provisioning(micro_library):
    register_quantile("api-test-p95x", lambda fp: fp.p95 * 1.5, replace=True)

    def one_plan(quantile):
        session = MinosSession(micro_library, quantile=quantile, **GATES)
        handle = session.submit(stream_telemetry(
            micro_gemm(), 1.0, MODEL, seed=3, target_duration=0.5))
        handle.run()
        return handle.plan()

    base, scaled = one_plan("p95"), one_plan("api-test-p95x")
    assert scaled.predicted_p90_w == pytest.approx(
        1.5 * base.predicted_p90_w, rel=1e-12)
    with pytest.raises(ValueError, match="QuantilePolicy"):
        MinosSession(micro_library, quantile=0.9)
    with pytest.raises(KeyError, match="unknown quantile"):
        MinosSession(micro_library, quantile="p42")


class _SpyActuator(FrequencyActuator):
    def __init__(self, device):
        self.device = device
        self.caps = []

    def set_cap(self, freq):
        self.caps.append(freq)

    def get_cap(self):
        return self.caps[-1] if self.caps else 1.0


def test_custom_actuator_factory_and_registry(micro_library):
    register_actuator("api-test-spy", _SpyActuator, replace=True)
    session = MinosSession(micro_library, actuator="api-test-spy", **GATES)
    handle = session.submit(stream_telemetry(micro_gemm(), 1.0, MODEL,
                                             seed=3, target_duration=0.5))
    d = handle.run()
    assert isinstance(handle.actuator, _SpyActuator)
    assert handle.actuator.caps == [d.cap]
    assert handle.actuator.device.device_id == d.device_id
    # "none" decides without actuating at all
    quiet = MinosSession(micro_library, actuator="none", **GATES)
    h2 = quiet.submit(stream_telemetry(micro_gemm(), 1.0, MODEL, seed=3,
                                       target_duration=0.5))
    d2 = h2.run()
    assert h2.actuator is None
    _assert_same_decision(d2, d)                  # actuation never feeds back
    with pytest.raises(ValueError, match="callable"):
        register_actuator("api-test-bad", "not-a-factory")
    assert "api-test-spy" in ACTUATORS
    assert {"powercentric", "perfcentric"} <= set(OBJECTIVES.names())
    assert {"p90", "p95", "p99"} <= set(QUANTILES.names())


# ---------------------------------------------------------------------------
# declarative construction
# ---------------------------------------------------------------------------
def test_from_config_builds_full_session(micro_library, tmp_path):
    store = str(tmp_path / "store")
    micro_library.save(store)
    cfg = {
        "library": store,
        "devices": {"tpu-v5e": 2, "tpu-v5p": 1},
        "variability": "none",
        "seed": 4,
        "objective": "perfcentric",
        "actuator": "none",
        "quantile": "p95",
        "budget_fraction_of_nameplate": 0.5,
        "gates": {"min_confidence": 0.25, "min_spike_samples": 10},
    }
    session = MinosSession.from_config(cfg)
    assert len(session.inventory) == 3
    assert session.inventory.models == ["tpu-v5e", "tpu-v5p"]
    assert session.objective == "perfcentric"
    assert session.scheduler.quantile == "p95"
    assert session.budget_w == pytest.approx(
        0.5 * session.inventory.nameplate_w)
    assert session._fleet._gates["min_confidence"] == 0.25
    assert session._fleet._gates["min_spike_samples"] == 10
    assert session.classifier.references[0].name == micro_library.names[0]
    # the same config as JSON text and as a file on disk
    for source in (json.dumps(cfg),):
        s2 = MinosSession.from_config(source)
        assert s2.budget_w == session.budget_w
    path = tmp_path / "session.json"
    path.write_text(json.dumps(cfg))
    s3 = MinosSession.from_config(str(path))
    assert s3.objective == "perfcentric"
    # a config-built session still decides (end to end)
    d = s3.submit(stream_telemetry(micro_gemm(), 1.0, MODEL, seed=3,
                                   target_duration=0.5), chips=2).run()
    assert d.objective == "perfcentric" and d.cap == d.selection.f_perf


def test_from_config_validation(micro_library):
    with pytest.raises(ValueError, match="unknown config keys"):
        MinosSession.from_config({"budgett": 1.0}, references=micro_library)
    with pytest.raises(ValueError, match="not both"):
        MinosSession.from_config(
            {"budget_w": 1.0, "budget_fraction_of_nameplate": 0.5,
             "devices": 1}, references=micro_library)
    with pytest.raises(ValueError, match="needs 'devices'"):
        MinosSession.from_config({"budget_fraction_of_nameplate": 0.5},
                                 references=micro_library)
    with pytest.raises(ValueError, match="unknown gate keys"):
        MinosSession.from_config({"gates": {"min_conf": 0.1}},
                                 references=micro_library)
    with pytest.raises(ValueError, match="'library'"):
        MinosSession.from_config({})
    with pytest.raises(ValueError, match="variability"):
        MinosSession.from_config({"devices": 1, "variability": 7},
                                 references=micro_library)


# ---------------------------------------------------------------------------
# handle/session edges
# ---------------------------------------------------------------------------
def test_submit_validation_and_unique_ids(micro_library):
    session = MinosSession(micro_library, **GATES)
    with pytest.raises(TypeError, match="KernelStream"):
        session.submit(42)
    meta, chunks = stream_telemetry(micro_gemm(), 1.0, MODEL,
                                    target_duration=0.5)
    with pytest.raises(ValueError, match="only apply"):
        session.submit(meta, seed=3)
    a = session.submit((meta, chunks))
    meta2, chunks2 = stream_telemetry(micro_gemm(), 1.0, MODEL,
                                      target_duration=0.5)
    b = session.submit((meta2, chunks2))          # same workload, same device
    assert a.job_id != b.job_id and b.job_id.endswith("#2")
    with pytest.raises(ValueError, match="no attached stream"):
        session.submit(meta2).run()
    with pytest.raises(ValueError, match="no inventory"):
        session._resolve_device("tpu-v5e/000")


def test_inventory_round_robin_and_device_lookup(micro_library):
    inv = DeviceInventory.generate(2, VariabilityModel.none(), seed=0)
    session = MinosSession(micro_library, inventory=inv, **GATES)
    handles = [session.submit(stream_telemetry(
        micro_gemm(), 1.0, MODEL, seed=i, target_duration=0.5))
        for i in range(3)]
    assert [h.device.device_id for h in handles] == \
        ["tpu-v5e/000", "tpu-v5e/001", "tpu-v5e/000"]
    by_id = session.submit(stream_telemetry(micro_gemm(), 1.0, MODEL,
                                            target_duration=0.5),
                           device="tpu-v5e/001")
    assert by_id.device is inv[1]
    assert math.isinf(session.budget_w)


def test_report_is_pure_and_incremental(micro_library):
    session = MinosSession(micro_library, **GATES)
    assert session.report() == session.report()
    assert session.report().n_jobs == 0
    handle = session.submit(stream_telemetry(micro_gemm(), 1.0, MODEL,
                                             seed=1, target_duration=0.5))
    assert session.report().decisions == {}       # nothing decided yet
    handle.run()
    rep = session.run()
    assert rep.n_jobs == 1 and rep.early_decisions == int(
        rep.decisions[handle.job_id].early)
    assert np.isfinite(rep.decisions[handle.job_id].cap)
