# minoslint: path=examples/quickstart.py
"""Known-good twin of ``bad_facade.py``: the facade consumes only the
public surface."""
from repro.api import MinosSession
from repro.fleet import FleetCapController


def main():
    return MinosSession, FleetCapController
