# minoslint: path=src/repro/sched/fixture_float.py
"""Known-good twin of ``bad_floatcontract.py``: tolerance-based
comparison, reference math stays in float64 (integral-valued literals
compare exactly and are allowed)."""
import math

import numpy as np


def decide(margin, trace):
    if math.isclose(margin, 0.3, rel_tol=1e-9) or margin == 0.0:
        return None
    return np.asarray(trace, dtype=np.float64)
