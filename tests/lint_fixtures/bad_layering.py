# minoslint: path=src/repro/core/fixture_layering.py
"""Known-bad W401/W403 fixture: ``core`` reaching up into ``api`` (the
north-star edge the DAG forbids) and into the frozen legacy surface."""
from repro.api import MinosSession          # W401: core -> api
from repro.legacy import simulate_workload  # W403 (and not core's edge)


def helper():
    return MinosSession, simulate_workload
