# minoslint: path=src/repro/store/fixture_writeahead.py
"""Known-bad W101 fixture: the mutation lands BEFORE the journal call, so
a crash in between loses state the journal never saw."""


class BrokenController:
    def __init__(self, journal):
        self.journal = journal
        self.jobs = {}

    def admit(self, job_id, spec):
        self.jobs[job_id] = spec            # W101: mutate-then-journal
        self.journal.append("admit", {"job_id": job_id})

    def retire(self, job_id):
        del self.jobs[job_id]               # W101: never journaled at all
