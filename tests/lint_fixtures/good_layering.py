# minoslint: path=src/repro/core/fixture_layering.py
"""Known-good twin of ``bad_layering.py``: ``core`` stays on its declared
DAG edges (kernels, pipeline)."""
from repro.kernels import spikes            # allowed: core -> kernels


def helper():
    return spikes
