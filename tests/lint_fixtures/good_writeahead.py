# minoslint: path=src/repro/store/fixture_writeahead.py
"""Known-good twin of ``bad_writeahead.py``: every mutation is dominated
by the journal call — write-ahead, crash-safe."""


class Controller:
    def __init__(self, journal):
        self.journal = journal
        self.jobs = {}

    def admit(self, job_id, spec):
        self.journal.append("admit", {"job_id": job_id})
        self.jobs[job_id] = spec

    def retire(self, job_id):
        self.journal.append("retire", {"job_id": job_id})
        del self.jobs[job_id]
