# minoslint: path=src/repro/store/fixture_kinds.py
"""Known-bad W201/W202/W203 fixture: one emitter produces a kind the
dispatch never handles (and the registry never registered), and the
dispatch keeps a handler for a kind nothing emits."""

ADMIT = "admit"
RETIRE = "retire"
ALL_KINDS = frozenset({ADMIT, RETIRE})


class Session:
    def submit(self, job_id):
        self._journal("admit", job_id=job_id)
        self._journal("orphan", job_id=job_id)   # W201 + W203

    def _apply_record(self, rec):
        match rec.kind:
            case "admit":
                pass
            case "retire":                       # W202: nothing emits it
                pass
