# minoslint: path=src/repro/pipeline/fixture_determinism.py
"""Known-good twin of ``bad_determinism.py``: timestamps flow in as
parameters, RNG is explicitly seeded, set output is sorted, and keys are
stable identities."""
import numpy as np


def stamp(profiles, started: float, seed: int):
    rng = np.random.default_rng(seed)
    jitter = rng.random(len(profiles))
    names = sorted({p.name for p in profiles})
    order = {}
    for i, p in enumerate(profiles):
        order[p.name] = i
    return started, jitter, names, order
