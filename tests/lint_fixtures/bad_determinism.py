# minoslint: path=src/repro/pipeline/fixture_determinism.py
"""Known-bad W301-W304 fixture: every classic determinism leak in one
pinned-module snippet."""
import random
import time

import numpy as np


def stamp(profiles):
    started = time.time()                       # W301
    jitter = np.random.rand(len(profiles))      # W302
    shuffled = random.random()                  # W302
    names = list({p.name for p in profiles})    # W303
    order = {}
    for i, p in enumerate(profiles):
        order[id(p)] = i                        # W304
    return started, jitter, shuffled, names, order
