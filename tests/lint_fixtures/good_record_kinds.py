# minoslint: path=src/repro/store/fixture_kinds.py
"""Known-good twin of ``bad_record_kinds.py``: emitted == handled ==
registered."""

ADMIT = "admit"
RETIRE = "retire"
ALL_KINDS = frozenset({ADMIT, RETIRE})


class Session:
    def submit(self, job_id):
        self._journal("admit", job_id=job_id)

    def retire(self, job_id):
        self._journal("retire", job_id=job_id)

    def _apply_record(self, rec):
        match rec.kind:
            case "admit":
                pass
            case "retire":
                pass
