# minoslint: path=examples/quickstart.py
"""Known-bad W402 fixture: a facade file importing past the public
``repro.api`` / ``repro.fleet`` surface."""
from repro.api import MinosSession          # fine
from repro.store.journal import EventJournal  # W402: deep import


def main():
    return MinosSession, EventJournal
