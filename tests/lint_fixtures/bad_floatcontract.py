# minoslint: path=src/repro/sched/fixture_float.py
"""Known-bad W501/W502 fixture: exact equality against a non-integral
float literal, and a float32 downcast in a float64 reference module."""
import numpy as np


def decide(margin, trace):
    if margin == 0.3:                       # W501
        return None
    return np.asarray(trace, dtype=np.float32)  # W502
