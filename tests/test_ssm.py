"""Mamba block: full-sequence scan vs token-by-token decode; kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import ParamStore, SMOKE_TOPO
from repro.models.ssm import MambaBlock, ssm_chunk_scan


def _block(d=64, di=128, ds=8, dr=8, chunk=16):
    blk = MambaBlock("m", d_model=d, d_inner=di, d_state=ds, d_conv=4,
                     dt_rank=dr, chunk=chunk)
    store = ParamStore()
    blk.register(store)
    params = store.init(jax.random.key(0))
    return blk, params["m"]


def test_fullseq_vs_decode_consistency():
    blk, p = _block()
    b, s = 2, 48
    x = jax.random.normal(jax.random.key(1), (b, s, 64), jnp.float32) * 0.5
    out_full, (state, conv_tail) = blk(p, x, None, SMOKE_TOPO, return_state=True)
    # replay the same sequence token by token
    st = jnp.zeros((b, 128, 8), jnp.float32)
    cv = jnp.zeros((b, 3, 128), jnp.float32)
    outs = []
    for t in range(s):
        o, (st, cv) = blk.decode(p, x[:, t], t, st, cv, SMOKE_TOPO)
        outs.append(o)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(out_full),
                               rtol=2e-3, atol=2e-3)
    # final states agree
    np.testing.assert_allclose(np.asarray(st), np.asarray(state),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(cv),
                               np.asarray(conv_tail.astype(jnp.float32)),
                               rtol=2e-3, atol=2e-3)


def test_chunk_scan_matches_unchunked():
    b, s, di, ds = 1, 32, 16, 4
    keys = jax.random.split(jax.random.key(2), 2)
    a = jnp.exp(-jax.random.uniform(keys[0], (b, s, di, ds)))
    u = jax.random.normal(keys[1], (b, s, di, ds)) * 0.1
    h0 = jnp.zeros((b, di, ds))
    hs, h_last = ssm_chunk_scan(a, u, h0)
    # sequential reference
    h = h0
    want = []
    for t in range(s):
        h = a[:, t] * h + u[:, t]
        want.append(h)
    want = jnp.stack(want, 1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(want), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(want[:, -1]),
                               rtol=1e-5, atol=1e-6)
    # chunk boundary invariance via the block
    blk16, p = _block(chunk=16)
    blk8, _ = _block(chunk=8)
    x = jax.random.normal(jax.random.key(3), (1, 32, 64), jnp.float32) * 0.3
    o16 = blk16(p, x, None, SMOKE_TOPO)
    o8 = blk8(p, x, None, SMOKE_TOPO)
    np.testing.assert_allclose(np.asarray(o16), np.asarray(o8), rtol=2e-3, atol=2e-3)
