"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, ref, rmsnorm, spike_hist, ssm_scan
from repro.core import spikes as core_spikes


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,sq,skv,H,KV,dh,causal", [
    (1, 128, 128, 4, 4, 64, True),      # MHA causal
    (2, 128, 128, 8, 2, 64, True),      # GQA 4:1
    (2, 64, 256, 8, 8, 128, False),     # cross-ish, bidirectional
    (1, 256, 256, 16, 2, 128, True),    # MQA-ish wide
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, sq, skv, H, KV, dh, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(b * sq + H), 3)
    q = jax.random.normal(k1, (b, sq, H, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(k2, (b, skv, KV, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(k3, (b, skv, KV, dh), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,s,di,ds,bs,bd", [
    (1, 64, 128, 8, 16, 128),
    (2, 128, 256, 16, 64, 128),
    (1, 96, 384, 16, 32, 384),          # non-pow2 seq blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(b, s, di, ds, bs, bd, dtype):
    keys = jax.random.split(jax.random.key(s + di), 6)
    x = (jax.random.normal(keys[0], (b, s, di)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, di)) * 0.2 - 1).astype(dtype)
    A = -jnp.exp(jax.random.normal(keys[2], (di, ds)) * 0.3)
    B = (jax.random.normal(keys[3], (b, s, ds)) * 0.5).astype(dtype)
    C = (jax.random.normal(keys[4], (b, s, ds)) * 0.5).astype(dtype)
    D = jnp.ones((di,))
    y = ssm_scan(x, dt, A, B, C, D, block_s=bs, block_d=bd)
    want, _ = ref.ssm_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               **(_tol(dtype) if dtype == jnp.bfloat16
                                  else dict(rtol=2e-4, atol=2e-4)))


@pytest.mark.parametrize("n,n_bins", [(100, 15), (5000, 15), (4096, 30),
                                      (777, 6)])
def test_spike_hist_sweep(n, n_bins):
    key = jax.random.key(n)
    p = jax.random.uniform(key, (n,), jnp.float32, 0.0, 2.3) * 200.0
    v = spike_hist(p, 200.0, n_bins=n_bins)
    counts = ref.spike_hist_ref(p / 200.0, n_bins)
    want = counts / jnp.maximum(jnp.sum(counts), 1)
    np.testing.assert_allclose(np.asarray(v), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # cross-check against the numpy implementation Minos actually uses
    c = (2.0 - 0.5) / n_bins
    v_np = core_spikes.spike_vector(np.asarray(p), 200.0, bin_size=c)
    np.testing.assert_allclose(np.asarray(v), v_np, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,d", [(8, 128), (64, 512), (100, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(n, d, dtype):
    k1, k2 = jax.random.split(jax.random.key(n + d))
    x = jax.random.normal(k1, (n, d), jnp.float32).astype(dtype)
    sc = jax.random.normal(k2, (d,), jnp.float32)
    y = rmsnorm(x, sc)
    want = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_matches_model_chunked_path():
    """Pallas kernel vs the model's jnp chunked attention (both vs exact)."""
    from repro.models.attention import chunked_attention
    from repro.models.common import SMOKE_TOPO
    b, s, H, KV, dh = 1, 128, 4, 2, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, KV, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, KV, dh), jnp.float32)
    pos = jnp.arange(s, dtype=jnp.int32)
    o_model = chunked_attention(q * (dh ** 0.5) / (dh ** 0.5), k, v, causal=True,
                                q_positions=pos, kv_positions=pos,
                                topo=SMOKE_TOPO, heads_sharded=False)
    o_kernel = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("jobs,n,n_bins,c", [(1, 100, 15, 0.1),
                                             (13, 300, 10, 0.15),
                                             (9, 1000, 30, 0.05),
                                             (32, 257, 3, 0.5)])
def test_spike_hist_batch_sweep(jobs, n, n_bins, c):
    """Batched (jobs x samples) histogram kernel == per-row f32 binning;
    -inf padding/masking never counted (the ragged-commit mask contract)."""
    from repro.kernels.spike_hist import spike_hist_batch_pallas
    rng = np.random.default_rng(jobs * 1000 + n)
    r = rng.uniform(0.0, 2.5, size=(jobs, n)).astype(np.float32)
    r = np.where(rng.random((jobs, n)) < 0.8, r, -np.inf).astype(np.float32)
    got = np.asarray(spike_hist_batch_pallas(jnp.asarray(r), n_bins, lo=0.5,
                                             bin_width=c, interpret=True))
    want = np.zeros((jobs, n_bins), np.float32)
    for i in range(jobs):
        row = r[i][r[i] >= 0.5]
        idx = np.floor((row - np.float32(0.5)) / np.float32(c)) \
            .astype(np.int32)
        want[i] = np.bincount(np.minimum(idx, n_bins - 1),
                              minlength=n_bins).astype(np.float32)
    np.testing.assert_array_equal(got, want)
