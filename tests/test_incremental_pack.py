"""Incremental packing + bulk admission acceptance (ISSUE 8).

The contracts pinned here:

  * **incremental ≡ full** — an ``IncrementalPacker`` driven by ANY
    interleaving of insert / remove / replace / budget changes produces a
    ``ScheduleResult`` byte-identical to ``PowerAwareScheduler.pack`` over
    the same live population (hypothesis property, the tentpole's
    correctness bar);
  * **fleet equivalence** — a controller on the incremental path reaches
    the same decisions, plans, and repack accounting as one degraded to
    full re-packs, and the repack history stays readable (lazy
    materialization: latest entry is a full ``ScheduleResult``, superseded
    entries collapse to ``RepackStats``);
  * **submit_many ≡ sequential submit** — identical job ids, placements,
    decisions, and resume behavior (zero classifier calls), with the whole
    batch rejected atomically on a bad entry;
  * the satellites: journal segment rotation (continuous seqs, live-only
    torn-tail truncation, sealed-damage quarantine, rotation mid-batch)
    and the fingerprint-keyed columnar report cache.
"""
import math
import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (DeviceInventory, EventJournal, IncrementalPacker,
                       JobPlan, MinosSession, PowerAwareScheduler,
                       ReferenceLibrary, RepackStats, ScheduleResult,
                       SessionStore, TPUPowerModel, VariabilityModel,
                       count_classifier_calls, micro_gemm, micro_idle_burst,
                       micro_spmv_memory, micro_stencil, store_report,
                       stream_profile_workload, stream_telemetry, to_dict,
                       windowed_report)
from repro.store.journal import JOURNAL_FILE

MODEL = TPUPowerModel()
TDP = MODEL.spec.tdp_w
FREQS = (0.6, 0.8, 1.0)
GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)

# pack() never touches the classifier; a bare scheduler is a pure packer
SCHED = PowerAwareScheduler(None, 100.0)


@pytest.fixture(scope="module")
def micro_library():
    return ReferenceLibrary(
        (stream_profile_workload(s, MODEL, FREQS, TDP, seed=i,
                                 target_duration=0.5)
         for i, s in enumerate([micro_gemm(), micro_idle_burst(),
                                micro_spmv_memory(), micro_stencil()])),
        built_on="tpu-v5e")


def _inventory(spec=None, seed=7):
    return DeviceInventory.generate(spec or {"tpu-v5e": 3, "tpu-v5p": 2},
                                    VariabilityModel(), seed=seed)


def _telemetry(stream, seed):
    meta, chunks = stream_telemetry(stream, 1.0, MODEL, seed=seed,
                                    target_duration=0.5)
    return meta, list(chunks)          # re-iterable: shareable across runs


def _plan(p90, chips=1, name="w", job_id="", nameplate_w=150.0):
    return JobPlan(name, chips, 1.0, p90, None, nameplate_w=nameplate_w,
                   job_id=job_id)


def _fleet_state(session) -> dict:
    fleet = session._fleet
    return {
        "job_ids": sorted(fleet.jobs),
        "decisions": {jid: to_dict(j.decision) for jid, j in
                      fleet.jobs.items() if j.decision is not None},
        "plans": {jid: to_dict(j.plan) for jid, j in fleet.jobs.items()
                  if j.plan is not None},
        "rr": session._rr,
    }


# ---------------------------------------------------------------------------
# tentpole: incremental ≡ full FFD pack, property-pinned
# ---------------------------------------------------------------------------
_P90 = st.one_of(
    st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
              allow_infinity=False),
    st.sampled_from([0.0, 1.0, 96.0, 96.0, 100.0, 250.0, 0.1 + 0.2]))
_BUDGET = st.one_of(
    st.floats(min_value=-10.0, max_value=2000.0, allow_nan=False,
              allow_infinity=False),
    st.sampled_from([0.0, -0.0, math.inf, -math.inf, math.nan]))
_OPS = st.lists(
    st.one_of(
        st.tuples(st.just("insert"), _P90, st.integers(1, 8)),
        st.tuples(st.just("remove"), st.integers(0, 10 ** 6)),
        st.tuples(st.just("replace"), st.integers(0, 10 ** 6), _P90),
        st.tuples(st.just("budget"), _BUDGET),
    ), min_size=1, max_size=50)


@settings(max_examples=80, deadline=None)
@given(_OPS, st.sampled_from([8, 16, 128]))
def test_incremental_matches_full_pack_under_any_interleaving(ops, bs):
    """Property: after EVERY mutation the maintained placement equals a
    from-scratch ``pack()`` — same placed plans in the same order, same
    deferred names.  Names repeat so FFD ties are exercised; job_ids stay
    unique (the fleet invariant the packer requires)."""
    packer = IncrementalPacker(budget_w=500.0, block_size=bs)
    live, counter = [], 0
    for op in ops:
        if op[0] == "insert":
            plan = _plan(op[1], op[2], name=f"w{counter % 3}",
                         job_id=f"j{counter}")
            counter += 1
            packer.insert(plan)
            live.append(plan)
        elif op[0] == "remove":
            if not live:
                continue
            packer.remove(live.pop(op[1] % len(live)))
        elif op[0] == "replace":
            if not live:
                continue
            i = op[1] % len(live)
            old = live[i]
            new = _plan(op[2], old.chips, name=old.name, job_id=old.job_id)
            packer.replace(old, new)
            live[i] = new
        else:
            packer.set_budget(op[1])
        ref = SCHED.pack(live, packer.budget_w)
        got = packer.result()
        assert [p.job_id for p in got.placed] \
            == [p.job_id for p in ref.placed]
        assert got.deferred == ref.deferred
        assert len(packer) == len(live)
        stats = packer.stats()
        assert stats.planned_power_w \
            == pytest.approx(ref.planned_power_w, rel=1e-12, abs=1e-9)
        assert stats.nameplate_power_w \
            == pytest.approx(ref.nameplate_power_w, rel=1e-12, abs=1e-9)


def test_packer_rejects_unpackable_plans():
    packer = IncrementalPacker(budget_w=100.0)
    plan = _plan(40.0, job_id="a")
    packer.insert(plan)
    with pytest.raises(ValueError, match="duplicate packing key"):
        packer.insert(_plan(40.0, job_id="a"))
    with pytest.raises(ValueError, match="finite power terms"):
        packer.insert(_plan(math.inf, job_id="b"))
    with pytest.raises(KeyError, match="not packed"):
        packer.remove(_plan(40.0, job_id="ghost"))
    assert len(packer) == 1                 # failed mutations change nothing
    assert [p.job_id for p in packer.result().placed] == ["a"]
    # budget flips that cannot change admissions skip the re-flow entirely
    v = packer.version
    packer.set_budget(100.0)
    assert packer.version == v


# ---------------------------------------------------------------------------
# fleet equivalence: incremental path vs full re-packs, lazy history
# ---------------------------------------------------------------------------
def _drive(session):
    a = session.submit(_telemetry(micro_gemm(), 100), chips=4)
    a.run()
    session.submit(_telemetry(micro_spmv_memory(), 101), chips=2)
    session.submit(_telemetry(micro_stencil(), 102), chips=1)
    session.set_budget(5000.0)
    session.run()
    session.fail_device(a.device.device_id)
    session.retire(a.job_id)
    return session


def test_incremental_fleet_matches_full_packs(micro_library):
    inc = MinosSession(micro_library, inventory=_inventory(),
                       budget_w=20000.0, **GATES)
    full = MinosSession(micro_library, inventory=_inventory(),
                        budget_w=20000.0, **GATES)
    full._fleet._packer = None      # the documented full-re-pack fallback
    _drive(inc)
    _drive(full)
    assert _fleet_state(inc) == _fleet_state(full)
    ri, rf = inc._fleet.repacks, full._fleet.repacks
    assert len(ri) == len(rf) > 0
    for a, b in zip(ri, rf):
        assert a.budget_w == b.budget_w
        assert a.planned_power_w == pytest.approx(b.planned_power_w,
                                                  rel=1e-12, abs=1e-9)
    # the latest pack is fully materialized on both paths: same placement
    assert [p.job_id for p in ri[-1].placed] \
        == [p.job_id for p in rf[-1].placed]
    assert ri[-1].deferred == rf[-1].deferred


def test_repack_history_materializes_lazily(micro_library):
    session = MinosSession(micro_library, inventory=_inventory(),
                           budget_w=20000.0, **GATES)
    session.submit(_telemetry(micro_gemm(), 100), chips=4).run()
    session.set_budget(5000.0)
    repacks = session._fleet.repacks
    assert len(repacks) >= 2
    last = repacks[-1]
    assert isinstance(last, ScheduleResult) and last.placed
    first = repacks[0]                       # superseded by the budget change
    assert isinstance(first, RepackStats)
    with pytest.raises(AttributeError, match="superseded"):
        first.placed
    assert first.headroom_reclaimed_w \
        == first.nameplate_power_w - first.planned_power_w
    # iteration and slicing resolve entries like indexing does
    assert [r.budget_w for r in repacks][-1] == 5000.0
    assert isinstance(repacks[-1:][0], ScheduleResult)
    assert session._fleet.repack_s >= 0.0


# ---------------------------------------------------------------------------
# bulk admission: submit_many ≡ sequential submit
# ---------------------------------------------------------------------------
def _sources():
    specs = [(micro_gemm(), 4, 100), (micro_spmv_memory(), 2, 101),
             (micro_stencil(), 1, 102), (micro_gemm(), 2, 103)]
    return [(_telemetry(s, seed), c) for s, c, seed in specs]


def test_submit_many_equals_sequential_submit(micro_library):
    srcs = _sources()
    seq = MinosSession(micro_library, inventory=_inventory(),
                       budget_w=20000.0, **GATES)
    bulk = MinosSession(micro_library, inventory=_inventory(),
                        budget_w=20000.0, **GATES)
    hs = [seq.submit(s, chips=c) for s, c in srcs]
    hb = bulk.submit_many([s for s, _ in srcs], chips=[c for _, c in srcs])
    assert [h.job_id for h in hb] == [h.job_id for h in hs]
    assert [h.device.device_id for h in hb] \
        == [h.device.device_id for h in hs]
    seq.run()
    bulk.run()
    assert _fleet_state(bulk) == _fleet_state(seq)


def test_submit_many_deduplicates_auto_ids(micro_library):
    session = MinosSession(micro_library,
                           inventory=_inventory({"tpu-v5e": 1}, seed=3),
                           budget_w=20000.0, **GATES)
    src_a, src_b = _telemetry(micro_gemm(), 100), _telemetry(micro_gemm(),
                                                             104)
    handles = session.submit_many([src_a, src_b])
    assert handles[1].job_id == f"{handles[0].job_id}#2"


def test_submit_many_rejects_batch_atomically(micro_library):
    session = MinosSession(micro_library, inventory=_inventory(),
                           budget_w=20000.0, **GATES)
    srcs = [s for s, _ in _sources()[:2]]
    with pytest.raises(ValueError, match="duplicate job_id"):
        session.submit_many(srcs, job_ids=["x", "x"])
    assert not session._fleet.jobs and not session.jobs


def test_submit_many_resume_equivalence(micro_library, tmp_path):
    """Bulk-admitted sessions journal the same durable truth: resume
    reconstructs every decision and plan with zero classifier calls."""
    srcs = _sources()
    path = str(tmp_path / "bulk")
    session = MinosSession(micro_library, inventory=_inventory(),
                           budget_w=20000.0, store=path, **GATES)
    session.submit_many([s for s, _ in srcs], chips=[c for _, c in srcs])
    session.run()
    expected = _fleet_state(session)
    session.close()
    clf = micro_library.classifier()
    calls = count_classifier_calls(clf)
    resumed = MinosSession.resume(path, references=clf)
    assert calls["n"] == 0
    assert _fleet_state(resumed) == expected
    resumed.close()


# ---------------------------------------------------------------------------
# satellite: journal segment rotation
# ---------------------------------------------------------------------------
def test_rotation_rolls_segments_with_continuous_seqs(tmp_path):
    jp = str(tmp_path / JOURNAL_FILE)
    journal = EventJournal(jp, rotate_every=3)
    for i in range(10):
        journal.append("tick", {"i": i})
    journal.close()
    assert [k for k, _ in EventJournal.segments(jp)] == [1, 2, 3]
    records, _ = EventJournal.recover(jp)
    assert [r.seq for r in records] == list(range(1, 11))
    assert [r.data["i"] for r in records] == list(range(10))
    # reopening keeps rotating where it left off (live file has 1 record)
    journal2, recovered = EventJournal.open_existing(jp, rotate_every=3)
    assert len(recovered) == 10
    for i in range(10, 14):
        journal2.append("tick", {"i": i})
    journal2.close()
    assert [k for k, _ in EventJournal.segments(jp)] == [1, 2, 3, 4]
    records2, _ = EventJournal.recover(jp)
    assert [r.data["i"] for r in records2] == list(range(14))


def test_rotation_torn_tail_truncates_live_segment_only(tmp_path):
    jp = str(tmp_path / JOURNAL_FILE)
    journal = EventJournal(jp, rotate_every=3)
    for i in range(7):
        journal.append("tick", {"i": i})
    journal.close()
    sealed_sizes = {seg: os.path.getsize(seg)
                    for _, seg in EventJournal.segments(jp)}
    with open(jp, "ab") as f:
        f.write(b'{"seq": 8, "ts": 0.0, "ki')          # torn live tail
    with pytest.warns(RuntimeWarning, match="torn"):
        journal2, recovered = EventJournal.open_existing(jp, rotate_every=3)
    journal2.close()
    assert [r.data["i"] for r in recovered] == list(range(7))
    for seg, size in sealed_sizes.items():             # sealed = untouched
        assert os.path.getsize(seg) == size


def test_sealed_segment_damage_quarantines_suffix(tmp_path):
    jp = str(tmp_path / JOURNAL_FILE)
    journal = EventJournal(jp, rotate_every=2)
    for i in range(7):
        journal.append("tick", {"i": i})
    journal.close()                    # segments 1..3 (recs 1-6), live rec 7
    seg2 = EventJournal.segment_path(jp, 2)
    seg3 = EventJournal.segment_path(jp, 3)
    with open(seg2, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    with open(seg2, "wb") as f:        # corrupt segment 2's second record
        f.writelines([lines[0], lines[1].replace(b'"kind"', b'"kinX"', 1)])
    with pytest.warns(RuntimeWarning):
        records, good = EventJournal.recover(jp)
    assert [r.seq for r in records] == [1, 2, 3]       # stops at the wound
    assert good == 0                   # live file unreachable: no append pt
    with pytest.warns(RuntimeWarning):
        journal2, recovered = EventJournal.open_existing(jp, rotate_every=2)
    assert [r.seq for r in recovered] == [1, 2, 3]
    # the unreachable suffix is quarantined, never deleted
    assert os.path.exists(seg3 + ".corrupt")
    assert os.path.exists(jp + ".corrupt")
    assert not os.path.exists(seg3)
    # the truncated damaged segment is the live file again; appends resume
    assert [k for k, _ in EventJournal.segments(jp)] == [1]
    assert journal2.append("tick", {"i": 99}) == 4
    journal2.close()
    records2, _ = EventJournal.recover(jp)
    assert [r.seq for r in records2] == [1, 2, 3, 4]


def test_rotation_mid_batch_seals_complete_segments(tmp_path):
    jp = str(tmp_path / JOURNAL_FILE)
    journal = EventJournal(jp, rotate_every=2)
    with journal.batch():
        for i in range(5):
            journal.append("tick", {"i": i})
        segs = EventJournal.segments(jp)
        assert [k for k, _ in segs] == [1, 2]
        for _, seg in segs:            # sealed mid-batch, yet complete
            with open(seg, "rb") as f:
                raw = f.read()
            assert raw.endswith(b"\n") and raw.count(b"\n") == 2
    journal.close()
    records, _ = EventJournal.recover(jp)
    assert [r.data["i"] for r in records] == list(range(5))


def test_session_store_rotation_roundtrip(tmp_path):
    """SessionStore passes rotate_every through — including the edge where
    rotation leaves no live file at close (8 records, rotate every 4)."""
    path = str(tmp_path / "s")
    store = SessionStore.create(path, rotate_every=4)
    for i in range(8):
        store.record("tick", i=i)
    store.close()
    assert not os.path.exists(os.path.join(path, JOURNAL_FILE))
    reopened = SessionStore.open_existing(path, rotate_every=4)
    assert [r.data["i"] for r in reopened.recovered_records] \
        == list(range(8))
    assert reopened.record("tick", i=8) == 9
    reopened.close()


# ---------------------------------------------------------------------------
# satellite: fingerprint-keyed columnar report cache
# ---------------------------------------------------------------------------
def _spy_recover(monkeypatch):
    real, calls = EventJournal.recover, {"n": 0}

    def spy(cls, path):
        calls["n"] += 1
        return real(path)

    monkeypatch.setattr(EventJournal, "recover", classmethod(spy))
    return calls


def test_store_report_parses_once_until_journal_changes(tmp_path,
                                                        monkeypatch):
    path = str(tmp_path / "s")
    store = SessionStore.create(path, rotate_every=2)
    store.record("open", budget_w=900.0)
    store.record("admit", job_id="a")
    store.record("decision", job_id="a",
                 plan={"job_id": "a", "predicted_p90_w": 123.0})
    calls = _spy_recover(monkeypatch)
    first = store_report(path, window_s=3600.0)
    rewindowed = store_report(path, window_s=60.0)     # served from cache
    assert calls["n"] == 1
    assert sum(w["admits"] for w in rewindowed) == 1
    assert first[-1]["planned_w"] == 123.0
    assert first[-1]["budget_w"] == 900.0
    # reports agree with the uncached aggregation over the same records
    assert first == windowed_report(EventJournal.recover.__func__(
        EventJournal, os.path.join(path, JOURNAL_FILE))[0],
        window_s=3600.0)
    assert calls["n"] == 2                             # the explicit call
    store.record("retire", job_id="a")                 # append -> new print
    invalidated = store_report(path, window_s=3600.0)
    assert calls["n"] == 3
    assert sum(w["retires"] for w in invalidated) == 1
    store.close()
