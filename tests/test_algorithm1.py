"""Algorithm 1 (SELECT_OPTIMAL_FREQ) unit + property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.algorithm1 import (cap_perf_centric, cap_power_centric,
                                   choose_bin_size, profiling_savings,
                                   select_optimal_freq)
from repro.core.classify import FreqPoint, MinosClassifier, WorkloadProfile

TDP = 200.0
FREQS = [0.6, 0.7, 0.8, 0.9, 1.0]


def _profile(name, p90_by_freq, time_by_freq, trace_level, sm=0.9, dram=0.2):
    rng = np.random.default_rng(hash(name) % 2**31)
    trace = rng.normal(trace_level * TDP, 6.0, 600)
    scaling = {
        f: FreqPoint(freq=f, p90=p90_by_freq[f], p95=p90_by_freq[f] + 0.03,
                     p99=p90_by_freq[f] + 0.07, mean_power=p90_by_freq[f] - 0.1,
                     exec_time=time_by_freq[f])
        for f in FREQS
    }
    return WorkloadProfile(name=name, tdp=TDP, power_trace=trace,
                           sm_util=sm, dram_util=dram, exec_time=time_by_freq[1.0],
                           scaling=scaling)


def _compute_bound(name="compute", level=1.3):
    # p90 scales with frequency; time scales inversely
    return _profile(
        name,
        {f: level * f for f in FREQS},
        {f: 1.0 / f for f in FREQS},
        trace_level=level, sm=0.95, dram=0.15)


def _memory_bound(name="memory", level=0.7):
    return _profile(
        name,
        {f: level for f in FREQS},
        {f: 1.0 for f in FREQS},
        trace_level=level, sm=0.1, dram=0.9)


def test_cap_power_centric_highest_freq_meeting_bound():
    prof = _compute_bound()
    # p90(f) = 1.3 f < 1.3 -> any f < 1.0; highest available below = 0.9
    assert cap_power_centric(prof, bound=1.3) == 0.9
    assert cap_power_centric(prof, bound=2.0) == 1.0
    # impossible bound -> lowest frequency
    assert cap_power_centric(prof, bound=0.1) == 0.6


def test_cap_perf_centric_lowest_freq_within_bound():
    prof = _compute_bound()
    # degradation(f) = 1/f - 1 <= 0.05 -> f >= 0.952 -> lowest such = 1.0
    assert cap_perf_centric(prof, bound=0.05) == 1.0
    # memory-bound: no degradation anywhere -> lowest freq
    assert cap_perf_centric(_memory_bound(), bound=0.05) == 0.6


@given(st.floats(0.5, 2.0))
@settings(max_examples=30, deadline=None)
def test_cap_power_monotone_in_bound(bound):
    prof = _compute_bound()
    f1 = cap_power_centric(prof, bound=bound)
    f2 = cap_power_centric(prof, bound=bound + 0.2)
    assert f2 >= f1      # looser bound can only allow higher frequency


def test_neighbors_and_selection():
    refs = [_compute_bound("gemm-ref", 1.3), _memory_bound("spmv-ref", 0.7),
            _profile("hybrid-ref", {f: 0.9 + 0.3 * f for f in FREQS},
                     {f: 1 / (0.5 + 0.5 * f) for f in FREQS}, 1.1, 0.5, 0.5)]
    clf = MinosClassifier(refs)
    target = _compute_bound("new-gemm", 1.28)
    sel = select_optimal_freq(target, clf)
    assert sel.power_neighbor == "gemm-ref"
    assert sel.util_neighbor == "gemm-ref"
    assert sel.f_pwr == cap_power_centric(refs[0])
    assert sel.f_perf == cap_perf_centric(refs[0])


def test_choose_bin_size_returns_candidate():
    refs = [_compute_bound("a", 1.3), _memory_bound("b", 0.7)]
    clf = MinosClassifier(refs)
    c = choose_bin_size(_compute_bound("t", 1.25), clf, (0.05, 0.1, 0.25))
    assert c in (0.05, 0.1, 0.25)


def test_profiling_savings_matches_paper_formula():
    prof = _compute_bound()
    # sum of 1/f for FREQS; single profile at f0=1.0 costs 1.0
    total = sum(1.0 / f for f in FREQS)
    assert profiling_savings(prof, FREQS) == pytest.approx(1 - 1.0 / total)
    # 9-freq sweep like the paper -> ~89-90% savings
    freqs9 = [0.6 + 0.05 * i for i in range(9)]
    prof9 = _profile("x", {round(f, 2): 1.0 for f in freqs9},
                     {round(f, 2): 1.0 / f for f in freqs9}, 1.0)
    s = profiling_savings(prof9, [round(f, 2) for f in freqs9])
    # pure compute-bound lower bound is 1 - 1/sum(1/f) ~= 0.845; partially
    # memory-bound workloads approach 1 - 1/9 ~= 0.889 (the paper's 89-90%)
    assert 0.84 < s < 0.90
