"""Unit + property tests for the paper's §4.1/§5.3.1 trace pipeline."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spikes

TDP = 200.0


def test_ema_alpha_half_is_successive_average():
    x = np.array([0.0, 10.0, 20.0, 30.0])
    y = spikes.ema_filter(x, alpha=0.5)
    # paper: P_filt(t) = (P(t) + P_filt(t-1)) / 2
    assert y[0] == 0.0
    assert y[1] == 5.0
    assert y[2] == 12.5


def test_trim_idle():
    p = np.arange(10.0)
    busy = np.array([0, 0, 1, 1, 0, 1, 0, 0, 0, 0])
    out = spikes.trim_idle(p, busy)
    np.testing.assert_array_equal(out, p[2:6])
    assert len(spikes.trim_idle(p, np.zeros(10))) == 0


def test_spike_vector_basic():
    # samples at 0.55, 0.55, 1.25 x TDP plus sub-threshold ones
    p = np.array([0.1, 0.55, 0.55, 1.25, 0.3]) * TDP
    v = spikes.spike_vector(p, TDP, bin_size=0.1)
    assert len(v) == 15
    assert v[0] == pytest.approx(2 / 3)       # [0.5, 0.6)
    assert v[7] == pytest.approx(1 / 3)       # [1.2, 1.3)
    assert v.sum() == pytest.approx(1.0)


def test_spike_vector_no_spikes_is_zero():
    p = np.full(100, 0.3) * TDP
    v = spikes.spike_vector(p, TDP)
    assert v.sum() == 0.0


@given(st.lists(st.floats(0.0, 2.5), min_size=1, max_size=500),
       st.sampled_from([0.05, 0.1, 0.15, 0.25]))
@settings(max_examples=50, deadline=None)
def test_spike_vector_properties(rel, c):
    p = np.array(rel) * TDP
    v = spikes.spike_vector(p, TDP, bin_size=c)
    n = spikes.num_bins(c)
    assert len(v) == n
    assert np.all(v >= 0)
    # normalized iff any spike exists
    if np.any(np.array(rel) >= 0.5):
        assert v.sum() == pytest.approx(1.0)
    else:
        assert v.sum() == 0.0
    # permutation invariance (a distribution, not a time series)
    rng = np.random.default_rng(0)
    v2 = spikes.spike_vector(rng.permutation(p), TDP, bin_size=c)
    np.testing.assert_allclose(v, v2)


@given(st.lists(st.floats(10.0, 500.0), min_size=2, max_size=200))
@settings(max_examples=30, deadline=None)
def test_ema_bounded_by_input_range(vals):
    x = np.array(vals)
    y = spikes.ema_filter(x, alpha=0.5)
    assert np.all(y >= x.min() - 1e-9)
    assert np.all(y <= x.max() + 1e-9)


def test_quantiles_and_mean():
    p = np.linspace(0.0, 2.0, 101) * TDP
    assert spikes.p_quantile(p, TDP, 90) == pytest.approx(1.8, abs=0.02)
    assert spikes.mean_power_rel(p, TDP) == pytest.approx(1.0, abs=0.01)


def test_power_from_energy():
    e = np.cumsum(np.full(11, 0.2))          # 0.2 J per 1 ms -> 200 W
    p = spikes.power_from_energy(e, 1e-3)
    np.testing.assert_allclose(p, 200.0)
