"""Per-architecture smoke tests (assignment deliverable f): reduced configs
of the same family, one train step + prefill->decode consistency on CPU,
asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.models import build_model, make_batch
from repro.models.common import SMOKE_TOPO

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = ARCHS[arch].reduced()
    shape = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
    m = build_model(cfg, SMOKE_TOPO, kind="train")
    params = m.init_params(jax.random.key(0))
    batch = make_batch(cfg, shape, jax.random.key(1))
    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(m.loss, has_aux=True)(p, b))(params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must match a full forward at position S —
    this crosses the prefill (megatron/fsdp_sp) and decode (row-parallel,
    seq-sharded-cache) code paths and the SSM/conv state handoff."""
    # capacity_factor high so MoE routing is batch-independent (capacity
    # drops legitimately differ between a grouped prefill and a single-token
    # decode; that's inherent to capacity-based MoE, not a bug)
    cfg = ARCHS[arch].reduced(capacity_factor=8.0)
    S = 24
    b = 2
    mp = build_model(cfg, SMOKE_TOPO, kind="prefill")
    md = build_model(cfg, SMOKE_TOPO, kind="decode")
    params = mp.init_params(jax.random.key(0))

    shape_long = ShapeConfig("smoke", seq_len=S + 1, global_batch=b, kind="prefill")
    batch_long = make_batch(cfg, shape_long, jax.random.key(1))
    batch_short = dict(batch_long)
    batch_short["tokens"] = batch_long["tokens"][:, :S]
    if "frames" in batch_long:
        batch_short["frames"] = batch_long["frames"]  # same audio memory

    logits_full, _ = jax.jit(mp.prefill)(params, batch_long)

    _, caches = jax.jit(mp.prefill)(params, batch_short)
    if cfg.is_encoder_decoder:
        structs = md.cache_shape_structs(b, S + 4,
                                         memory_len=batch_long["frames"].shape[1])
    else:
        structs = md.cache_shape_structs(b, S + 4)

    def pad(c, st):
        pads = [(0, a - bb) for a, bb in zip(st.shape, c.shape)]
        return jnp.pad(c.astype(st.dtype), pads)

    caches = jax.tree.map(pad, caches, structs)
    tok = batch_long["tokens"][:, S]
    logits_dec, _ = jax.jit(md.decode_step)(params, caches, tok, jnp.int32(S))

    a = np.asarray(logits_full, np.float32)[:, :cfg.vocab_size]
    d = np.asarray(logits_dec, np.float32)[:, :cfg.vocab_size]
    # bf16 params, two different code paths: compare top-1 + numeric closeness
    np.testing.assert_allclose(a, d, rtol=0.15, atol=0.15)
    scale = np.maximum(np.abs(a).max(), 1.0)
    assert np.max(np.abs(a - d)) / scale < 0.12


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_shapes_match_specs(arch):
    cfg = ARCHS[arch].reduced()
    m = build_model(cfg, SMOKE_TOPO, kind="train")
    shapes = m.param_shapes()
    specs = m.param_specs()
    flat_sh = jax.tree.leaves(shapes)
    import jax.sharding as js
    flat_sp = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, js.PartitionSpec))
    assert len(flat_sh) == len(flat_sp)
    params = m.init_params(jax.random.key(0))
    for a, b in zip(jax.tree.leaves(params), flat_sh):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_param_counts_near_nominal():
    # full configs should land near their nominal parameter counts
    expected = {
        "falcon-mamba-7b": 7.3e9, "glm4-9b": 9.4e9, "command-r-35b": 32.4e9,
        "phi3-medium-14b": 14.7e9, "qwen2.5-14b": 14.8e9,
        "llama-3.2-vision-11b": 10.1e9, "jamba-1.5-large-398b": 398e9,
        "deepseek-v2-236b": 244e9, "granite-moe-3b-a800m": 3.4e9,
        "whisper-medium": 0.8e9,
    }
    for name, want in expected.items():
        got = ARCHS[name].param_count()
        assert abs(got - want) / want < 0.12, (name, got, want)
