"""Streaming pipeline tests: ProfileBuilder golden equivalence + chunking
invariance, ReferenceLibrary versioning/persistence/warm-start byte-identity,
and the OnlineCapController decision gates."""
import dataclasses
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spikes
from repro.core.algorithm1 import select_optimal_freq
from repro.core.classify import FreqPoint, MinosClassifier, WorkloadProfile
from repro.pipeline import (OnlineCapController, ProfileBuilder,
                            ReferenceLibrary, classify_with_margin,
                            stream_profile_once, stream_profile_workload)
from repro.sched import SimActuator
from repro.telemetry import (TPUPowerModel, profile_once, profile_workload,
                             simulate, stream_telemetry)
from repro.telemetry.kernel_stream import (micro_gemm, micro_idle_burst,
                                           micro_spmv_memory, micro_stencil)
from repro.telemetry.simulator import TelemetryChunk, TraceMeta

MODEL = TPUPowerModel()
TDP = MODEL.spec.tdp_w
FREQS = (0.6, 0.8, 1.0)


# ---------------------------------------------------------------------------
# the retired batch assembly, frozen here as the golden reference for both
# the streaming builder and the deprecation shims that replaced it
# ---------------------------------------------------------------------------
def _batch_profile_once(stream, model, tdp, freq=1.0, seed=0,
                        target_duration=4.0):
    tr = simulate(stream, freq, model, seed=seed,
                  target_duration=target_duration)
    return WorkloadProfile(
        name=stream.name, tdp=tdp, power_trace=tr.power_filtered,
        sm_util=tr.app_sm_util, dram_util=tr.app_dram_util,
        exec_time=tr.exec_time, scaling={}, domain=stream.domain)


def _batch_profile_workload(stream, model, freqs, tdp, seed=0,
                            target_duration=4.0):
    scaling, top, top_tr = {}, max(freqs), None
    for i, f in enumerate(sorted(freqs)):
        tr = simulate(stream, f, model, seed=seed * 1009 + i,
                      target_duration=target_duration)
        scaling[f] = FreqPoint(
            freq=f, p90=spikes.p_quantile(tr.power_filtered, tdp, 90),
            p95=spikes.p_quantile(tr.power_filtered, tdp, 95),
            p99=spikes.p_quantile(tr.power_filtered, tdp, 99),
            mean_power=spikes.mean_power_rel(tr.power_filtered, tdp),
            exec_time=tr.exec_time,
            spike_vec=spikes.spike_vector(tr.power_filtered, tdp))
        if f == top:
            top_tr = tr
    return WorkloadProfile(
        name=stream.name, tdp=tdp, power_trace=top_tr.power_filtered,
        sm_util=top_tr.app_sm_util, dram_util=top_tr.app_dram_util,
        exec_time=top_tr.exec_time, scaling=scaling, domain=stream.domain)


# ---------------------------------------------------------------------------
# ProfileBuilder: golden equivalence against the batch path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stream_fn", [micro_gemm, micro_idle_burst,
                                       micro_spmv_memory])
def test_stream_profile_once_matches_batch(stream_fn):
    batch = _batch_profile_once(stream_fn(), MODEL, TDP, seed=5)
    streamed = stream_profile_once(stream_fn(), MODEL, TDP, seed=5,
                                   chunk_samples=173)
    np.testing.assert_allclose(streamed.power_trace, batch.power_trace,
                               rtol=1e-9, atol=1e-9)
    assert streamed.name == batch.name
    assert streamed.sm_util == batch.sm_util
    assert streamed.dram_util == batch.dram_util
    assert streamed.exec_time == batch.exec_time
    assert streamed.complete and streamed.fraction == 1.0


def test_stream_profile_workload_matches_batch():
    batch = _batch_profile_workload(micro_gemm(), MODEL, FREQS, TDP, seed=3,
                                    target_duration=1.0)
    streamed = stream_profile_workload(micro_gemm(), MODEL, FREQS, TDP,
                                       seed=3, target_duration=1.0)
    np.testing.assert_allclose(streamed.power_trace, batch.power_trace,
                               rtol=1e-9, atol=1e-9)
    assert set(streamed.scaling) == set(batch.scaling)
    for f in FREQS:
        a, b = streamed.scaling[f], batch.scaling[f]
        for attr in ("freq", "p90", "p95", "p99", "mean_power", "exec_time"):
            assert getattr(a, attr) == pytest.approx(getattr(b, attr),
                                                     abs=1e-9), (f, attr)
        np.testing.assert_allclose(a.spike_vec, b.spike_vec, atol=1e-9)


# ---------------------------------------------------------------------------
# deprecation shims: one implementation, pinned to the retired batch output
# ---------------------------------------------------------------------------
def test_profile_once_shim_warns_and_matches_old_output():
    with pytest.warns(DeprecationWarning, match="stream_profile_once"):
        shimmed = profile_once(micro_gemm(), MODEL, TDP, seed=5)
    old = _batch_profile_once(micro_gemm(), MODEL, TDP, seed=5)
    np.testing.assert_allclose(shimmed.power_trace, old.power_trace,
                               rtol=1e-9, atol=1e-9)
    assert (shimmed.name, shimmed.sm_util, shimmed.dram_util,
            shimmed.exec_time, shimmed.domain) == \
        (old.name, old.sm_util, old.dram_util, old.exec_time, old.domain)
    # ...and is byte-identical to the one streaming implementation
    streamed = stream_profile_once(micro_gemm(), MODEL, TDP, seed=5)
    np.testing.assert_array_equal(shimmed.power_trace, streamed.power_trace)


def test_profile_workload_shim_warns_and_matches_old_output():
    with pytest.warns(DeprecationWarning, match="stream_profile_workload"):
        shimmed = profile_workload(micro_gemm(), MODEL, FREQS, TDP, seed=3,
                                   target_duration=1.0)
    old = _batch_profile_workload(micro_gemm(), MODEL, FREQS, TDP, seed=3,
                                  target_duration=1.0)
    np.testing.assert_allclose(shimmed.power_trace, old.power_trace,
                               rtol=1e-9, atol=1e-9)
    for f in FREQS:
        a, b = shimmed.scaling[f], old.scaling[f]
        for attr in ("freq", "p90", "p95", "p99", "mean_power", "exec_time"):
            assert getattr(a, attr) == pytest.approx(getattr(b, attr),
                                                     abs=1e-9), (f, attr)
        np.testing.assert_allclose(a.spike_vec, b.spike_vec, atol=1e-9)
    streamed = stream_profile_workload(micro_gemm(), MODEL, FREQS, TDP,
                                       seed=3, target_duration=1.0)
    np.testing.assert_array_equal(shimmed.power_trace, streamed.power_trace)


def test_builder_incremental_histogram_matches_trace():
    meta, chunks = stream_telemetry(micro_idle_burst(), 1.0, MODEL, seed=2,
                                    target_duration=1.0, chunk_samples=97)
    b = ProfileBuilder(meta, TDP)
    for chunk in chunks:
        b.ingest(chunk)
    prof = b.finalize()
    for c in b.bin_sizes:
        np.testing.assert_array_equal(
            b.spike_vector(c), spikes.spike_vector(prof.power_trace, TDP, c))


def test_builder_snapshot_is_pure_and_monotone():
    meta, chunks = stream_telemetry(micro_stencil(), 1.0, MODEL, seed=4,
                                    target_duration=1.0, chunk_samples=200)
    b = ProfileBuilder(meta, TDP)
    last_n = -1
    for chunk in chunks:
        b.ingest(chunk)
        s1 = b.snapshot()
        s2 = b.snapshot()                 # snapshot must not mutate state
        np.testing.assert_array_equal(s1.power_trace, s2.power_trace)
        assert not s1.complete
        assert b.n_ingested > last_n
        last_n = b.n_ingested
    # snapshotting along the way must not have perturbed the final build
    ref = stream_profile_once(micro_stencil(), MODEL, TDP, seed=4,
                              chunk_samples=200, target_duration=1.0)
    np.testing.assert_array_equal(b.finalize().power_trace, ref.power_trace)


def test_builder_rejects_bad_streams():
    meta, chunks = stream_telemetry(micro_gemm(), 1.0, MODEL, seed=0,
                                    target_duration=1.0)
    chunk = next(iter(chunks))
    b = ProfileBuilder(meta, TDP)
    with pytest.raises(ValueError, match="expected 0"):
        b.ingest(dataclasses.replace(chunk, start_index=5))
    with pytest.raises(ValueError, match="differ in length"):
        b.ingest(dataclasses.replace(chunk, busy_s=chunk.busy_s[:-1]))
    b.ingest(chunk)
    b.finalize()
    with pytest.raises(ValueError, match="finalized"):
        b.ingest(chunk)
    with pytest.raises(ValueError, match="not tracked"):
        b.spike_vector(0.33)
    with pytest.raises(ValueError, match="chunk_samples"):
        stream_telemetry(micro_gemm(), 1.0, MODEL, chunk_samples=0)


# ---------------------------------------------------------------------------
# chunking invariance: the property the streaming design hinges on
# ---------------------------------------------------------------------------
def _synthetic_stream(seed: int, n: int):
    """Raw counter readings with idle head/tail, busy gaps, and power
    straddling every spike-bin edge."""
    rng = np.random.default_rng(seed)
    p = rng.uniform(0.0, 2.1 * TDP, n)
    de = p * 1e-3
    busy = (rng.random(n) < 0.7).astype(np.float64)
    head = rng.integers(0, n // 3 + 1)
    tail = rng.integers(0, n // 3 + 1)
    busy[:head] = 0.0
    busy[n - tail:] = 0.0
    energy_ctr = np.concatenate([[0.0], np.cumsum(de)])
    busy_ctr = np.concatenate([[0.0], np.cumsum(busy * 1e-3)])
    meta = TraceMeta(name="synthetic", domain="test", sample_dt=1e-3,
                     n_samples=n, exec_time=1.0, app_sm_util=0.5,
                     app_dram_util=0.5, kernel_rows=[])
    return meta, energy_ctr, busy_ctr


def _ingest_chunked(meta, energy_ctr, busy_ctr, cuts):
    b = ProfileBuilder(meta, TDP)
    bounds = [0] + sorted(cuts) + [meta.n_samples]
    for i, j in zip(bounds[:-1], bounds[1:]):
        if i == j:
            continue
        b.ingest(TelemetryChunk(energy_j=energy_ctr[i + 1:j + 1],
                                busy_s=busy_ctr[i + 1:j + 1],
                                sample_dt=meta.sample_dt, start_index=i))
    return b


@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=1, max_value=1500),
       st.lists(st.integers(min_value=0, max_value=1499), min_size=0,
                max_size=12))
@settings(max_examples=40, deadline=None)
def test_any_chunking_reproduces_batch_spike_vector(seed, n, raw_cuts):
    """Property: however an event stream is chunked, ProfileBuilder's spike
    vectors and trace are bit-for-bit identical to ingesting the whole
    stream as one batch chunk."""
    meta, energy_ctr, busy_ctr = _synthetic_stream(seed, n)
    cuts = [min(c, n) for c in raw_cuts]
    batch = _ingest_chunked(meta, energy_ctr, busy_ctr, [])
    chunked = _ingest_chunked(meta, energy_ctr, busy_ctr, cuts)
    for c in batch.bin_sizes:
        np.testing.assert_array_equal(chunked.spike_vector(c),
                                      batch.spike_vector(c))
    np.testing.assert_array_equal(chunked.finalize().power_trace,
                                  batch.finalize().power_trace)


@pytest.mark.parametrize("chunk_samples", [1, 7, 64, 1000, 10 ** 9])
def test_simulator_chunk_size_invariance(chunk_samples):
    ref = stream_profile_once(micro_idle_burst(), MODEL, TDP, seed=9,
                              target_duration=1.0, chunk_samples=256)
    got = stream_profile_once(micro_idle_burst(), MODEL, TDP, seed=9,
                              target_duration=1.0,
                              chunk_samples=chunk_samples)
    np.testing.assert_array_equal(got.power_trace, ref.power_trace)


# ---------------------------------------------------------------------------
# ReferenceLibrary: versioning, persistence, warm start, dedup
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_library():
    profs = [stream_profile_workload(s, MODEL, FREQS, TDP, seed=i,
                                     target_duration=0.5)
             for i, s in enumerate([micro_gemm(), micro_idle_burst(),
                                    micro_spmv_memory(), micro_stencil()])]
    return ReferenceLibrary(profs)


def test_library_add_remove_versioning(small_library):
    lib = ReferenceLibrary(small_library.profiles)
    v0 = lib.version
    M0 = lib.spike_matrix(0.1).copy()
    p = lib.remove("sgemm-25k")
    assert lib.version == v0 + 1
    assert "sgemm-25k" not in lib
    np.testing.assert_array_equal(lib.spike_matrix(0.1), M0[1:])
    lib.add(p)
    assert lib.version == v0 + 2
    np.testing.assert_array_equal(lib.spike_matrix(0.1),
                                  np.vstack([M0[1:], M0[:1]]))
    with pytest.raises(ValueError, match="duplicate"):
        lib.add(p)
    with pytest.raises(KeyError):
        lib.remove("nope")


def test_library_save_load_warm_start_byte_identical(small_library, tmp_path):
    d = str(tmp_path / "lib")
    small_library.save(d)
    loaded = ReferenceLibrary.load(d)
    assert loaded.names == small_library.names
    assert loaded.fingerprint() == small_library.fingerprint()
    for p, q in zip(small_library.profiles, loaded.profiles):
        assert q.power_trace.dtype == np.float64
        np.testing.assert_array_equal(p.power_trace, q.power_trace)
        assert list(p.scaling) == list(q.scaling)
    # warm classifier adopts the on-disk matrices; cold recomputes — the
    # matrices and every neighbor decision must be byte-identical
    warm = loaded.classifier()
    cold = MinosClassifier(loaded.profiles)
    targets = [stream_profile_once(micro_stencil(), MODEL, TDP, seed=31)]
    for c in small_library.bin_sizes:
        np.testing.assert_array_equal(warm.spike_matrix(c),
                                      cold.spike_matrix(c))
        (nw, dw), = warm.power_neighbors(targets, bin_size=c)
        (nc, dc), = cold.power_neighbors(targets, bin_size=c)
        assert nw.name == nc.name and dw == dc


def test_library_stale_cache_is_rejected(small_library, tmp_path):
    d = str(tmp_path / "lib")
    small_library.save(d)
    with open(os.path.join(d, "library.json")) as f:
        meta = json.load(f)
    meta["fingerprint"] = "stale"
    with open(os.path.join(d, "library.json"), "w") as f:
        json.dump(meta, f)
    loaded = ReferenceLibrary.load(d)
    assert loaded._spike == {}            # cache dropped, not trusted
    loaded.classifier()                   # still classifies (cold rebuild)


def test_library_subset_keeps_warm_rows(small_library):
    lib = ReferenceLibrary(small_library.profiles)
    full = lib.spike_matrix(0.1)
    sub = lib.subset(lambda p: p.name != "sgemm-25k")
    assert sub.names == [n for n in lib.names if n != "sgemm-25k"]
    np.testing.assert_array_equal(sub.spike_matrix(0.1), full[1:])


def test_library_dedup_removes_clones(small_library):
    lib = ReferenceLibrary(small_library.profiles)
    clone = dataclasses.replace(lib.profiles[0], name="clone-a")
    lib.add(clone)
    removed = lib.dedup(max_distance=1e-9)
    assert removed == ["clone-a"]
    assert lib.dedup(max_distance=1e-9) == []


# ---------------------------------------------------------------------------
# OnlineCapController
# ---------------------------------------------------------------------------
def test_classify_with_margin_bounds(small_library):
    clf = small_library.classifier()
    target = stream_profile_once(micro_stencil(), MODEL, TDP, seed=7)
    sel, conf = classify_with_margin(target, clf)
    assert 0.0 <= conf <= 1.0
    assert sel.power_neighbor == select_optimal_freq(target, clf).power_neighbor
    # a single-reference library is trivially confident
    solo = ReferenceLibrary(small_library.profiles[:1]).classifier()
    _, conf_solo = classify_with_margin(target, solo)
    assert conf_solo == 1.0


def test_controller_gates_and_early_decision(small_library):
    actuator = SimActuator()
    ctl = OnlineCapController(small_library, actuator=actuator,
                              min_confidence=0.0, min_fraction=0.3,
                              min_spike_samples=10)
    meta, chunks = stream_telemetry(micro_gemm(), 1.0, MODEL, seed=12,
                                    target_duration=1.0, chunk_samples=128)
    b = ProfileBuilder(meta, TDP)
    decision = None
    for chunk in chunks:
        b.ingest(chunk)
        decision = ctl.observe(b)
        if decision is not None:
            break
        assert b.fraction < 0.3 or b.spike_count() < 10
    assert decision is not None and decision.early
    assert decision.fraction >= 0.3
    assert actuator.get_cap() == decision.cap
    assert ctl.decisions == [decision]


def test_controller_run_falls_back_to_finalize(small_library):
    # an impossible confidence bar: the decision must come from the full
    # profile, flagged as not-early, and match the batch Algorithm 1 cap
    ctl = OnlineCapController(small_library, min_confidence=2.0)
    meta, chunks = stream_telemetry(micro_gemm(), 1.0, MODEL, seed=12,
                                    target_duration=1.0)
    decision = ctl.run(meta, chunks, TDP)
    assert not decision.early and decision.fraction == 1.0
    full = stream_profile_once(micro_gemm(), MODEL, TDP, seed=12,
                               target_duration=1.0)
    clf = small_library.classifier()
    assert decision.cap == select_optimal_freq(full, clf).f_pwr


def test_controller_rejects_bad_objective(small_library):
    with pytest.raises(ValueError, match="objective"):
        OnlineCapController(small_library, objective="fastest")
