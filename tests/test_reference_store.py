"""Regression tests for the reference-store roundtrip contract: profile
ordering, scaling-dict float keys, and dtypes must survive
``save_profiles`` -> ``load_profiles`` exactly.  The store is now a
deprecation shim over ``pipeline.ReferenceLibrary``; these tests pin both the
shim behavior (warnings included) and backward compatibility with pre-shim
float32 archives."""
import json
import os

import numpy as np
import pytest

from repro.core.classify import FreqPoint, WorkloadProfile
from repro.core.reference_store import load_profiles, save_profiles
from repro.pipeline import ReferenceLibrary

TDP = 180.0
# deliberately awkward frequency keys: only exact float-key preservation
# (via repr/float roundtrip) keeps scaling lookups working after a reload
FREQS = (0.6, 2.0 / 3.0, 0.8125, 1.0)


def _profile(name: str, level: float, seed: int) -> WorkloadProfile:
    rng = np.random.default_rng(seed)
    scaling = {
        f: FreqPoint(freq=f, p90=level * f, p95=level * f + 0.03,
                     p99=level * f + 0.07, mean_power=level * f - 0.1,
                     exec_time=1.0 / f)
        for f in FREQS
    }
    return WorkloadProfile(
        name=name, tdp=TDP, power_trace=rng.normal(level * TDP, 7.0, 321),
        sm_util=rng.random(), dram_util=rng.random(), exec_time=1.25,
        scaling=scaling, domain="test")


@pytest.fixture()
def profiles():
    # z-/a- names: ordering must come from the save order, not name sort
    return [_profile("z-gemm", 1.3, 0), _profile("a-spmv", 0.7, 1),
            _profile("m-stencil", 0.95, 2)]


def test_roundtrip_preserves_keys_dtypes_and_order(profiles, tmp_path):
    d = str(tmp_path)
    with pytest.deprecated_call():
        save_profiles(profiles, d)
    with pytest.deprecated_call():
        loaded = load_profiles(d)

    assert [p.name for p in loaded] == [p.name for p in profiles]
    for orig, got in zip(profiles, loaded):
        # scaling keys: exact floats, in insertion order
        assert list(got.scaling) == list(orig.scaling)
        for f in FREQS:
            assert f in got.scaling        # exact float key, not a repr-ish
            a, b = orig.scaling[f], got.scaling[f]
            for attr in ("freq", "p90", "p95", "p99", "mean_power",
                         "exec_time"):
                assert getattr(a, attr) == getattr(b, attr), (f, attr)
        # dtypes: float64 in, float64 out, bit-exact traces
        assert got.power_trace.dtype == np.float64
        np.testing.assert_array_equal(got.power_trace, orig.power_trace)
        assert got.tdp == orig.tdp
        assert got.sm_util == orig.sm_util
        assert got.dram_util == orig.dram_util
        assert got.exec_time == orig.exec_time
        assert got.domain == orig.domain


def test_loads_pre_shim_float32_archives(profiles, tmp_path):
    """Directories written by the pre-PR-2 store (float32 traces, no
    library.json/spike_cache.npz sidecars) must still load."""
    d = str(tmp_path)
    meta, arrays = {}, {}
    for i, p in enumerate(profiles):
        key = f"trace_{i}"
        arrays[key] = np.asarray(p.power_trace, np.float32)
        meta[p.name] = {
            "trace_key": key, "tdp": p.tdp, "sm_util": p.sm_util,
            "dram_util": p.dram_util, "exec_time": p.exec_time,
            "domain": p.domain,
            "scaling": {str(f): {
                "freq": fp.freq, "p90": fp.p90, "p95": fp.p95, "p99": fp.p99,
                "mean_power": fp.mean_power, "exec_time": fp.exec_time}
                for f, fp in p.scaling.items()},
        }
    np.savez_compressed(os.path.join(d, "traces.npz"), **arrays)
    with open(os.path.join(d, "profiles.json"), "w") as f:
        json.dump(meta, f)

    lib = ReferenceLibrary.load(d)
    assert lib.names == [p.name for p in profiles]
    assert lib._spike == {}               # no sidecars -> cold start
    for orig, got in zip(profiles, lib.profiles):
        assert got.power_trace.dtype == np.float64
        np.testing.assert_allclose(got.power_trace, orig.power_trace,
                                   rtol=1e-6, atol=1e-4)
        assert list(got.scaling) == list(orig.scaling)
    lib.classifier()                      # still classifies


def test_shim_and_library_formats_interoperate(profiles, tmp_path):
    d = str(tmp_path / "lib")
    ReferenceLibrary(profiles).save(d)
    with pytest.deprecated_call():
        loaded = load_profiles(d)          # shim reads library format
    assert [p.name for p in loaded] == [p.name for p in profiles]
    np.testing.assert_array_equal(loaded[0].power_trace,
                                  profiles[0].power_trace)
