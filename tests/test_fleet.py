"""Fleet layer tests: inventory determinism, per-device power models, the
telemetry mux, device-portable classification, and the pinned invariance —
on a homogeneous zero-variability fleet, ``FleetCapController`` decisions
are byte-identical to the single-job ``OnlineCapController`` path."""
import dataclasses

import numpy as np
import pytest

from repro.analysis.hardware import CHIP_MODELS, V5E
from repro.core.algorithm1 import select_optimal_freq
from repro.fleet import (DeviceInstance, DeviceInventory, FleetCapController,
                         FleetTelemetryMux, VariabilityModel)
from repro.pipeline import (OnlineCapController, ReferenceLibrary,
                            stream_profile_once, stream_profile_workload)
from repro.telemetry import TPUPowerModel, simulate, stream_telemetry
from repro.telemetry.kernel_stream import (micro_gemm, micro_idle_burst,
                                           micro_spmv_compute,
                                           micro_spmv_memory, micro_stencil)

MODEL = TPUPowerModel()
TDP = MODEL.spec.tdp_w
FREQS = (0.6, 0.8, 1.0)
GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)


@pytest.fixture(scope="module")
def micro_library():
    return ReferenceLibrary(
        (stream_profile_workload(s, MODEL, FREQS, TDP, seed=i,
                                 target_duration=0.5)
         for i, s in enumerate([micro_gemm(), micro_idle_burst(),
                                micro_spmv_memory(), micro_stencil()])),
        built_on="tpu-v5e")


# ---------------------------------------------------------------------------
# inventory
# ---------------------------------------------------------------------------
def test_inventory_generation_is_deterministic():
    a = DeviceInventory.generate({"tpu-v5e": 2, "tpu-v5p": 1},
                                 VariabilityModel(), seed=9)
    b = DeviceInventory.generate({"tpu-v5e": 2, "tpu-v5p": 1},
                                 VariabilityModel(), seed=9)
    assert [d.spec for d in a] == [d.spec for d in b]
    assert [d.device_id for d in a] == [d.device_id for d in b]
    c = DeviceInventory.generate({"tpu-v5e": 2, "tpu-v5p": 1},
                                 VariabilityModel(), seed=10)
    assert [d.spec for d in a] != [d.spec for d in c]


def test_zero_variability_is_exactly_nominal():
    inv = DeviceInventory.generate(3, VariabilityModel.none(), seed=4)
    assert inv.homogeneous
    for d in inv:
        assert d.spec.perf_scale == 1.0 and d.spec.power_scale == 1.0
        assert d.effective_tdp_w == V5E.tdp_w
        # everything but the variability fields matches the registry spec
        assert dataclasses.replace(d.spec) == dataclasses.replace(
            CHIP_MODELS[d.model], perf_scale=1.0, power_scale=1.0)


def test_variability_perturbs_each_device_differently():
    inv = DeviceInventory.generate(4, VariabilityModel(), seed=0)
    scales = {(d.spec.perf_scale, d.spec.power_scale) for d in inv}
    assert len(scales) == 4
    assert not inv.homogeneous
    for d in inv:
        assert 1 - 3 * 0.05 <= d.spec.perf_scale <= 1 + 3 * 0.05
        assert 1 - 3 * 0.08 <= d.spec.power_scale <= 1 + 3 * 0.08


def test_inventory_lookup_and_validation():
    inv = DeviceInventory.generate({"tpu-v5e": 1, "tpu-v6e": 1}, seed=0)
    assert len(inv) == 2 and inv.models == ["tpu-v5e", "tpu-v6e"]
    assert inv.get("tpu-v6e/000").model == "tpu-v6e"
    assert inv.nameplate_w == V5E.tdp_w + CHIP_MODELS["tpu-v6e"].tdp_w
    with pytest.raises(KeyError):
        inv.get("nope")
    with pytest.raises(KeyError):
        DeviceInventory.generate({"tpu-v9x": 1})
    dup = inv[0]
    with pytest.raises(ValueError, match="duplicate device_id"):
        DeviceInventory([dup, dup])


# ---------------------------------------------------------------------------
# per-device power model
# ---------------------------------------------------------------------------
def test_nominal_device_trace_is_byte_identical_to_prefleet():
    dev = DeviceInventory.generate(1, seed=0)[0]
    base = simulate(micro_gemm(), 1.0, MODEL, target_duration=0.5, seed=3)
    got = simulate(micro_gemm(), 1.0, dev.power_model(),
                   target_duration=0.5, seed=3)
    np.testing.assert_array_equal(got.power_filtered, base.power_filtered)
    np.testing.assert_array_equal(got.power_raw, base.power_raw)


def test_power_scale_scales_drawn_power():
    hot = dataclasses.replace(V5E, power_scale=1.1)
    cool = dataclasses.replace(V5E, power_scale=0.9)
    m_hot, m_cool = TPUPowerModel(hot), TPUPowerModel(cool)
    assert m_hot.idle_w > MODEL.idle_w > m_cool.idle_w
    p = [m.steady_power(0.9, 0.2, 1.0) for m in (m_hot, MODEL, m_cool)]
    assert p[0] > p[1] > p[2]
    assert p[0] == pytest.approx(1.1 * p[1] / 1.0)


def test_perf_scale_scales_kernel_duration():
    fast = dataclasses.replace(V5E, perf_scale=1.1)
    slow = dataclasses.replace(V5E, perf_scale=0.9)
    k = micro_gemm().kernels[0]
    d = [TPUPowerModel(s).exec_kernel(k, 1.0).duration
         for s in (fast, V5E, slow)]
    assert d[0] < d[1] < d[2]


def test_device_portable_classification(micro_library):
    """A profile captured on a perturbed chip, normalized by the device's
    effective TDP, classifies to the same neighbor as the nominal chip."""
    clf = micro_library.classifier()
    nominal = stream_profile_once(micro_spmv_compute(), MODEL, TDP, seed=21)
    sel_nom = select_optimal_freq(nominal, clf)
    dev = DeviceInventory.generate(
        1, VariabilityModel(sigma_perf=0.0, sigma_power=0.08), seed=2)[0]
    assert dev.spec.power_scale != 1.0
    raw = stream_profile_once(micro_spmv_compute(), dev.power_model(),
                       dev.effective_tdp_w, seed=21)
    sel_dev = select_optimal_freq(raw, clf)
    assert sel_dev.power_neighbor == sel_nom.power_neighbor
    assert sel_dev.f_pwr == sel_nom.f_pwr
    # normalize_profile reframes an existing nameplate-relative profile
    nameplate_frame = stream_profile_once(micro_spmv_compute(), dev.power_model(),
                                   dev.nameplate_w, seed=21)
    renormed = dev.normalize_profile(nameplate_frame)
    assert renormed.tdp == dev.effective_tdp_w
    np.testing.assert_array_equal(renormed.power_trace,
                                  nameplate_frame.power_trace)


# ---------------------------------------------------------------------------
# telemetry mux
# ---------------------------------------------------------------------------
def _job_stream(stream_fn, seed, device_id=""):
    return stream_telemetry(stream_fn(), 1.0, MODEL, seed=seed,
                            target_duration=0.5, chunk_samples=100,
                            device_id=device_id)


def test_mux_preserves_per_job_order_and_merges_by_time():
    mux = FleetTelemetryMux()
    metas = {}
    for i, fn in enumerate([micro_gemm, micro_idle_burst]):
        meta, chunks = _job_stream(fn, seed=i, device_id=f"dev/{i}")
        metas[f"job{i}"] = meta
        mux.add_job(f"job{i}", meta, chunks)
    seen = {}
    last_t = -1.0
    for fc in mux:
        assert fc.t_end >= last_t            # global time order
        last_t = fc.t_end
        assert fc.device_id == f"dev/{fc.job_id[-1]}"
        seen.setdefault(fc.job_id, []).append(fc.chunk)
    for job_id, chunks in seen.items():
        idx = [c.start_index for c in chunks]
        assert idx == sorted(idx)            # per-job order intact
        assert idx[0] == 0
        n = idx[-1] + len(chunks[-1].energy_j)
        assert n == metas[job_id].n_samples  # nothing dropped
    assert set(seen) == {"job0", "job1"}


def test_mux_rejects_duplicate_job_and_honors_t_start():
    mux = FleetTelemetryMux()
    meta, chunks = _job_stream(micro_gemm, seed=0)
    mux.add_job("a", meta, chunks)
    with pytest.raises(ValueError, match="duplicate job_id"):
        mux.add_job("a", meta, iter(()))
    # a job arriving much later drains strictly after an early one
    meta_b, chunks_b = _job_stream(micro_gemm, seed=0)
    mux.add_job("b", meta_b, chunks_b, t_start=1e6)
    order = [fc.job_id for fc in mux]
    assert order == ["a"] * order.count("a") + ["b"] * order.count("b")


# ---------------------------------------------------------------------------
# FleetCapController: the pinned homogeneous-fleet invariance
# ---------------------------------------------------------------------------
def test_homogeneous_fleet_is_byte_identical_to_single_job_path(
        micro_library):
    """ISSUE 3 acceptance: variability disabled + one device type ->
    every fleet decision (neighbor, bin size, cap, confidence, fraction)
    is byte-identical to the PR 2 per-job ``OnlineCapController.run``."""
    inv = DeviceInventory.generate(3, VariabilityModel.none(), seed=0)
    jobs = [(micro_gemm, 0), (micro_spmv_memory, 1), (micro_spmv_compute, 2)]

    fleet = FleetCapController(micro_library, budget_w=1e9, **GATES)
    mux = FleetTelemetryMux()
    ids = []
    for (fn, seed), dev in zip(jobs, inv):
        meta, chunks = _job_stream(fn, seed=seed, device_id=dev.device_id)
        ids.append(fleet.admit(dev, meta, chips=4))
        mux.add_job(ids[-1], meta, chunks)
    result = fleet.run(mux)

    for (fn, seed), dev, job_id in zip(jobs, inv, ids):
        single = OnlineCapController(micro_library, actuator=None,
                                     **GATES)
        meta, chunks = _job_stream(fn, seed=seed)
        expect = single.run(meta, chunks, V5E.tdp_w)
        got = result.decisions[job_id]
        assert got.selection == expect.selection      # neighbor + bin size
        assert got.cap == expect.cap
        assert got.confidence == expect.confidence
        assert got.fraction == expect.fraction
        assert got.n_samples == expect.n_samples
        assert got.early == expect.early
        assert got.device_id == dev.device_id
    # the fleet plan never exceeds its budget, at any repack
    for res in fleet.repacks:
        assert res.planned_power_w <= res.budget_w


def test_fleet_controller_gates_budget_and_early_stop(micro_library):
    inv = DeviceInventory.generate(2, VariabilityModel(), seed=1)
    fleet = FleetCapController(micro_library, budget_w=1.0, **GATES)
    mux = FleetTelemetryMux()
    for i, (fn, dev) in enumerate(zip([micro_gemm, micro_spmv_memory], inv)):
        meta, chunks = _job_stream(fn, seed=i, device_id=dev.device_id)
        mux.add_job(fleet.admit(dev, meta, chips=8), meta, chunks)
    with pytest.raises(ValueError, match="duplicate job_id"):
        fleet.admit(inv[0], meta, job_id=list(fleet.jobs)[0])
    result = fleet.run(mux)
    assert len(result.decisions) == 2
    # a 1 W budget can place nothing, but decisions still happen
    assert result.schedule.placed == []
    assert len(result.schedule.deferred) == 2
    assert result.schedule.planned_power_w == 0.0
    if result.early_decisions:
        assert result.chunks_dropped > 0
    # per-job actuators were driven on the jobs' own devices
    for job in fleet.jobs.values():
        assert job.actuator.device_id == job.device.device_id
        assert job.actuator.get_cap() == result.decisions[job.job_id].cap


# ---------------------------------------------------------------------------
# batched engine: bit-for-bit identity with per-job ProfileBuilders
# ---------------------------------------------------------------------------
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.pipeline import BatchProfileEngine, ProfileBuilder  # noqa: E402
from repro.telemetry.simulator import TelemetryChunk, TraceMeta  # noqa: E402


def _synthetic_counters(seed, n, name="synthetic"):
    rng = np.random.default_rng(seed)
    power = rng.uniform(0.0, 1.3 * TDP, size=n)
    busy = (rng.random(n) < 0.8).astype(float)
    energy_ctr = np.concatenate([[0.0], np.cumsum(power * 1e-3)])
    busy_ctr = np.concatenate([[0.0], np.cumsum(busy * 1e-3)])
    meta = TraceMeta(name=name, domain="test", sample_dt=1e-3, n_samples=n,
                     exec_time=1.0, app_sm_util=0.5, app_dram_util=0.5,
                     kernel_rows=[])
    return meta, energy_ctr, busy_ctr


def _assert_builder_match(ref, sb):
    assert ref.n_ingested == sb.n_ingested
    assert ref.n_committed == sb.n_committed
    assert ref.fraction == sb.fraction
    assert ref.spike_count() == sb.spike_count()
    for c in ref.bin_sizes:
        np.testing.assert_array_equal(ref.spike_vector(c),
                                      sb.spike_vector(c))
    a, b = ref.snapshot(), sb.snapshot()
    np.testing.assert_array_equal(a.power_trace, b.power_trace)
    assert a.fraction == b.fraction and a.n_samples == b.n_samples


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=20, deadline=None)
def test_batched_engine_is_bit_identical_to_perjob_builders(scenario_seed):
    """ISSUE 7 pin: under arbitrary job interleavings, chunk splits, and
    mid-stream retire/admit (with slot reuse), the columnar engine's state
    is bit-for-bit identical to one ``ProfileBuilder`` per job — spike
    histograms, committed traces, snapshots, and finalized profiles."""
    rng = np.random.default_rng(scenario_seed)
    eng = BatchProfileEngine(capacity=2)       # force slot-array growth

    def new_job(name):
        n = int(rng.integers(1, 1200))
        meta, e, b = _synthetic_counters(int(rng.integers(0, 10 ** 6)), n,
                                         name)
        cuts = sorted({int(c) for c in
                       rng.integers(1, max(n, 2),
                                    size=int(rng.integers(0, 6)))
                       if 0 < c < n})
        bounds = [0] + cuts + [n]
        chunks = [TelemetryChunk(energy_j=e[i + 1:j + 1],
                                 busy_s=b[i + 1:j + 1],
                                 sample_dt=meta.sample_dt, start_index=i)
                  for i, j in zip(bounds[:-1], bounds[1:])]
        return dict(ref=ProfileBuilder(meta, TDP),
                    sb=eng.builder(meta, TDP), chunks=chunks, pos=0)

    live = {f"j{k}": new_job(f"j{k}")
            for k in range(int(rng.integers(2, 5)))}
    admits_left, next_id = 3, 100
    while live:
        remaining = [j for j in sorted(live)
                     if live[j]["pos"] < len(live[j]["chunks"])]
        if remaining:
            # random tick: a random subset of unfinished jobs polls at once
            tick = [j for j in remaining if rng.random() < 0.7] \
                or [remaining[0]]
            slots, chunks = [], []
            for jid in tick:
                job = live[jid]
                ck = job["chunks"][job["pos"]]
                job["pos"] += 1
                job["ref"].ingest(ck)
                slots.append(job["sb"].slot)
                chunks.append(ck)
            eng.ingest_batch(slots, chunks)
            _assert_builder_match(live[tick[0]]["ref"], live[tick[0]]["sb"])
        # mid-stream retire (slot goes back to the free list mid-run)
        if rng.random() < 0.15:
            jid = sorted(live)[int(rng.integers(len(live)))]
            job = live.pop(jid)
            _assert_builder_match(job["ref"], job["sb"])
            job["sb"].release()
            if admits_left and rng.random() < 0.5:   # slot reuse
                admits_left -= 1
                live[f"n{next_id}"] = new_job(f"n{next_id}")
                next_id += 1
        # fully-fed jobs: finalize must match bit-for-bit, then free
        for jid in [j for j in sorted(live)
                    if live[j]["pos"] >= len(live[j]["chunks"])]:
            job = live.pop(jid)
            _assert_builder_match(job["ref"], job["sb"])
            a, b = job["ref"].finalize(), job["sb"].finalize()
            np.testing.assert_array_equal(a.power_trace, b.power_trace)
            assert a.fraction == b.fraction and a.n_samples == b.n_samples
            assert a.complete and b.complete
            job["sb"].release()


def test_batched_engine_poisoned_tick_is_all_or_nothing():
    """A poisoned chunk raises the per-job builder's message and leaves
    every slot in the tick untouched (no partial mutation)."""
    eng = BatchProfileEngine()
    meta_a, ea, ba = _synthetic_counters(1, 300, "a")
    meta_b, eb, bb = _synthetic_counters(2, 300, "b")
    sa, sb_ = eng.builder(meta_a, TDP), eng.builder(meta_b, TDP)
    bad = eb[1:301].copy()
    bad[50] = np.nan
    with pytest.raises(ValueError, match="NaN/non-finite energy_j"):
        eng.ingest_batch(
            (sa.slot, sb_.slot),
            (TelemetryChunk(energy_j=ea[1:301], busy_s=ba[1:301],
                            sample_dt=1e-3, start_index=0),
             TelemetryChunk(energy_j=bad, busy_s=bb[1:301],
                            sample_dt=1e-3, start_index=0)))
    assert sa.n_ingested == 0 and sb_.n_ingested == 0


def test_mux_ticks_batches_equal_timestamps_in_chunk_order():
    """ISSUE 7 satellite: ``ticks()`` yields all equal-``t_end`` chunks as
    one batch, and concatenating the batches reproduces ``__iter__``'s
    exact chunk sequence."""
    def build():
        mux = FleetTelemetryMux()
        for i, fn in enumerate([micro_gemm, micro_idle_burst,
                                micro_spmv_memory]):
            meta, chunks = _job_stream(fn, seed=i, device_id=f"dev/{i}")
            mux.add_job(f"job{i}", meta, chunks)
        return mux
    flat = [(fc.job_id, fc.t_end, fc.chunk.start_index)
            for fc in build()]
    ticked = []
    n_batches = 0
    for batch in build().ticks():
        n_batches += 1
        assert len({fc.t_end for fc in batch}) == 1   # one poll instant
        ticked.extend((fc.job_id, fc.t_end, fc.chunk.start_index)
                      for fc in batch)
    assert ticked == flat
    assert n_batches < len(flat)     # equal timestamps really coalesced


def test_fleet_batched_engine_matches_perjob_engine(micro_library):
    """Fleet-level pin: engine='batched' through the tick path produces the
    byte-identical decisions and final packing as engine='perjob' through
    the per-chunk path, and repack='tick' converges to the same packing."""
    jobs = [(micro_gemm, 0), (micro_spmv_memory, 1), (micro_spmv_compute, 2),
            (micro_idle_burst, 3)]

    def run(engine, repack, per_chunk=False):
        inv = DeviceInventory.generate(4, VariabilityModel(), seed=7)
        fleet = FleetCapController(micro_library, budget_w=5000.0,
                                   engine=engine, repack=repack, **GATES)
        mux = FleetTelemetryMux()
        for (fn, seed), dev in zip(jobs, inv):
            meta, chunks = _job_stream(fn, seed=seed,
                                       device_id=dev.device_id)
            mux.add_job(fleet.admit(dev, meta, chips=4), meta, chunks)
        if per_chunk:
            for fc in mux:
                fleet.ingest(fc)
            return fleet.finalize()
        return fleet.run(mux)

    ref = run("perjob", "decision", per_chunk=True)
    got = run("batched", "decision")
    assert set(got.decisions) == set(ref.decisions)
    for job_id, expect in ref.decisions.items():
        assert got.decisions[job_id] == expect
    assert got.repacks == ref.repacks
    assert got.schedule == ref.schedule
    assert got.chunks_dropped == ref.chunks_dropped
    # tick-cadence repacking: fewer scheduler calls, same final packing
    coarse = run("batched", "tick")
    assert coarse.decisions == ref.decisions
    assert coarse.schedule == ref.schedule
    assert coarse.repacks <= ref.repacks
