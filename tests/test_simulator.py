"""Telemetry simulator behavior (what the paper measures on hardware)."""
import numpy as np
import pytest

from repro.analysis.hardware import V5E
from repro.core import spikes
from repro.telemetry import TPUPowerModel, simulate
from repro.telemetry.kernel_stream import (micro_gemm, micro_idle_burst,
                                           micro_spmv_memory)

TDP = V5E.tdp_w


@pytest.fixture(scope="module")
def model():
    return TPUPowerModel()


def test_trace_ranges(model):
    tr = simulate(micro_gemm(), 1.0, model, seed=1)
    assert len(tr.power_filtered) > 100
    assert tr.power_filtered.min() > 0
    assert tr.power_filtered.max() <= V5E.max_excursion * TDP * 1.6  # noise slack
    assert 0.0 <= tr.app_sm_util <= 1.0
    assert 0.0 <= tr.app_dram_util <= 1.0


def test_compute_stream_shifts_left_under_cap(model):
    hi = simulate(micro_gemm(), 1.0, model, seed=2)
    lo = simulate(micro_gemm(), 0.6, model, seed=2)
    p_hi = spikes.p_quantile(hi.power_filtered, TDP, 90)
    p_lo = spikes.p_quantile(lo.power_filtered, TDP, 90)
    assert p_lo < p_hi - 0.2
    assert lo.exec_time > hi.exec_time * 1.5


def test_memory_stream_invariant_under_cap(model):
    hi = simulate(micro_spmv_memory(), 1.0, model, seed=3)
    lo = simulate(micro_spmv_memory(), 0.6, model, seed=3)
    p_hi = spikes.p_quantile(hi.power_filtered, TDP, 90)
    p_lo = spikes.p_quantile(lo.power_filtered, TDP, 90)
    assert abs(p_hi - p_lo) < 0.08
    assert lo.exec_time == pytest.approx(hi.exec_time, rel=0.05)


def test_idle_burst_has_spikes_and_idle(model):
    tr = simulate(micro_idle_burst(), 1.0, model, seed=4)
    rel = tr.power_filtered / TDP
    assert np.max(rel) > 1.3          # burst overshoots
    assert np.percentile(rel, 20) < 0.6   # mostly idle-ish
    v = spikes.spike_vector(tr.power_filtered, TDP)
    assert v.sum() == pytest.approx(1.0)


def test_determinism(model):
    a = simulate(micro_gemm(), 1.0, model, seed=9)
    b = simulate(micro_gemm(), 1.0, model, seed=9)
    np.testing.assert_allclose(a.power_filtered, b.power_filtered)


def test_busy_trimming(model):
    tr = simulate(micro_idle_burst(), 1.0, model, seed=5)
    # the raw trace has idle padding; the filtered one is trimmed
    assert len(tr.power_filtered) <= len(tr.power_raw)
