"""Training loop, checkpoint/restart, preemption, optimizer."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import ARCHS
from repro.configs.base import RunConfig, ShapeConfig
from repro.data import ByteCorpus
from repro.ft import PreemptionHandler, StragglerMonitor, plan_new_mesh
from repro.configs.base import MeshConfig
from repro.models.common import SMOKE_TOPO
from repro.optim import adamw_update, clip_by_global_norm, init_opt_state
from repro.train import Trainer


def _run_cfg(tmp, steps=6, **kw):
    return RunConfig(total_steps=steps, warmup_steps=2, checkpoint_every=3,
                     checkpoint_dir=tmp, learning_rate=3e-3, **kw)


def test_loss_decreases_on_byte_corpus():
    cfg = ARCHS["glm4-9b"].reduced(num_layers=2, vocab_size=256)
    shape = ShapeConfig("smoke", seq_len=48, global_batch=8, kind="train")
    with tempfile.TemporaryDirectory() as tmp:
        run = _run_cfg(tmp, steps=14)
        tr = Trainer(cfg, shape, run, SMOKE_TOPO,
                     data=ByteCorpus(cfg, shape))
        res = tr.run()
    first = np.mean(res.losses[:3])
    last = np.mean(res.losses[-3:])
    assert last < first - 0.2, (first, last)


def test_checkpoint_resume_continues_exactly():
    cfg = ARCHS["glm4-9b"].reduced(num_layers=2)
    shape = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")
    with tempfile.TemporaryDirectory() as tmp:
        run = _run_cfg(tmp, steps=6)
        t1 = Trainer(cfg, shape, run, SMOKE_TOPO)
        r1 = t1.run(num_steps=3)                  # checkpoints at step 3
        t2 = Trainer(cfg, shape, run, SMOKE_TOPO)
        r2 = t2.run()                             # resumes 3 -> 6
        assert r2.restored_from == 3
        assert r2.final_step == 6
        # an uninterrupted run must produce identical losses for steps 4-6
        with tempfile.TemporaryDirectory() as tmp2:
            run3 = _run_cfg(tmp2, steps=6)
            t3 = Trainer(cfg, shape, run3, SMOKE_TOPO)
            r3 = t3.run()
        np.testing.assert_allclose(r2.losses, r3.losses[3:], rtol=1e-5)


def test_preemption_checkpoints_and_stops():
    cfg = ARCHS["glm4-9b"].reduced(num_layers=2)
    shape = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")
    with tempfile.TemporaryDirectory() as tmp:
        run = _run_cfg(tmp, steps=50)
        pre = PreemptionHandler(install=False)
        tr = Trainer(cfg, shape, run, SMOKE_TOPO, preemption=pre)
        pre.trigger()
        res = tr.run()
        assert res.preempted
        assert res.steps_run == 1
        assert ckpt.latest_step(tmp) == 1


def test_ckpt_roundtrip_and_gc():
    state = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
             "b": {"c": jnp.float32(3.5), "d": jnp.arange(4, dtype=jnp.int32)}}
    with tempfile.TemporaryDirectory() as tmp:
        for step in (1, 2, 3, 4):
            ckpt.save(state, tmp, step)
        ckpt.garbage_collect(tmp, keep=2)
        assert ckpt.latest_step(tmp) == 4
        restored, step = ckpt.restore(tmp)
        assert step == 4
        np.testing.assert_array_equal(
            np.asarray(restored["a"], np.float32),
            np.asarray(state["a"], np.float32))
        assert restored["a"].dtype == jnp.bfloat16
        assert float(restored["b"]["c"]) == 3.5
        steps = sorted(d for d in os.listdir(tmp) if d.startswith("step_"))
        assert len(steps) == 2


def test_microbatched_step_matches_unbatched():
    from repro.models import build_model, make_batch
    from repro.train.step import init_state, make_train_step
    cfg = ARCHS["glm4-9b"].reduced(num_layers=2)
    shape = ShapeConfig("smoke", seq_len=32, global_batch=8, kind="train")
    m = build_model(cfg, SMOKE_TOPO, kind="train")
    batch = make_batch(cfg, shape, jax.random.key(1))
    s0 = init_state(m, RunConfig(), jax.random.key(0))
    step1 = make_train_step(m, RunConfig(microbatches=1), SMOKE_TOPO)
    step4 = make_train_step(m, RunConfig(microbatches=4), SMOKE_TOPO)
    _, m1 = jax.jit(step1)(s0, batch)
    s0b = init_state(m, RunConfig(), jax.random.key(0))
    _, m4 = jax.jit(step4)(s0b, batch)
    # bf16 grad accumulation: losses equal, grad norms close
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-2
    assert abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) / \
        max(float(m1["grad_norm"]), 1e-9) < 0.1


def test_optimizer_units():
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    grads = {"w": jnp.full((4, 4), 2.0, jnp.bfloat16)}
    opt = init_opt_state(params, "bfloat16")
    assert opt["m"]["w"].dtype == jnp.bfloat16
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(8.0)
    assert float(jnp.linalg.norm(
        clipped["w"].astype(jnp.float32))) == pytest.approx(1.0, rel=1e-2)
    cfg = RunConfig()
    new_p, new_opt = adamw_update(params, grads, opt, cfg, jnp.float32(1e-2))
    assert new_opt["step"] == 1
    assert float(new_p["w"][0, 0]) < 1.0   # moved against the gradient


def test_straggler_and_elastic():
    mon = StragglerMonitor(min_samples=3, k=4.0)
    for host in range(8):
        for step in range(6):
            mon.record(host, step, 1.0 + 0.01 * host)
    for step in range(6):
        mon.record(8, step, 5.0)     # slow host
    assert mon.stragglers() == [8]
    assert 8 not in mon.healthy_hosts(list(range(9)))

    mesh = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
    plan = plan_new_mesh(mesh, surviving_devices=208)   # lost 3 hosts of 8 chips
    assert plan.new.model_axis_size == 16
    assert plan.new.data_axis_size == 8                 # largest pow2 <= 13
    assert plan.new.num_devices <= 208
