"""Clustering primitives: correctness on known structure + invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (
    best_k_by_silhouette, cosine_distance_matrix, cut, cut_k,
    dendrogram_order, euclidean_distance_matrix, kmeans, linkage,
    silhouette_score,
)


def _three_blobs(seed=0, n=6, d=5):
    rng = np.random.default_rng(seed)
    centers = np.eye(3, d) * 3
    X = np.vstack([rng.normal(0, 0.05, (n, d)) + c for c in centers])
    return np.abs(X)


def test_cosine_distance_matrix_properties():
    X = _three_blobs()
    D = cosine_distance_matrix(X)
    assert np.allclose(D, D.T)
    assert np.allclose(np.diag(D), 0)
    assert D.min() >= -1e-12 and D.max() <= 2.0 + 1e-12
    # zero vector convention
    X2 = np.vstack([X, np.zeros(X.shape[1])])
    D2 = cosine_distance_matrix(X2)
    assert np.allclose(D2[-1, :-1], 1.0)


@pytest.mark.parametrize("method", ["ward", "average", "complete", "single"])
def test_linkage_recovers_blobs(method):
    X = _three_blobs()
    Z = linkage(cosine_distance_matrix(X), method)
    labels = cut_k(Z, 3)
    # each blob is a single cluster
    for blk in range(3):
        blob = labels[blk * 6:(blk + 1) * 6]
        assert len(set(blob)) == 1
    assert len(set(labels)) == 3


def test_linkage_shape_and_sizes():
    X = _three_blobs(n=4)
    Z = linkage(cosine_distance_matrix(X), "average")
    n = X.shape[0]
    assert Z.shape == (n - 1, 4)
    assert Z[-1, 3] == n                      # final merge holds everything
    order = dendrogram_order(Z)
    assert sorted(order) == list(range(n))


def test_cut_thresholds():
    X = _three_blobs()
    Z = linkage(cosine_distance_matrix(X), "average")
    assert len(set(cut(Z, 1e9))) == 1
    assert len(set(cut(Z, -1.0))) == len(X)


def test_kmeans_recovers_blobs():
    X = _three_blobs(seed=3)
    centers, labels, inertia = kmeans(X, 3, seed=0)
    assert len(set(np.asarray(labels).tolist())) == 3
    assert silhouette_score(X, np.asarray(labels)) > 0.8


def test_kmeans_inertia_decreases_with_k():
    X = _three_blobs(seed=4)
    inertias = [kmeans(X, k, seed=0)[2] for k in (1, 2, 3, 5)]
    assert all(a >= b - 1e-9 for a, b in zip(inertias, inertias[1:]))


def test_silhouette_best_k():
    X = _three_blobs(seed=5)
    best, scores = best_k_by_silhouette(X, k_range=range(2, 8), seed=0)
    assert best == 3


@given(st.integers(4, 24), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_silhouette_bounds_random(n, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 3))
    _, labels, _ = kmeans(X, 3, seed=seed)
    s = silhouette_score(X, np.asarray(labels))
    assert -1.0 - 1e-9 <= s <= 1.0 + 1e-9


@given(st.integers(5, 16), st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_cut_k_returns_k_clusters(n, seed):
    rng = np.random.default_rng(seed)
    X = np.abs(rng.normal(size=(n, 4))) + 0.1
    Z = linkage(cosine_distance_matrix(X), "ward")
    for k in (1, 2, 3, n):
        labels = cut_k(Z, k)
        assert len(set(labels)) == min(k, n)
