"""Durable-session acceptance (PR 6): write-ahead journal + snapshot store.

The contracts pinned here:

  * **crash-at-any-point equivalence** — truncate the journal after ANY
    record, resume, and the reconstructed decisions / plans / device
    health / events are byte-identical to the live session at that point,
    with **zero classifier calls** (the `count_classifier_calls` spy);
  * **torn tails and corrupt snapshots never crash recovery** — damaged
    journal tails are truncated with a warning, a corrupt latest snapshot
    falls back to its predecessor (N-1 retention);
  * **store-inert-by-default** — a session without a ``store`` key takes
    exactly the pre-store code paths and produces identical outcomes;
  * the satellite hardening: poisoned telemetry cannot corrupt a later
    snapshot, and a corrupt spike cache degrades to a cold rebuild.
"""
import glob
import json
import math
import os
import shutil
import warnings

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (DeviceInventory, EventJournal, MinosSession,
                       NoStoreError, ProfileBuilder, ReferenceLibrary,
                       SessionStore, SnapshotStore, StoreError,
                       TPUPowerModel, TraceMeta, VariabilityModel,
                       count_classifier_calls, micro_gemm, micro_idle_burst,
                       micro_spmv_memory, micro_stencil, store_report,
                       stream_profile_workload, stream_telemetry, to_dict,
                       windowed_report)
from repro.store.journal import JOURNAL_FILE
from repro.telemetry.simulator import TelemetryChunk

MODEL = TPUPowerModel()
TDP = MODEL.spec.tdp_w
FREQS = (0.6, 0.8, 1.0)
GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)


@pytest.fixture(scope="module")
def micro_library():
    return ReferenceLibrary(
        (stream_profile_workload(s, MODEL, FREQS, TDP, seed=i,
                                 target_duration=0.5)
         for i, s in enumerate([micro_gemm(), micro_idle_burst(),
                                micro_spmv_memory(), micro_stencil()])),
        built_on="tpu-v5e")


def _inventory():
    return DeviceInventory.generate({"tpu-v5e": 3, "tpu-v5p": 2},
                                    VariabilityModel(), seed=7)


def _telemetry(stream, seed):
    return stream_telemetry(stream, 1.0, MODEL, seed=seed,
                            target_duration=0.5)


def _state(session) -> dict:
    """JSON-comparable view of everything resume must reproduce."""
    fleet = session._fleet
    return {
        "job_ids": sorted(fleet.jobs),
        "decisions": {jid: to_dict(j.decision) for jid, j in
                      fleet.jobs.items() if j.decision is not None},
        "plans": {jid: to_dict(j.plan) for jid, j in fleet.jobs.items()
                  if j.plan is not None},
        "health": fleet.device_health(),
        "events": [to_dict(e) for e in fleet.events],
        "retired": {jid: to_dict(d) if d is not None else None
                    for jid, d in session._retired.items()},
        "budget": to_dict(fleet.budget_w),
        "failed": sorted(fleet._failed_devices),
        "rr": session._rr,
    }


def _drive_scripted(session, record_boundary=None):
    """The chaos script every store test replays: submits, an early
    decision, a failure, a budget squeeze, a degrade, a retire, and a
    restore — every journaled mutation kind appears at least once.
    ``record_boundary(tag)`` is called after each step."""
    mark = record_boundary or (lambda tag: None)
    mark("open")
    a = session.submit(_telemetry(micro_gemm(), 100), chips=4)
    mark("submit-a")
    a.run()
    mark("decide-a")
    b = session.submit(_telemetry(micro_spmv_memory(), 101), chips=2)
    mark("submit-b")
    session.fail_device(a.device.device_id)
    mark("fail")
    session.set_budget(5000.0)
    mark("budget")
    c = session.submit(_telemetry(micro_stencil(), 102), chips=1)
    mark("submit-c")
    session.run()
    mark("run")
    session.degrade_device(c.device.device_id)
    mark("degrade")
    session.retire(a.job_id)
    mark("retire")
    session.restore_device(sorted(session._fleet._failed_devices)[0])
    mark("restore")
    return session


@pytest.fixture(scope="module")
def scripted_store(micro_library, tmp_path_factory):
    """One scripted durable run: returns (store_path, boundaries) where
    boundaries maps journal seq -> the live session state at that point."""
    path = str(tmp_path_factory.mktemp("store") / "session")
    session = MinosSession(micro_library, inventory=_inventory(),
                           budget_w=20000.0, store=path, **GATES)
    boundaries = {}

    def mark(tag):
        boundaries[session.store.journal.last_seq] = (tag, _state(session))

    _drive_scripted(session, mark)
    session.close()
    return path, boundaries


def _truncate_journal(src: str, dst: str, keep_records: int) -> None:
    """Copy a store, keeping only the first ``keep_records`` journal
    records — the on-disk picture of a crash right after that append."""
    shutil.rmtree(dst, ignore_errors=True)
    shutil.copytree(src, dst)
    jp = os.path.join(dst, JOURNAL_FILE)
    with open(jp, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    with open(jp, "wb") as f:
        f.writelines(lines[:keep_records])


def _resume_spied(path, micro_library):
    """Resume with the classifier spied from before construction; returns
    (session, calls)."""
    clf = micro_library.classifier()
    calls = count_classifier_calls(clf)
    session = MinosSession.resume(path, references=clf)
    return session, calls


# ---------------------------------------------------------------------------
# tentpole: crash-at-any-point equivalence, zero classifier calls
# ---------------------------------------------------------------------------
def test_resume_at_every_boundary_is_byte_identical(scripted_store,
                                                    micro_library, tmp_path):
    path, boundaries = scripted_store
    for seq, (tag, expected) in boundaries.items():
        crash = str(tmp_path / f"crash-{seq}")
        _truncate_journal(path, crash, seq)
        session, calls = _resume_spied(crash, micro_library)
        assert calls["n"] == 0, \
            f"resume at {tag!r} (seq {seq}) re-classified {calls['n']}x"
        got = _state(session)
        assert got == expected, f"state diverged at boundary {tag!r}"
        # jobs that were still profiling lost their in-flight telemetry:
        # they must come back flagged for an explicit re-run
        for job in session._fleet.jobs.values():
            if job.decision is None:
                assert job.needs_reprofile


def test_resume_after_any_single_record_never_crashes(scripted_store,
                                                      micro_library,
                                                      tmp_path):
    """Crash points BETWEEN session-level operations (mid-drain, between a
    cause record and its consequence events) must still resume cleanly —
    write-ahead redo semantics — with zero classifier calls throughout."""
    path, _ = scripted_store
    with open(os.path.join(path, JOURNAL_FILE), "rb") as f:
        total = len(f.read().splitlines())
    clf = micro_library.classifier()
    calls = count_classifier_calls(clf)
    for keep in range(1, total + 1):
        crash = str(tmp_path / "crash")
        _truncate_journal(path, crash, keep)
        session = MinosSession.resume(crash, references=clf)
        assert session.report() is not None
    assert calls["n"] == 0


def test_resume_with_torn_journal_tail(scripted_store, micro_library,
                                       tmp_path):
    """A partially flushed last record (no newline / garbage bytes) is
    truncated with a warning; the session recovers to the last intact
    record's state."""
    path, boundaries = scripted_store
    last_seq = max(boundaries)
    crash = str(tmp_path / "torn")
    _truncate_journal(path, crash, last_seq)
    with open(os.path.join(crash, JOURNAL_FILE), "ab") as f:
        f.write(b'{"seq": 999, "ts": 0.0, "kind": "bud')   # torn mid-write
    with pytest.warns(RuntimeWarning, match="torn record"):
        session, calls = _resume_spied(crash, micro_library)
    assert calls["n"] == 0
    assert _state(session) == boundaries[last_seq][1]


def test_resume_with_corrupt_middle_record_truncates_tail(scripted_store,
                                                          micro_library,
                                                          tmp_path):
    """A checksum-corrupt record invalidates everything after it (those
    records describe state that may never have been reached): recovery
    keeps the clean prefix and warns."""
    path, _ = scripted_store
    crash = str(tmp_path / "corrupt")
    shutil.rmtree(crash, ignore_errors=True)
    shutil.copytree(path, crash)
    for snap in glob.glob(os.path.join(crash, "snapshot-*.json")):
        os.remove(snap)                   # force pure journal replay
    jp = os.path.join(crash, JOURNAL_FILE)
    with open(jp, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    victim = len(lines) // 2
    lines[victim] = lines[victim].replace(b'"kind"', b'"kinX"', 1)
    with open(jp, "wb") as f:
        f.writelines(lines)
    with pytest.warns(RuntimeWarning):
        session, calls = _resume_spied(crash, micro_library)
    assert calls["n"] == 0
    assert session.store.journal.last_seq >= victim


def test_resume_with_corrupt_latest_snapshot_falls_back(scripted_store,
                                                        micro_library,
                                                        tmp_path):
    """N-1 rollback: flipping bytes in the newest snapshot forces the
    previous snapshot (or full replay) — same reconstructed state."""
    path, boundaries = scripted_store
    crash = str(tmp_path / "badsnap")
    shutil.rmtree(crash, ignore_errors=True)
    shutil.copytree(path, crash)
    snaps = sorted(glob.glob(os.path.join(crash, "snapshot-*.json")))
    assert snaps, "scripted run should have written snapshots"
    with open(snaps[-1], "r+b") as f:
        f.seek(20)
        f.write(b"XXXXXX")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        session, calls = _resume_spied(crash, micro_library)
    assert calls["n"] == 0
    assert _state(session) == boundaries[max(boundaries)][1]


def test_reprofile_after_resume_reproduces_decision(scripted_store,
                                                    micro_library, tmp_path):
    """A mid-profile job resumes via needs_reprofile: feeding it raises
    until JobHandle.reprofile, and re-running the SAME stream/seed yields
    the byte-identical decision the uninterrupted session reached."""
    path, boundaries = scripted_store
    submit_b = next(seq for seq, (tag, _) in boundaries.items()
                    if tag == "submit-b")
    final_states = boundaries[max(boundaries)][1]
    crash = str(tmp_path / "reprofile")
    _truncate_journal(path, crash, submit_b)
    session, calls = _resume_spied(crash, micro_library)
    # at this boundary A is decided and B is the lone mid-profile job
    b_id = next(jid for jid, j in session._fleet.jobs.items()
                if j.decision is None)
    handle = session.jobs[b_id]
    _, probe = _telemetry(micro_spmv_memory(), 101)
    with pytest.raises(ValueError, match="restart"):
        handle.feed(next(iter(probe)))
    assert calls["n"] == 0                 # resume itself never classified
    handle.reprofile(_telemetry(micro_spmv_memory(), 101))
    handle.run()
    got = to_dict(handle.decision())
    expect = final_states["decisions"][b_id]
    # same stream, same seed, same device frame -> byte-identical decision
    # (the device tag survives, too: the job was re-admitted on its device)
    assert got == expect


# ---------------------------------------------------------------------------
# store-inert-by-default + transparent journaling
# ---------------------------------------------------------------------------
def test_store_inert_by_default(micro_library):
    session = MinosSession(micro_library, inventory=_inventory(),
                           budget_w=20000.0, **GATES)
    assert session.store is None and session._fleet.journal is None
    _drive_scripted(session)
    session.close()                        # no-op without a store
    assert session.report() is not None


def test_stored_session_behaves_identically(micro_library, tmp_path):
    """Attaching a store must not perturb a single decision, plan, event,
    or placement — durability is observation, not interference."""
    plain = MinosSession(micro_library, inventory=_inventory(),
                         budget_w=20000.0, **GATES)
    stored = MinosSession(micro_library, inventory=_inventory(),
                          budget_w=20000.0, store=str(tmp_path / "s"),
                          **GATES)
    assert _state(_drive_scripted(plain)) \
        == _state(_drive_scripted(stored))
    stored.close()


def test_from_config_store_key(micro_library, tmp_path):
    path = str(tmp_path / "cfg-store")
    session = MinosSession.from_config(
        {"devices": {"tpu-v5e": 2}, "budget_w": 1500.0, "store": path},
        references=micro_library)
    assert session.store is not None
    assert os.path.exists(os.path.join(path, JOURNAL_FILE))
    session.submit(_telemetry(micro_gemm(), 5)).run()
    session.close()
    resumed = MinosSession.resume(path, references=micro_library)
    assert len(resumed._fleet.jobs) == 1
    resumed.close()


def test_fresh_store_refuses_existing_journal(micro_library, tmp_path):
    path = str(tmp_path / "reused")
    MinosSession(micro_library, store=path, **GATES).close()
    with pytest.raises(ValueError, match="already holds a session journal"):
        MinosSession(micro_library, store=path, **GATES)


# ---------------------------------------------------------------------------
# satellite: actionable resume errors (no store vs corrupt store)
# ---------------------------------------------------------------------------
def test_resume_errors_distinguish_missing_from_corrupt(micro_library,
                                                        tmp_path):
    with pytest.raises(NoStoreError, match="no session store"):
        MinosSession.resume(str(tmp_path / "nowhere"),
                            references=micro_library)
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(NoStoreError, match="no session store"):
        MinosSession.resume(str(empty), references=micro_library)
    corrupt = tmp_path / "corrupt"
    corrupt.mkdir()
    (corrupt / JOURNAL_FILE).write_text("this is not a journal\n")
    with pytest.raises(StoreError, match="corrupt"), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        MinosSession.resume(str(corrupt), references=micro_library)
    assert issubclass(NoStoreError, StoreError)   # one except catches both


def test_from_config_unknown_key_suggests(micro_library):
    with pytest.raises(ValueError, match="did you mean 'budget_w'"):
        MinosSession.from_config({"budgett_w": 1.0},
                                 references=micro_library)
    with pytest.raises(ValueError, match="recognized"):
        MinosSession.from_config({"zzz": 1}, references=micro_library)


# ---------------------------------------------------------------------------
# journal / snapshot unit behavior
# ---------------------------------------------------------------------------
def test_journal_roundtrip_and_torn_tail(tmp_path):
    jp = str(tmp_path / "j" / JOURNAL_FILE)
    journal = EventJournal(jp)
    for i in range(5):
        assert journal.append("tick", {"i": i}) == i + 1
    journal.close()
    records, good = EventJournal.recover(jp)
    assert [r.data["i"] for r in records] == list(range(5))
    assert good == os.path.getsize(jp)
    with open(jp, "ab") as f:
        f.write(b'{"seq": 6, "ts": 1.0, "ki')
    with pytest.warns(RuntimeWarning, match="torn"):
        journal2, records2 = EventJournal.open_existing(jp)
    assert len(records2) == 5
    assert os.path.getsize(jp) == good       # damaged tail physically gone
    assert journal2.append("tick", {"i": 5}) == 6
    journal2.close()
    records3, _ = EventJournal.recover(jp)
    assert len(records3) == 6                # extends the clean prefix


def test_journal_checksum_and_sequence_breaks(tmp_path):
    jp = str(tmp_path / JOURNAL_FILE)
    journal = EventJournal(jp)
    for i in range(4):
        journal.append("tick", {"i": i})
    journal.close()
    with open(jp, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    # checksum flip in record 3 -> prefix of 2 survives
    bad = lines[:2] + [lines[2].replace(b'"i":2', b'"i":9', 1)] + lines[3:]
    with open(jp, "wb") as f:
        f.writelines(bad)
    with pytest.warns(RuntimeWarning, match="checksum"):
        records, _ = EventJournal.recover(jp)
    assert len(records) == 2
    # sequence gap -> same prefix rule
    with open(jp, "wb") as f:
        f.writelines([lines[0], lines[2]])
    with pytest.warns(RuntimeWarning, match="sequence"):
        records, _ = EventJournal.recover(jp)
    assert len(records) == 1


def test_snapshot_retention_and_fallback(tmp_path):
    store = SnapshotStore(str(tmp_path), retain=2)
    for seq in (3, 7, 11):
        store.write({"v": seq}, seq)
    files = sorted(glob.glob(str(tmp_path / "snapshot-*.json")))
    assert len(files) == 2                   # N-1 retention pruned seq 3
    state, seq = store.load_latest()
    assert (state, seq) == ({"v": 11}, 11)
    with open(files[-1], "r+b") as f:        # corrupt the newest
        f.seek(10)
        f.write(b"~~~~")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        state, seq = store.load_latest()
    assert (state, seq) == ({"v": 7}, 7)     # fell back one snapshot
    assert store.load_latest(max_seq=5) == (None, 0)   # future snaps skipped


def test_session_store_snapshot_cadence(tmp_path):
    store = SessionStore.create(str(tmp_path / "s"), snapshot_every=3)
    store.capture = lambda: {"n": store.journal.last_seq}
    for i in range(7):
        store.record("tick", i=i)
        store.flush_snapshot()
    assert store.load_snapshot() == ({"n": 6}, 6)      # wrote at 3 and 6
    store.close()


# ---------------------------------------------------------------------------
# satellite: poisoned telemetry cannot corrupt a later snapshot
# ---------------------------------------------------------------------------
def _poison(chunk, kind, rng_val):
    e = np.asarray(chunk.energy_j, np.float64).copy()
    b = np.asarray(chunk.busy_s, np.float64).copy()
    i = int(rng_val * (len(e) - 1))
    dt = chunk.sample_dt
    if kind == "nan-energy":
        e[i] = np.nan
    elif kind == "neg-energy":
        e[i] = -abs(e[i]) - 1.0
    elif kind == "backwards-energy":
        e[i] = e[i] * 0.25 - 1.0
        e[:i] = np.maximum.accumulate(e[:i]) + 2.0 + e[i]
    elif kind == "nan-busy":
        b[i] = np.nan
    elif kind == "backwards-busy":
        b[-1] = -1.0
    elif kind == "bad-dt":
        dt = 0.0
    return TelemetryChunk(energy_j=e, busy_s=b, sample_dt=dt,
                          start_index=chunk.start_index)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(["nan-energy", "neg-energy", "backwards-energy",
                        "nan-busy", "backwards-busy", "bad-dt"]),
       st.floats(min_value=0.0, max_value=1.0),
       st.integers(min_value=0, max_value=5))
def test_poisoned_chunk_never_corrupts_snapshot(kind, where, after):
    """Property: offer a poisoned chunk at an arbitrary stream position —
    ingest raises ValueError with job/device context and the builder's
    later snapshots are byte-identical to never having seen the poison."""
    meta, chunks = stream_telemetry(micro_gemm(), 1.0, MODEL, seed=42,
                                    target_duration=0.3,
                                    device_id="tpu-v5e/000")
    chunks = list(chunks)
    after = min(after, len(chunks) - 1)
    clean = ProfileBuilder(meta, tdp=TDP)
    poisoned = ProfileBuilder(meta, tdp=TDP)
    for chunk in chunks[:after]:
        clean.ingest(chunk)
        poisoned.ingest(chunk)
    with pytest.raises(ValueError) as err:
        poisoned.ingest(_poison(chunks[after], kind, where))
    assert meta.name in str(err.value)
    assert "tpu-v5e/000" in str(err.value)
    for chunk in chunks[after:]:             # the intact stream continues
        clean.ingest(chunk)
        poisoned.ingest(chunk)
    a, b = clean.finalize(), poisoned.finalize()
    assert np.array_equal(a.power_trace, b.power_trace)
    for c in (0.1, 0.25):
        assert np.array_equal(a.spike_vec(c), b.spike_vec(c))


# ---------------------------------------------------------------------------
# satellite: corrupt spike cache degrades to a cold rebuild
# ---------------------------------------------------------------------------
def test_library_load_survives_corrupt_spike_cache(micro_library, tmp_path):
    directory = str(tmp_path / "lib")
    micro_library.save(directory)
    intact = ReferenceLibrary.load(directory)        # byte-identity pin path
    for c in intact.bin_sizes:
        assert np.array_equal(intact.spike_matrix(c),
                              micro_library.spike_matrix(c))
    with open(os.path.join(directory, "spike_cache.npz"), "r+b") as f:
        f.truncate(100)                              # truncated mid-write
    with pytest.warns(RuntimeWarning, match="cold spike-matrix rebuild"):
        cold = ReferenceLibrary.load(directory)
    for c in cold.bin_sizes:
        assert np.array_equal(cold.spike_matrix(c),
                              micro_library.spike_matrix(c))
    # corrupt library.json: same degradation, still loads
    with open(os.path.join(directory, "library.json"), "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning, match="cold spike-matrix rebuild"):
        cold2 = ReferenceLibrary.load(directory)
    assert [p.name for p in cold2] == [p.name for p in micro_library]


# ---------------------------------------------------------------------------
# journal-derived windowed reports
# ---------------------------------------------------------------------------
def test_windowed_report_from_scripted_run(scripted_store):
    path, _ = scripted_store
    windows = store_report(path, window_s=3600.0)
    assert windows, "journal should produce at least one window"
    totals = {k: sum(w[k] for w in windows)
              for k in ("admits", "decisions", "retires", "migrations",
                        "failures", "degrades", "restores")}
    assert totals["admits"] == 3
    assert totals["decisions"] == 3
    assert totals["retires"] == 1
    assert totals["failures"] == 1
    assert totals["degrades"] == 1
    assert totals["restores"] == 1
    assert totals["migrations"] >= 1         # the fail drained job A
    last = windows[-1]
    assert last["budget_w"] == 5000.0
    assert last["headroom_w"] == pytest.approx(5000.0 - last["planned_w"])
    assert 0.0 <= last["utilization"] <= 1.0


def test_windowed_report_handles_unbounded_budget():
    recs = [
        {"seq": 1, "ts": 0.0, "kind": "open",
         "data": {"budget_w": {"__float__": "inf"}}},
        {"seq": 2, "ts": 1.0, "kind": "admit", "data": {"job_id": "a"}},
        {"seq": 3, "ts": 2.0, "kind": "decision",
         "data": {"job_id": "a", "plan": {"job_id": "a",
                                          "predicted_p90_w": 123.0}}},
        {"seq": 4, "ts": 7200.0, "kind": "retire", "data": {"job_id": "a"}},
    ]
    windows = windowed_report(recs, window_s=3600.0)
    assert len(windows) == 3                 # gap windows are emitted too
    assert windows[0]["planned_w"] == 123.0
    assert windows[0]["utilization"] is None
    assert windows[0]["headroom_w"] == math.inf
    assert windows[1]["records"] == 0
    assert windows[2]["retires"] == 1 and windows[2]["planned_w"] == 0.0
    with pytest.raises(ValueError, match="positive"):
        windowed_report(recs, window_s=0.0)
    assert windowed_report([], window_s=60.0) == []


def test_meta_roundtrip_preserves_traces():
    """Admit-record codec: a TraceMeta rebuilt from its journal record is
    equal to the original (kernel rows back to tuples, floats exact)."""
    from repro.fleet.records import meta_from_record, meta_record
    meta, _ = _telemetry(micro_gemm(), 3)
    rebuilt = meta_from_record(json.loads(json.dumps(meta_record(meta))))
    assert rebuilt == meta
    assert isinstance(rebuilt, TraceMeta)


def test_journal_batch_coalesces_flushes_and_recovers(tmp_path):
    """ISSUE 7 satellite: appends inside ``batch()`` defer their flush to
    batch exit — small records stay in the stdio buffer mid-batch — yet the
    file recovers every record intact afterwards."""
    jp = str(tmp_path / JOURNAL_FILE)
    journal = EventJournal(jp)
    journal.append("open", {})               # unbatched: flushed eagerly
    base = os.path.getsize(jp)
    with journal.batch():
        for i in range(3):                   # 3 tiny records << 8K buffer
            journal.append("tick", {"i": i})
        assert os.path.getsize(jp) == base   # nothing flushed mid-batch
        with journal.batch():                # re-entrant: still deferred
            journal.append("tick", {"i": 3})
        assert os.path.getsize(jp) == base
    assert os.path.getsize(jp) > base        # one flush at batch exit
    journal.close()
    records, good = EventJournal.recover(jp)
    assert [r.kind for r in records] == ["open"] + ["tick"] * 4
    assert good == os.path.getsize(jp)


def test_journal_batch_preserves_fsync_per_record(tmp_path):
    """fsync=True journals keep per-record flush (+fsync) inside a batch —
    explicit durability is never weakened by coalescing."""
    jp = str(tmp_path / JOURNAL_FILE)
    journal = EventJournal(jp, fsync=True)
    with journal.batch():
        journal.append("tick", {"i": 0})
        size_after_first = os.path.getsize(jp)
        assert size_after_first > 0          # hit the OS immediately
        journal.append("tick", {"i": 1})
        assert os.path.getsize(jp) > size_after_first
    journal.close()
    records, _ = EventJournal.recover(jp)
    assert len(records) == 2


# ---------------------------------------------------------------------------
# journal compaction (PR 9 satellite): folded segments, unbroken sequences
# ---------------------------------------------------------------------------
def _ticked_store(path, n=40, snapshot_every=5, rotate_every=4,
                  compact_every=None):
    store = SessionStore.create(path, snapshot_every=snapshot_every,
                                rotate_every=rotate_every,
                                compact_every=compact_every)
    store.capture = lambda: {"n": store.journal.last_seq}
    store.record("open", a=1)
    for i in range(n):
        store.record("tick", i=i)
        store.flush_snapshot()
    return store


def test_compact_folds_segments_and_keeps_sequences(tmp_path):
    path = str(tmp_path / "s")
    store = _ticked_store(path, compact_every=10)
    last = store.journal.last_seq
    base = store.journal.base
    assert base is not None and base["base_seq"] > 0
    assert base["open"]["kind"] == "open"         # open record preserved
    live_segments = [k for k, _ in EventJournal.segments(store.journal.path)]
    assert live_segments and min(live_segments) > base["through_segment"]
    store.close()
    # recovery: one unbroken sequence from the base floor to the tip
    reopened = SessionStore.open_existing(path)
    assert reopened.journal.last_seq == last
    seqs = [r.seq for r in reopened.recovered_records]
    assert seqs == list(range(base["base_seq"] + 1, last + 1))
    opened = reopened.open_record()
    assert opened.kind == "open" and opened.seq == 1
    assert reopened.load_snapshot()[0] is not None
    # appends extend the same sequence
    assert reopened.record("tick", i=99) == last + 1
    reopened.close()


def test_compact_respects_n1_snapshot_fallback(tmp_path):
    """Nothing folds while fewer than two intact snapshots exist — the N-1
    fallback must always stay replayable."""
    store = SessionStore.create(str(tmp_path / "s"), rotate_every=3)
    for i in range(10):
        store.record("tick", i=i)
    assert store.compact() == 0                    # no snapshots at all
    store.capture = lambda: {"n": store.journal.last_seq}
    store.flush_snapshot(force=True)
    assert store.compact() == 0                    # one snapshot: still no
    store.record("tick", i=10)
    assert store.compact() >= 1                    # second snapshot -> folds
    store.close()


def test_compact_only_folds_fully_covered_segments(tmp_path):
    """A segment folds only when the OLDEST retained snapshot sits at or
    past its last record: restoring the fallback never needs folded data."""
    path = str(tmp_path / "s")
    store = _ticked_store(path, n=20, snapshot_every=50, rotate_every=3)
    store.snapshots.write({"n": 6}, 6)
    store.snapshots.write({"n": 18}, 18)
    store.capture = None                # no fresh tip snapshot: pin the floor
    folded = store.compact()
    base = store.journal.base
    assert folded >= 1
    assert base["base_seq"] == 6                   # floor = oldest snapshot
    store.close()


def test_compact_every_cadence_triggers_automatically(tmp_path):
    store = _ticked_store(str(tmp_path / "auto"), compact_every=10)
    assert store.journal.base is not None          # folded without compact()
    plain = _ticked_store(str(tmp_path / "plain"))
    assert plain.journal.base is None              # knob off -> no base file
    store.close()
    plain.close()


def test_compacted_session_resumes_identically(micro_library, tmp_path):
    """Recovery-equivalence pin: the same scripted session driven through a
    compacting store and a plain store resumes to the identical state, with
    zero classifier calls, and the compacted store really shed segments."""
    from repro.api.results import to_dict as _td
    paths, states = {}, {}
    for mode, compact_every in (("plain", None), ("compact", 6)):
        path = str(tmp_path / mode)
        store = SessionStore.create(path, encode=_td, snapshot_every=4,
                                    rotate_every=3,
                                    compact_every=compact_every)
        session = MinosSession(micro_library, inventory=_inventory(),
                               budget_w=20000.0, store=store, **GATES)
        _drive_scripted(session)
        session.close()
        paths[mode] = path
        resumed, calls = _resume_spied(path, micro_library)
        assert calls["n"] == 0
        states[mode] = _state(resumed)
        resumed.close()
    assert states["compact"] == states["plain"]
    assert os.path.exists(EventJournal.base_path(
        os.path.join(paths["compact"], JOURNAL_FILE)))
    jp_plain = os.path.join(paths["plain"], JOURNAL_FILE)
    jp_compact = os.path.join(paths["compact"], JOURNAL_FILE)
    assert len(EventJournal.segments(jp_compact)) \
        < len(EventJournal.segments(jp_plain))


def test_corrupt_base_file_warns_and_fails_closed(tmp_path):
    """A damaged base file means the folded records are gone: recovery
    warns, and a store whose surviving snapshot cannot cover the loss
    refuses to fabricate state."""
    path = str(tmp_path / "s")
    store = _ticked_store(path, compact_every=10)
    store.close()
    bp = EventJournal.base_path(os.path.join(path, JOURNAL_FILE))
    with open(bp, "r+b") as f:
        f.seek(5)
        f.write(b"XXXX")
    with pytest.warns(RuntimeWarning, match="journal base"):
        with pytest.raises(StoreError, match="no intact records"):
            # with the base gone, the surviving segments start mid-sequence
            # and chain to nothing: the store refuses to fabricate state
            SessionStore.open_existing(path)


def test_session_store_batch_delegates_and_snapshots_stay_safe(tmp_path):
    """SessionStore.batch() wraps the journal; a snapshot written mid-batch
    (past the unflushed tail) is skipped by load_snapshot after a crash
    that tears the tail — the max_seq guard."""
    store = SessionStore.create(str(tmp_path / "s"))
    with store.batch():
        for i in range(4):
            store.record("tick", i=i)
    assert store.journal.last_seq == 4
    store.close()
    reopened = SessionStore.open_existing(str(tmp_path / "s"))
    assert [r.data["i"] for r in reopened.recovered_records] == [0, 1, 2, 3]
    reopened.close()
