"""Golden-equivalence tests: the vectorized event-stream profiling engine
(PR 1) against the frozen seed implementations in ``repro.legacy``.

Every rewritten hot path — ``integrate_events``/``simulate``, ``ema_filter``,
the batched/cached classifier neighbors, ``linkage``, ``silhouette_score``,
kmeans++ seeding — must reproduce the seed semantics to 1e-9 on fixed-seed
inputs (the busy counter bit-exactly).  Plus behavior tests for the new API
surface: spike-matrix caching, ValueError on fully-excluded neighbor queries
and non-positive bin sizes, and backend autodetection of the Pallas kernels.
"""
import numpy as np
import pytest

from repro import legacy
from repro.core import spikes
from repro.core.algorithm1 import choose_bin_size, select_optimal_freq
from repro.core.classify import FreqPoint, MinosClassifier, WorkloadProfile
from repro.core.clustering import (cosine_distance_matrix,
                                   euclidean_distance_matrix, kmeanspp_init,
                                   linkage, silhouette_score)
from repro.telemetry import TPUPowerModel, simulate
from repro.telemetry.kernel_stream import micro_gemm, micro_idle_burst
from repro.telemetry.simulator import integrate_events

TDP = 200.0
FREQS = [0.6, 0.8, 1.0]


# ---------------------------------------------------------------------------
# telemetry: event integration + full simulate
# ---------------------------------------------------------------------------
def test_integrate_events_matches_dense():
    rng = np.random.default_rng(0)
    for n_events in (1, 7, 300):
        t0 = rng.uniform(0.0, 3.0, n_events)
        t1 = t0 + rng.uniform(1e-6, 0.5, n_events)
        pw = rng.uniform(-50.0, 400.0, n_events)
        edges = np.arange(0, 3500) * 1e-3
        got = integrate_events(t0, t1, pw, edges)
        want = legacy.integrate_events_dense(t0, t1, pw, edges)
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_integrate_events_empty_and_coincident():
    edges = np.linspace(0, 1, 11)
    assert np.all(integrate_events(np.array([]), np.array([]),
                                   np.array([]), edges) == 0)
    # two events sharing both endpoints (np.add.at must accumulate, not clobber)
    t0 = np.array([0.2, 0.2])
    t1 = np.array([0.6, 0.6])
    pw = np.array([10.0, 5.0])
    want = legacy.integrate_events_dense(t0, t1, pw, edges)
    np.testing.assert_allclose(integrate_events(t0, t1, pw, edges), want,
                               rtol=1e-12, atol=1e-12)


@pytest.mark.parametrize("stream_fn,freq", [(micro_gemm, 1.0),
                                            (micro_gemm, 0.6),
                                            (micro_idle_burst, 1.0)])
def test_simulate_matches_seed(stream_fn, freq):
    model = TPUPowerModel()
    a = simulate(stream_fn(), freq, model, seed=11, target_duration=1.0)
    b = legacy.simulate_dense(stream_fn(), freq, model, seed=11,
                              target_duration=1.0)
    np.testing.assert_allclose(a.power_raw, b.power_raw, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(a.power_filtered, b.power_filtered,
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_array_equal(a.busy, b.busy)
    assert a.exec_time == b.exec_time
    assert a.app_sm_util == b.app_sm_util


# ---------------------------------------------------------------------------
# spikes: EMA
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 2, 100, 4099, 20000])
@pytest.mark.parametrize("alpha", [0.5, 0.1, 0.9])
def test_ema_vectorized_matches_loop(n, alpha):
    x = np.random.default_rng(n).uniform(40.0, 600.0, n)
    np.testing.assert_allclose(spikes.ema_filter(x, alpha),
                               legacy.ema_filter_loop(x, alpha),
                               rtol=1e-9, atol=1e-9)


def test_ema_pallas_backend_matches_loop():
    x = np.random.default_rng(3).uniform(40.0, 600.0, 3000)
    got = spikes.ema_filter(x, 0.5, backend="pallas")
    want = legacy.ema_filter_loop(x, 0.5)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)  # f32 kernel


def test_ema_edge_cases():
    assert spikes.ema_filter(np.array([]), 0.5).shape == (0,)
    with pytest.raises(ValueError, match="backend"):
        spikes.ema_filter(np.ones(4), 0.5, backend="cuda")


# ---------------------------------------------------------------------------
# classifier: cache + batched neighbors + error handling
# ---------------------------------------------------------------------------
def _profile(name, level, sm, dram):
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    trace = rng.normal(level * TDP, 9.0, 700)
    scaling = {f: FreqPoint(freq=f, p90=level * f, p95=level * f + 0.03,
                            p99=level * f + 0.07, mean_power=level * f - 0.1,
                            exec_time=1.0 / f) for f in FREQS}
    return WorkloadProfile(name=name, tdp=TDP, power_trace=trace,
                           sm_util=sm, dram_util=dram, exec_time=1.0,
                           scaling=scaling)


@pytest.fixture(scope="module")
def refs():
    return [_profile("gemm", 1.3, 0.95, 0.15),
            _profile("spmv", 0.7, 0.10, 0.90),
            _profile("hybrid", 1.05, 0.55, 0.50),
            _profile("stencil", 0.9, 0.40, 0.70),
            _profile("idle-burst", 1.5, 0.30, 0.20)]


def test_batched_power_neighbors_match_loop(refs):
    clf = MinosClassifier(refs)
    targets = [_profile("t-compute", 1.28, 0.9, 0.2),
               _profile("t-mem", 0.72, 0.15, 0.85)] + refs
    for c in (0.05, 0.1, 0.25):
        got = clf.power_neighbors(targets, bin_size=c)
        for t, (nn, d) in zip(targets, got):
            nn_ref, d_ref = legacy.power_neighbor_loop(refs, t, bin_size=c)
            assert nn.name == nn_ref.name
            assert d == pytest.approx(d_ref, abs=1e-9)


def test_batched_util_neighbors_match_loop(refs):
    clf = MinosClassifier(refs)
    targets = [_profile("t1", 1.0, 0.93, 0.18), _profile("t2", 1.0, 0.2, 0.8)] + refs
    for t, (nn, d) in zip(targets, clf.util_neighbors(targets)):
        nn_ref, d_ref = legacy.util_neighbor_loop(refs, t)
        assert nn.name == nn_ref.name
        assert d == pytest.approx(d_ref, abs=1e-9)


def test_neighbor_exclude_param(refs):
    clf = MinosClassifier(refs)
    target = _profile("t-compute", 1.28, 0.9, 0.2)
    nn_all, _ = clf.power_neighbor(target)
    nn_excl, _ = clf.power_neighbor(target, exclude=nn_all.name)
    assert nn_excl.name != nn_all.name
    want, _ = legacy.power_neighbor_loop(refs, target, 0.1, exclude=nn_all.name)
    assert nn_excl.name == want.name


def test_neighbor_raises_when_all_excluded(refs):
    single = MinosClassifier([refs[0]])
    with pytest.raises(ValueError, match="every reference"):
        single.power_neighbor(refs[0])        # self-match excludes the only ref
    with pytest.raises(ValueError, match="every reference"):
        single.util_neighbor(_profile("x", 1.0, 0.5, 0.5), exclude=refs[0].name)


def test_bad_bin_size_rejected(refs):
    clf = MinosClassifier(refs)
    t = _profile("t", 1.0, 0.5, 0.5)
    for bad in (0, 0.0, -0.1):
        with pytest.raises(ValueError, match="bin_size"):
            clf.power_neighbor(t, bin_size=bad)
        with pytest.raises(ValueError, match="bin_size"):
            clf.spike_matrix(bin_size=bad)
    with pytest.raises(ValueError, match="bin_size"):
        MinosClassifier(refs, bin_size=-1.0)
    with pytest.raises(ValueError, match="bin_size"):
        clf.power_neighbor(t, bin_size=True)   # bools are not bin sizes
    # numpy scalars are legitimate positive numbers
    nn_np, d_np = clf.power_neighbor(t, bin_size=np.float32(0.1))
    nn_py, d_py = clf.power_neighbor(t, bin_size=0.1)
    assert nn_np.name == nn_py.name


def test_spike_matrix_cached_per_bin_size(refs):
    clf = MinosClassifier(refs)
    m1 = clf.spike_matrix(0.1)
    m2 = clf.spike_matrix(0.1)
    assert m1 is m2                            # memoized, not recomputed
    m3 = clf.spike_matrix(0.25)
    assert m3 is not m1 and m3.shape != m1.shape
    np.testing.assert_allclose(
        m1, np.stack([r.spike_vec(0.1) for r in refs]), rtol=1e-12, atol=1e-12)


def test_choose_bin_size_matches_seed_loop(refs):
    clf = MinosClassifier(refs)
    for t in (_profile("t-compute", 1.28, 0.9, 0.2),
              _profile("t-mem", 0.72, 0.15, 0.85)):
        cands = (0.05, 0.1, 0.15, 0.25)
        assert choose_bin_size(t, clf, cands) == \
            legacy.choose_bin_size_loop(t, refs, cands)
        sel = select_optimal_freq(t, clf, cands)
        nn, _ = legacy.power_neighbor_loop(refs, t, bin_size=sel.bin_size)
        assert sel.power_neighbor == nn.name


# ---------------------------------------------------------------------------
# clustering
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ["ward", "average", "complete", "single"])
@pytest.mark.parametrize("n", [2, 5, 18])
def test_linkage_matches_loop(method, n):
    X = np.abs(np.random.default_rng(n).normal(size=(n, 6))) + 0.05
    D = cosine_distance_matrix(X)
    np.testing.assert_allclose(linkage(D, method), legacy.linkage_loop(D, method),
                               rtol=1e-9, atol=1e-9)


def test_silhouette_matches_loop():
    rng = np.random.default_rng(5)
    for _ in range(20):
        n = int(rng.integers(3, 40))
        X = rng.normal(size=(n, 3))
        labels = rng.integers(0, 4, size=n) * 7 - 3   # non-contiguous labels
        assert silhouette_score(X, labels) == \
            pytest.approx(legacy.silhouette_loop(X, labels), abs=1e-9)
    # degenerate inputs take the same early exit
    assert silhouette_score(X[:2], np.array([0, 1])) == 0.0
    assert silhouette_score(X, np.zeros(n, np.int64)) == 0.0


def test_kmeanspp_init_matches_loop_rng_stream():
    rng = np.random.default_rng(9)
    for seed in range(10):
        n = int(rng.integers(4, 30))
        X = rng.normal(size=(n, 2))
        k = int(rng.integers(2, min(6, n + 1)))
        np.testing.assert_array_equal(
            kmeanspp_init(X, k, np.random.default_rng(seed)),
            legacy.kmeanspp_init_loop(X, k, np.random.default_rng(seed)))
    # identical points: the tot<=0 fallback draws the same stream too
    Z = np.ones((6, 2))
    np.testing.assert_array_equal(
        kmeanspp_init(Z, 3, np.random.default_rng(1)),
        legacy.kmeanspp_init_loop(Z, 3, np.random.default_rng(1)))


# ---------------------------------------------------------------------------
# kernels: backend autodetection
# ---------------------------------------------------------------------------
def test_spike_hist_interpret_autodetect():
    import jax
    from repro.kernels.spike_hist import spike_hist_pallas

    p = jax.random.uniform(jax.random.key(0), (777,), minval=0.0, maxval=2.3)
    got = np.asarray(spike_hist_pallas(p, 15))             # interpret=None
    want = np.asarray(spike_hist_pallas(p, 15, interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    r = np.asarray(p, np.float64)
    counts, _ = np.histogram(r[(r >= 0.5) & (r < 2.0)],
                             bins=15, range=(0.5, 2.0))
    hi = np.sum(r >= 2.0)                                   # top bin clips
    counts[-1] += hi
    np.testing.assert_allclose(got, counts.astype(np.float64), atol=1e-6)


@pytest.mark.parametrize("n", [96 * 128, 1280, 130, 125 * 128, 250 * 128])
def test_spike_hist_partial_block_rows(n):
    """Row counts that don't divide the requested block (the seed shrank the
    block with a decrement search; the engine pads rows instead) still count
    every sample exactly once."""
    import jax
    from repro.kernels.spike_hist import spike_hist_pallas

    p = jax.random.uniform(jax.random.key(n), (n,), minval=0.4, maxval=2.2)
    got = np.asarray(spike_hist_pallas(p, 15, interpret=True))
    assert got.sum() == pytest.approx(float(np.sum(np.asarray(p) >= 0.5)))
