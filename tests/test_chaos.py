"""Fault-tolerance tests (ISSUE 5 acceptance): device health state, failure/
migration/restore paths with the classifier call-count pinned at ZERO across
migrations, elastic shrink of multi-chip jobs, straggler-driven proactive
drain, the no-failure byte-identity pin (an FT-wired fleet that never fails
equals the plain path), the session surface + JSON codec for fleet events,
and a hypothesis property: the packed budget is never exceeded under ANY
failure schedule.  Plus the satellite pins: ``ElasticPlan`` loss accounting,
``rescale_batch``'s per-device-batch contract, ``StragglerMonitor`` aging,
and the ``core.baselines`` all-excluded contract."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (MinosSession, OnlineCapController, ReferenceLibrary,
                       SessionReport, TPUPowerModel,
                       count_classifier_calls as _count_classifier_calls,
                       from_json, stream_profile_once,
                       stream_profile_workload, stream_telemetry, to_json)
from repro.configs.base import MeshConfig
from repro.core.baselines import mean_power_neighbor, util_only_neighbor
from repro.fleet import (DEGRADED, FAILED, HEALTHY, DeviceInventory,
                         FleetCapController, FleetChunk, FleetTelemetryMux,
                         VariabilityModel)
from repro.ft import (FleetStragglerAdapter, StragglerMonitor, plan_new_mesh,
                      rescale_batch)
from repro.telemetry.kernel_stream import (micro_gemm, micro_idle_burst,
                                           micro_spmv_compute,
                                           micro_spmv_memory, micro_stencil)

MODEL = TPUPowerModel()
TDP = MODEL.spec.tdp_w
FREQS = (0.6, 0.8, 1.0)
GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)


@pytest.fixture(scope="module")
def micro_library():
    return ReferenceLibrary(
        (stream_profile_workload(s, MODEL, FREQS, TDP, seed=i,
                                 target_duration=0.5)
         for i, s in enumerate([micro_gemm(), micro_idle_burst(),
                                micro_spmv_memory(), micro_stencil()])),
        built_on="tpu-v5e")


def _job_stream(stream_fn, device, seed):
    return stream_telemetry(stream_fn(), 1.0, device.power_model(),
                            seed=seed, target_duration=0.5,
                            chunk_samples=100, device_id=device.device_id)


# ---------------------------------------------------------------------------
# inventory health state
# ---------------------------------------------------------------------------
def test_inventory_health_lifecycle():
    inv = DeviceInventory.generate(3, VariabilityModel.none(), seed=0)
    ids = [d.device_id for d in inv]
    assert inv.device_health == {i: HEALTHY for i in ids}
    assert [d.device_id for d in inv.healthy] == ids
    inv.mark_failed(ids[0])
    inv.mark_degraded(ids[1])
    assert inv.health(ids[0]) == FAILED and not inv.is_healthy(ids[0])
    assert inv.health(ids[1]) == DEGRADED
    assert [d.device_id for d in inv.healthy] == [ids[2]]
    assert inv.failed_ids == [ids[0]]
    assert inv.healthy_nameplate_w == pytest.approx(
        inv.nameplate_w - inv.get(ids[0]).nameplate_w)
    inv.restore(ids[0])
    inv.restore(ids[1])
    assert inv.device_health == {i: HEALTHY for i in ids}
    with pytest.raises(KeyError):
        inv.mark_failed("tpu-v9x/000")
    with pytest.raises(KeyError):
        inv.health("nope")


# ---------------------------------------------------------------------------
# failure -> migration (the zero-classification pin)
# ---------------------------------------------------------------------------
def _decided_fleet(micro_library, n_devices=3, seed=0):
    """A fleet with every job decided (streams fully pumped)."""
    inv = DeviceInventory.generate(n_devices, VariabilityModel(), seed=seed)
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               **GATES)
    mux = FleetTelemetryMux()
    for i, fn in enumerate([micro_gemm, micro_spmv_memory]):
        meta, chunks = _job_stream(fn, inv[i], seed=i)
        mux.add_job(fleet.admit(inv[i], meta, chips=4), meta, chunks)
    fleet.run(mux)
    return inv, fleet


def test_fail_device_migrates_decided_jobs_without_classifying(micro_library):
    inv, fleet = _decided_fleet(micro_library)
    job = next(iter(fleet.jobs.values()))
    old_device, old_plan = job.device, job.plan
    assert old_plan is not None
    calls = _count_classifier_calls(fleet.clf)
    repacks_before = len(fleet.repacks)

    events = fleet.fail_device(old_device.device_id)

    assert calls["n"] == 0                     # the acceptance pin
    assert [e.kind for e in events] == ["fail", "migrate"]
    assert events[1].job_id == job.job_id
    assert events[1].to_device_id == job.device.device_id
    assert job.device.device_id != old_device.device_id
    assert inv.health(old_device.device_id) == FAILED
    # the plan was re-costed on the new device's effective TDP: same cap,
    # same selection, new watts frame
    assert job.plan.cap == old_plan.cap
    assert job.plan.selection == old_plan.selection
    assert job.plan.device_id == job.device.device_id
    rel = old_plan.predicted_p90_w / old_device.effective_tdp_w
    assert job.plan.predicted_p90_w == pytest.approx(
        rel * job.device.effective_tdp_w, rel=1e-12)
    # the cap was re-asserted on the new device's actuator
    assert job.actuator.device_id == job.device.device_id
    assert job.actuator.get_cap() == job.decision.cap
    # the failure ended in exactly one repack, still inside the budget
    assert len(fleet.repacks) == repacks_before + 1
    assert fleet.repacks[-1].planned_power_w <= fleet.budget_w
    # the failed device hosts nothing
    assert all(j.device.device_id != old_device.device_id
               for j in fleet.jobs.values())


def test_fail_device_requires_inventory(micro_library):
    fleet = FleetCapController(micro_library, budget_w=1e9, **GATES)
    with pytest.raises(ValueError, match="inventory"):
        fleet.fail_device("tpu-v5e/000")
    session = MinosSession(micro_library, **GATES)     # no inventory
    with pytest.raises(ValueError, match="inventory"):
        session.fail_device("tpu-v5e/000")


def test_fail_device_mid_profile_restarts_on_new_device(micro_library):
    inv = DeviceInventory.generate(2, VariabilityModel(), seed=3)
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               **GATES)
    meta, chunks = _job_stream(micro_gemm, inv[0], seed=5)
    job_id = fleet.admit(inv[0], meta, chips=2)
    chunks = list(chunks)
    fleet.ingest_chunk(job_id, chunks[0])      # some partial trace
    job = fleet.jobs[job_id]
    assert job.decision is None and job.builder.n_ingested > 0

    events = fleet.fail_device(inv[0].device_id)
    assert [e.kind for e in events] == ["fail", "migrate"]
    assert events[1].detail == "reprofile"
    assert job.device is inv[1]
    # the partial trace died with the device; the builder restarted in the
    # new device's normalization frame
    assert job.builder.n_ingested == 0
    assert job.builder.tdp == inv[1].effective_tdp_w
    # stale chunks from the dead device are discarded on the mux path
    stale = FleetChunk(job_id, inv[0].device_id, 1.0, chunks[1])
    assert fleet.ingest(stale) is None
    assert job.builder.n_ingested == 0
    # the un-tagged feed path can't tell stale from re-run: it demands an
    # explicit restart instead of mixing frames
    with pytest.raises(ValueError, match="restart"):
        fleet.ingest_chunk(job_id, chunks[1])
    # a re-run on the new device decides normally
    meta2, chunks2 = _job_stream(micro_gemm, inv[1], seed=6)
    fleet.restart_profile(job_id, meta2)
    for chunk in chunks2:
        if fleet.ingest_chunk(job_id, chunk) is not None:
            break
    decision = fleet.finalize_job(job_id)
    assert decision.device_id == inv[1].device_id


def test_fail_device_strands_jobs_when_no_healthy_device(micro_library):
    inv, fleet = _decided_fleet(micro_library, n_devices=2)
    calls = _count_classifier_calls(fleet.clf)
    fleet.fail_device(inv[1].device_id)        # second job moves to inv[0]
    events = fleet.fail_device(inv[0].device_id)
    assert {e.kind for e in events} == {"fail", "strand"}
    assert all(j.plan is None for j in fleet.jobs.values())
    assert fleet.repacks[-1].placed == []      # stranded jobs draw nothing
    assert calls["n"] == 0
    # decisions survive stranding: capacity can come back later
    assert all(j.decision is not None for j in fleet.jobs.values())

    # ...and when it does, restore re-places the strandees — still without
    # a single classification
    events = fleet.restore_device(inv[1].device_id)
    assert [e.kind for e in events] == ["restore", "migrate", "migrate"]
    assert all(j.plan is not None for j in fleet.jobs.values())
    assert all(j.device is inv[1] for j in fleet.jobs.values())
    assert len(fleet.repacks[-1].placed) == 2
    assert calls["n"] == 0


def test_restore_replaces_jobs_stranded_by_a_degrade_drain(micro_library):
    """A degrade drain with nowhere to go strands the job on the straggler;
    restoring capacity elsewhere must re-place it (zero classifier calls)."""
    inv, fleet = _decided_fleet(micro_library, n_devices=2)
    calls = _count_classifier_calls(fleet.clf)
    fleet.fail_device(inv[1].device_id)        # everyone ends up on inv[0]
    events = fleet.degrade_device(inv[0].device_id)
    assert {e.kind for e in events} == {"degrade", "strand"}
    assert all(j.plan is None for j in fleet.jobs.values())

    events = fleet.restore_device(inv[1].device_id)
    assert [e.kind for e in events] == ["restore", "migrate", "migrate"]
    assert all(j.plan is not None for j in fleet.jobs.values())
    assert all(j.device is inv[1] for j in fleet.jobs.values())
    assert len(fleet.repacks[-1].placed) == 2
    assert calls["n"] == 0


def test_span_job_deciding_on_degraded_device_drains_immediately(
        micro_library):
    """degrade_device's deferred contract must hold for multi-chip spans
    too: a span job that decides while a member is degraded shrinks the bad
    member away at decision time."""
    inv = DeviceInventory.generate(3, VariabilityModel(), seed=9)
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               **GATES)
    meta, chunks = _job_stream(micro_gemm, inv[1], seed=4)
    job_id = fleet.admit(inv[1], meta, chips=4, devices=(inv[0], inv[1]))
    chunks = list(chunks)
    fleet.ingest_chunk(job_id, chunks[0])
    fleet.degrade_device(inv[0].device_id)     # undecided span: no-op now
    job = fleet.jobs[job_id]
    assert job.decision is None and inv[0] in job.devices

    for chunk in chunks[1:]:
        if fleet.ingest_chunk(job_id, chunk) is not None:
            break
    fleet.finalize_job(job_id)
    assert any(e.kind == "shrink" and e.job_id == job_id
               for e in fleet.events)
    assert inv[0] not in job.devices
    assert job.chips == 2 and job.plan.chips == 2
    assert job.plan.device_id == inv[1].device_id


def test_restore_device_rejoins_placement_pool(micro_library):
    inv, fleet = _decided_fleet(micro_library)
    failed_id = inv[0].device_id
    fleet.fail_device(failed_id)
    meta, _ = _job_stream(micro_gemm, inv[0], seed=9)
    with pytest.raises(ValueError, match="device is failed"):
        fleet.admit(inv[0], meta, job_id="late-arrival")
    events = fleet.restore_device(failed_id)
    assert events[0].kind == "restore" and "failed" in events[0].detail
    assert inv.health(failed_id) == HEALTHY
    fleet.admit(inv[0], meta, job_id="late-arrival")   # admissible again


# ---------------------------------------------------------------------------
# multi-chip jobs: elastic shrink on partial span loss
# ---------------------------------------------------------------------------
def test_partial_span_loss_shrinks_through_elastic_remesh(micro_library):
    inv = DeviceInventory.generate(4, VariabilityModel(), seed=1)
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               **GATES)
    span = (inv[0], inv[1], inv[2])
    meta, chunks = _job_stream(micro_gemm, inv[0], seed=2)
    job_id = fleet.admit(inv[0], meta, chips=12, devices=span,
                         global_batch=96)
    for chunk in chunks:
        if fleet.ingest_chunk(job_id, chunk) is not None:
            break
    fleet.finalize_job(job_id)
    job = fleet.jobs[job_id]
    assert job.plan.chips == 12
    calls = _count_classifier_calls(fleet.clf)

    events = fleet.fail_device(inv[1].device_id)
    assert calls["n"] == 0
    assert [e.kind for e in events] == ["fail", "shrink"]
    # 12 chips over 3 devices -> lose 4, survivors hold 8 = a power of two
    assert job.chips == 8
    assert job.plan.chips == 8
    assert {d.device_id for d in job.devices} == \
        {inv[0].device_id, inv[2].device_id}
    # per-device batch constant: 96/12 = 8 per chip -> 64 on 8 chips
    assert job.global_batch == 64
    assert "chips 12->8" in events[1].detail

    # losing another span member drops to the largest power of two (4)
    events = fleet.fail_device(inv[2].device_id)
    assert events[1].kind == "shrink"
    assert job.chips == 4 and job.global_batch == 32
    assert job.device is inv[0]
    assert calls["n"] == 0


def test_partial_span_loss_of_primary_restarts_profiling(micro_library):
    inv = DeviceInventory.generate(3, VariabilityModel(), seed=6)
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               **GATES)
    meta, chunks = _job_stream(micro_gemm, inv[0], seed=7)
    job_id = fleet.admit(inv[0], meta, chips=4, devices=(inv[0], inv[1]))
    fleet.ingest_chunk(job_id, next(iter(chunks)))
    job = fleet.jobs[job_id]

    events = fleet.fail_device(inv[0].device_id)   # the profiling frame
    assert events[1].kind == "shrink"
    assert job.chips == 2 and job.device is inv[1]
    # the partial trace was captured on the lost primary: restart there too
    assert job.builder.n_ingested == 0
    assert job.builder.tdp == inv[1].effective_tdp_w
    with pytest.raises(ValueError, match="restart"):
        fleet.ingest_chunk(job_id, next(iter(chunks)))
    meta2, chunks2 = _job_stream(micro_gemm, inv[1], seed=8)
    fleet.restart_profile(job_id, meta2)
    fleet.ingest_chunk(job_id, next(iter(chunks2)))   # feeds again


def test_admit_validates_span(micro_library):
    inv = DeviceInventory.generate(3, VariabilityModel.none(), seed=0)
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               **GATES)
    meta, _ = _job_stream(micro_gemm, inv[0], seed=0)
    with pytest.raises(ValueError, match="part of the span"):
        fleet.admit(inv[0], meta, chips=4, devices=(inv[1], inv[2]))
    with pytest.raises(ValueError, match="divide evenly"):
        fleet.admit(inv[0], meta, chips=5, devices=(inv[0], inv[1]))
    with pytest.raises(ValueError, match="duplicate device"):
        fleet.admit(inv[0], meta, chips=4, devices=(inv[0], inv[0]))


# ---------------------------------------------------------------------------
# straggler-driven proactive drain
# ---------------------------------------------------------------------------
def test_straggler_adapter_flags_slow_device():
    adapter = FleetStragglerAdapter(StragglerMonitor(min_samples=5, k=4.0))

    class _FC:                                  # minimal FleetChunk stand-in
        def __init__(self, device_id, t_end):
            self.device_id, self.t_end = device_id, t_end

    for i in range(8):
        for d, cadence in (("dev/0", 0.05), ("dev/1", 0.05), ("dev/2", 0.5)):
            adapter.observe(_FC(d, i * cadence))
    assert adapter.degraded() == ["dev/2"]
    assert adapter.devices() == ["dev/0", "dev/1", "dev/2"]
    assert adapter.dead() == []


def test_degrade_drains_decided_jobs_and_migrates_on_decide(micro_library):
    inv = DeviceInventory.generate(3, VariabilityModel(), seed=4)
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               **GATES)
    # job A decides on inv[0]; job B stays mid-profile on inv[0]
    meta_a, chunks_a = _job_stream(micro_gemm, inv[0], seed=1)
    job_a = fleet.admit(inv[0], meta_a, chips=2, job_id="a")
    for chunk in chunks_a:
        if fleet.ingest_chunk(job_a, chunk) is not None:
            break
    fleet.finalize_job(job_a)
    meta_b, chunks_b = _job_stream(micro_spmv_memory, inv[0], seed=2)
    chunks_b = list(chunks_b)
    job_b = fleet.admit(inv[0], meta_b, chips=2, job_id="b")
    fleet.ingest_chunk(job_b, chunks_b[0])
    calls = _count_classifier_calls(fleet.clf)

    events = fleet.degrade_device(inv[0].device_id)
    assert calls["n"] == 0                      # drain never classifies
    assert [e.kind for e in events] == ["degrade", "migrate"]
    assert events[1].job_id == "a"              # only the decided job moved
    assert fleet.jobs["a"].device.device_id != inv[0].device_id
    assert fleet.jobs["b"].device is inv[0]     # still profiling in place
    assert fleet.degrade_device(inv[0].device_id) == []   # idempotent

    # job B keeps its partial trace (a slow chip's power frame is valid)
    # and migrates the moment it decides
    assert fleet.jobs["b"].builder.n_ingested > 0
    for chunk in chunks_b[1:]:
        if fleet.ingest_chunk(job_b, chunk) is not None:
            break
    fleet.finalize_job(job_b)
    assert fleet.jobs["b"].device.device_id != inv[0].device_id
    assert any(e.kind == "migrate" and e.job_id == "b" for e in fleet.events)


def test_auto_degrade_from_straggler_adapter(micro_library):
    inv = DeviceInventory.generate(3, VariabilityModel.none(), seed=0)
    adapter = FleetStragglerAdapter(StragglerMonitor(min_samples=5, k=4.0))
    fleet = FleetCapController(micro_library, budget_w=1e9, inventory=inv,
                               straggler_adapter=adapter, **GATES)
    streams = {}
    for i, fn in enumerate([micro_gemm, micro_spmv_memory, micro_stencil]):
        meta, chunks = stream_telemetry(
            fn(), 1.0, inv[i].power_model(), seed=i, target_duration=0.5,
            chunk_samples=50, device_id=inv[i].device_id)
        streams[fleet.admit(inv[i], meta, chips=2)] = (meta, list(chunks))
    # interleave with synthetic arrival times: device 2's cadence is 10x
    rounds = min(len(c) for _, c in streams.values())
    for r in range(rounds):
        for i, (job_id, (meta, chunks)) in enumerate(streams.items()):
            cadence = 0.5 if i == 2 else 0.05
            fleet.ingest(FleetChunk(job_id, inv[i].device_id,
                                    r * cadence, chunks[r]))
    assert inv.health(inv[2].device_id) == DEGRADED
    assert any(e.kind == "degrade" for e in fleet.events)


# ---------------------------------------------------------------------------
# the byte-identity pin: FT wiring that never fires changes nothing
# ---------------------------------------------------------------------------
def test_no_failure_fleet_byte_identical_to_no_ft_path(micro_library):
    inv = DeviceInventory.generate(3, VariabilityModel(), seed=7)
    jobs = [(micro_gemm, 0), (micro_spmv_memory, 1), (micro_spmv_compute, 2)]

    def run_fleet(**ft_kw):
        fleet = FleetCapController(micro_library, budget_w=2e4, **GATES,
                                   **ft_kw)
        mux = FleetTelemetryMux()
        for (fn, seed), dev in zip(jobs, inv):
            meta, chunks = _job_stream(fn, dev, seed=seed)
            mux.add_job(fleet.admit(dev, meta, chips=4), meta, chunks)
        return fleet.run(mux)

    plain = run_fleet()
    wired = run_fleet(inventory=inv,
                      straggler_adapter=FleetStragglerAdapter())
    assert wired.decisions == plain.decisions          # full dataclass eq
    assert list(wired.decisions) == list(plain.decisions)
    assert wired.schedule.placed == plain.schedule.placed
    assert wired.schedule.deferred == plain.schedule.deferred
    assert wired.repacks == plain.repacks
    assert wired.chunks_dropped == plain.chunks_dropped
    assert wired.events == [] and wired.migrations == 0


# ---------------------------------------------------------------------------
# session surface + codec
# ---------------------------------------------------------------------------
def test_session_fail_restore_surface_and_report(micro_library):
    inv = DeviceInventory.generate({"tpu-v5e": 2, "tpu-v5p": 1},
                                   VariabilityModel(), seed=5)
    session = MinosSession(micro_library, inventory=inv, budget_w=1e9,
                           **GATES)
    handles = []
    for i, fn in enumerate([micro_gemm, micro_spmv_memory]):
        h = session.submit(_job_stream(fn, inv[i], seed=i), device=inv[i],
                           chips=4)
        h.run()
        handles.append(h)
    calls = _count_classifier_calls(session.classifier)

    events = session.fail_device(inv[0].device_id)
    assert calls["n"] == 0
    assert session.device_health[inv[0].device_id] == FAILED
    assert handles[0].device.device_id != inv[0].device_id
    assert handles[0].plan().device_id == handles[0].device.device_id

    report = session.run()
    assert report.failures == 1 and report.migrations == 1
    assert report.events == session._fleet.events
    assert report.device_health == session.device_health
    # new submits round-robin over healthy devices only
    got = {session.submit(_job_stream(micro_stencil, inv[1], seed=9))
           .device.device_id for _ in range(4)}
    assert inv[0].device_id not in got

    session.restore_device(inv[0].device_id)
    assert session.device_health[inv[0].device_id] == HEALTHY
    report = session.report()
    assert [e.kind for e in report.events] == ["fail", "migrate", "restore"]
    # the whole FT trail round-trips through the JSON codec
    back = SessionReport.from_json(report.to_json())
    assert back == report
    assert [e.kind for e in back.events] == ["fail", "migrate", "restore"]
    assert back.device_health == report.device_health
    event = report.events[1]
    assert from_json(to_json(event)) == event


def test_session_reprofile_after_mid_profile_failure(micro_library):
    inv = DeviceInventory.generate(2, VariabilityModel(), seed=8)
    session = MinosSession(micro_library, inventory=inv, budget_w=1e9,
                           **GATES)
    meta, chunks = _job_stream(micro_gemm, inv[0], seed=3)
    handle = session.submit(meta, device=inv[0], chips=2)
    handle.feed(next(iter(chunks)))                    # one chunk only
    session.fail_device(inv[0].device_id)
    assert not handle.decided and handle.fraction == 0.0
    handle.reprofile(micro_gemm(), seed=4, target_duration=0.5,
                     chunk_samples=100)
    decision = handle.run()
    assert decision.device_id == inv[1].device_id
    with pytest.raises(ValueError, match="already decided"):
        handle.reprofile(micro_gemm(), seed=4, target_duration=0.5)
    with pytest.raises(TypeError, match="KernelStream"):
        handle.reprofile(42)


def test_from_config_stragglers(micro_library):
    cfg = {"devices": 2, "stragglers": {"window": 10, "k": 4.0}}
    session = MinosSession.from_config(cfg, references=micro_library)
    adapter = session._fleet.straggler_adapter
    assert isinstance(adapter, FleetStragglerAdapter)
    assert adapter.monitor.window == 10 and adapter.monitor.k == 4.0
    with pytest.raises(ValueError, match="unknown straggler keys"):
        MinosSession.from_config({"devices": 2, "stragglers": {"win": 1}},
                                 references=micro_library)
    with pytest.raises(ValueError, match="stragglers"):
        MinosSession.from_config({"devices": 2, "stragglers": 7},
                                 references=micro_library)
    assert MinosSession.from_config(
        {"devices": 2}, references=micro_library)._fleet.straggler_adapter \
        is None


# ---------------------------------------------------------------------------
# property: the packed budget survives ANY failure schedule
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=3 * 3 * 12 - 1),
                min_size=0, max_size=6))
def test_budget_never_exceeded_across_any_failure_schedule(encoded):
    """Each encoded int unpacks to (chunk index 0..11, action 0..2,
    device 0..2); whatever the churn, every repack stays inside the
    budget and chaos handling never classifies."""
    lib = _PROPERTY_LIB[0]
    inv = DeviceInventory.generate(3, VariabilityModel(), seed=2)
    jobs = [(micro_gemm, 0), (micro_spmv_memory, 1), (micro_stencil, 2)]
    budget = 0.75 * sum(4 * d.nameplate_w for d in inv)
    fleet = FleetCapController(lib, budget_w=budget, inventory=inv, **GATES)
    mux = FleetTelemetryMux()
    for (fn, seed), dev in zip(jobs, inv):
        meta, chunks = _job_stream(fn, dev, seed=seed)
        mux.add_job(fleet.admit(dev, meta, chips=4), meta, chunks)

    schedule = sorted(((e // 9) % 12, (e // 3) % 3, e % 3) for e in encoded)
    calls = _count_classifier_calls(fleet.clf)

    def apply_due(n):
        while schedule and n >= schedule[0][0]:
            _, action, dev_idx = schedule.pop(0)
            device_id = inv[dev_idx].device_id
            before = calls["n"]
            if action == 0:
                fleet.fail_device(device_id)
                mux.drop_device(device_id)
            elif action == 1:
                fleet.degrade_device(device_id)
            else:
                fleet.restore_device(device_id)
            assert calls["n"] == before        # chaos handling: 0 calls

    n = 0
    for fchunk in mux:
        apply_due(n)
        fleet.ingest(fchunk)                   # deciding MAY classify
        n += 1
    apply_due(12)
    for res in fleet.repacks:
        assert res.planned_power_w <= res.budget_w + 1e-9


_PROPERTY_LIB = []


@pytest.fixture(autouse=True)
def _seed_property_lib(micro_library):
    _PROPERTY_LIB[:] = [micro_library]


# ---------------------------------------------------------------------------
# satellites: elastic loss accounting + rescale contract
# ---------------------------------------------------------------------------
def test_elastic_plan_reports_actual_losses_and_idles():
    mesh = MeshConfig((16, 16), ("data", "model"))
    plan = plan_new_mesh(mesh, surviving_devices=208)
    # 256 -> 208 survivors: 48 actually lost; data 13 rounds down to 8,
    # idling 208 - 128 = 80 healthy devices (NOT "lost")
    assert plan.lost_devices == 48
    assert plan.idle_devices == 80
    assert plan.new.num_devices == 128
    assert plan.surviving_devices == 208
    # no loss, no rounding: nothing lost, nothing idle
    full = plan_new_mesh(mesh, surviving_devices=256)
    assert full.lost_devices == 0 and full.idle_devices == 0
    assert full.new.num_devices == 256


def test_rescale_batch_keeps_integer_per_device_batch():
    mesh = MeshConfig((16, 16), ("data", "model"))
    plan = plan_new_mesh(mesh, surviving_devices=144)   # data 16 -> 8
    assert rescale_batch(256, plan) == 128              # 16 per slice, kept
    # a non-divisible global batch keeps the floored per-device batch
    # instead of truncating the float ratio (250*8/16 = 125 would change
    # the per-device batch from 15 to 15.625)
    assert rescale_batch(250, plan) == 15 * 8
    assert rescale_batch(3, plan) == 8                  # min 1 per device


# ---------------------------------------------------------------------------
# satellites: straggler aging + baselines all-excluded contract
# ---------------------------------------------------------------------------
def test_straggler_monitor_ages_out_silent_hosts():
    mon = StragglerMonitor(window=10, min_samples=3, k=4.0)
    for step in range(5):
        mon.record(9, step, 5.0)               # host 9 then goes silent
    for host in range(3):
        for step in range(30):
            mon.record(host, step, 1.0)
    # host 9's stale window is evicted: it is dead, not a straggler, and
    # healthy_hosts no longer vouches for it
    assert mon.dead_hosts() == [9]
    assert 9 not in mon.stragglers()
    assert mon.healthy_hosts([0, 1, 2, 9]) == [0, 1, 2]
    # a host that reports again comes back from the dead
    mon.record(9, 31, 1.0)
    assert mon.dead_hosts() == []
    assert 9 in mon.healthy_hosts([0, 1, 2, 9])


def test_baselines_raise_on_all_excluded(micro_library):
    target = stream_profile_once(micro_gemm(), MODEL, TDP, seed=1,
                                 target_duration=0.5)
    refs = [r for r in micro_library.profiles if r.name == target.name]
    assert refs                                 # only the self-match left
    with pytest.raises(ValueError, match="every reference is excluded"):
        mean_power_neighbor(target, refs)
    with pytest.raises(ValueError, match="every reference is excluded"):
        util_only_neighbor(target, refs)
    only = [r for r in micro_library.profiles if r.name != target.name][0]
    with pytest.raises(ValueError, match="every reference is excluded"):
        mean_power_neighbor(target, [only], exclude=only.name)
