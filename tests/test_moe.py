"""MoE dispatch/combine semantics + aux loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.common import ParamStore, SMOKE_TOPO
from repro.models.moe import MoE


def _moe(E=4, k=2, d=32, f=64, S=16, cf=1.25, placement="ep"):
    m = MoE("moe", d_model=d, num_experts=E, top_k=k, d_ff=f,
            group_size=S, capacity_factor=cf, placement=placement)
    store = ParamStore()
    m.register(store)
    return m, store.init(jax.random.key(0))["moe"]


@pytest.mark.parametrize("placement", ["ep", "gathered", "ep_decode", "tp_decode"])
def test_moe_forward_finite(placement):
    m, p = _moe(placement=placement)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32) * 0.5
    out, aux = m(p, x, SMOKE_TOPO)
    assert out.shape == x.shape
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.isfinite(float(aux))


def test_moe_2d_decode_input():
    m, p = _moe()
    x = jax.random.normal(jax.random.key(2), (8, 32), jnp.float32)
    out, aux = m(p, x, SMOKE_TOPO)
    assert out.shape == x.shape


def test_aux_loss_balanced_is_one():
    """With a uniform router, aux = E * sum(f_t * f_p) ~= 1."""
    m, p = _moe(E=8, k=1, S=64)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform logits
    # one-hot argmax of uniform probs is degenerate; spread tokens by noise
    x = jax.random.normal(jax.random.key(3), (4, 64, 32), jnp.float32)
    p["router"] = jax.random.normal(jax.random.key(4), p["router"].shape) * 1e-3
    out, aux = m(p, x, SMOKE_TOPO)
    assert 0.8 < float(aux) < 1.5


def test_capacity_drops_tokens():
    """cf -> 0 forces drops: output collapses toward zero (residual only)."""
    m_full, p = _moe(cf=8.0)        # effectively no drops
    m_tight, _ = _moe(cf=0.10)      # C=1: most tokens dropped
    x = jax.random.normal(jax.random.key(5), (2, 16, 32), jnp.float32)
    out_full, _ = m_full(p, x, SMOKE_TOPO)
    out_tight, _ = m_tight(p, x, SMOKE_TOPO)
    n_full = float(jnp.sum(jnp.abs(out_full)))
    n_tight = float(jnp.sum(jnp.abs(out_tight)))
    assert n_tight < n_full


def test_dispatch_combine_identity_for_identity_experts():
    """With identity-ish experts and cf large, each token's output equals
    the weighted sum of its top-k expert outputs (here: same for all)."""
    m, p = _moe(E=4, k=2, d=16, f=16, S=8, cf=4.0)
    p = dict(p)
    # make every expert compute the same linear map -> routing invisible
    w_g = jnp.tile(p["w_gate"][0:1], (4, 1, 1))
    w_u = jnp.tile(p["w_up"][0:1], (4, 1, 1))
    w_d = jnp.tile(p["w_down"][0:1], (4, 1, 1))
    p.update(w_gate=w_g, w_up=w_u, w_down=w_d)
    x = jax.random.normal(jax.random.key(6), (1, 8, 16), jnp.float32) * 0.3
    out, _ = m(p, x, SMOKE_TOPO)
    # reference: the dense mlp with expert 0's weights
    g = x @ w_g[0]
    u = x @ w_u[0]
    want = (jax.nn.silu(g) * u) @ w_d[0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
