"""Import-boundary check for the facade migration (PR 4 satellite).

The entry points migrated onto ``repro.api.MinosSession`` must reach the
repro package only through the facade surface: ``repro.api`` (and
``repro.fleet`` for fleet-specific types), importing only names those
packages actually export.  This keeps the examples/benchmarks honest as
documentation — if they needed a deep import, the facade would be
incomplete.  Add files to ``FACADE_FILES`` as they migrate.
"""
import ast
import os

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# entry points that have been migrated onto the facade
FACADE_FILES = [
    "examples/quickstart.py",
    "examples/fleet_power_planner.py",
    "benchmarks/bench_fleet.py",
    "benchmarks/bench_fleet_scale.py",
    "benchmarks/bench_online_cap.py",
    "benchmarks/bench_chaos.py",
    "benchmarks/bench_recovery.py",
    "benchmarks/bench_discovery.py",
]

ALLOWED_MODULES = ("repro.api", "repro.fleet")


def _repro_imports(path: str):
    """Yield (module, names, lineno) for every repro import in ``path``."""
    with open(os.path.join(REPO, path)) as f:
        tree = ast.parse(f.read(), filename=path)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "repro" or alias.name.startswith("repro."):
                    yield alias.name, [], node.lineno
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro" or mod.startswith("repro."):
                yield mod, [a.name for a in node.names], node.lineno


@pytest.mark.parametrize("path", FACADE_FILES)
def test_facade_files_import_only_api_and_fleet(path):
    violations = []
    for mod, names, lineno in _repro_imports(path):
        if mod not in ALLOWED_MODULES:
            violations.append(f"{path}:{lineno}: imports {mod!r} "
                              f"(allowed: {', '.join(ALLOWED_MODULES)})")
    assert not violations, "\n".join(violations)


@pytest.mark.parametrize("path", FACADE_FILES)
def test_facade_files_import_only_public_names(path):
    import repro.api
    import repro.fleet
    public = {"repro.api": set(repro.api.__all__),
              "repro.fleet": set(repro.fleet.__all__)}
    violations = []
    for mod, names, lineno in _repro_imports(path):
        for name in names:
            if mod in public and name not in public[mod]:
                violations.append(f"{path}:{lineno}: {name!r} is not a "
                                  f"public (__all__) name of {mod}")
    assert not violations, "\n".join(violations)


def test_api_all_names_exist():
    """Every advertised facade name must actually resolve."""
    import repro.api
    missing = [n for n in repro.api.__all__ if not hasattr(repro.api, n)]
    assert not missing, f"repro.api.__all__ names missing: {missing}"
