"""Import-boundary checks, delegated to the minoslint layering pass.

The hand-rolled facade scan this file used to carry (PR 4 satellite) is
retired: ``repro.lint.contracts`` is now the single source of truth for
the facade list, the package DAG, and the legacy quarantine, and
``repro.lint.layering`` is the one engine that walks imports.  This test
drives that engine over the live tree so the boundary stays enforced in
plain ``pytest`` runs too (CI additionally runs the full
``python -m repro.lint`` job).

The runtime half — facade files importing only *public* (``__all__``)
names, and those names actually resolving — stays here: it needs the
imported modules, which the static pass never loads.
"""
import ast
import os
from pathlib import Path

import pytest

from repro.lint import LintContext, SourceFile
from repro.lint.contracts import FACADE_FILES
from repro.lint.layering import run_pass

REPO = Path(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _load(paths):
    files = [SourceFile(Path(p).as_posix(), (REPO / p).read_text())
             for p in paths]
    return LintContext(files, root=str(REPO))


def _tree_paths():
    out = []
    for d in ("src/repro", "examples", "benchmarks"):
        for p in sorted((REPO / d).rglob("*.py")):
            rel = p.relative_to(REPO).as_posix()
            if "__pycache__" not in rel:
                out.append(rel)
    return out


def test_facade_files_exist():
    missing = [p for p in FACADE_FILES if not (REPO / p).is_file()]
    assert not missing, f"FACADE_FILES entries not on disk: {missing}"


def test_layering_pass_clean_on_tree():
    """The whole DAG — facade surface (W402), package edges (W401), and
    the legacy quarantine (W403) — holds on the live tree."""
    findings = run_pass(_load(_tree_paths()))
    assert not findings, "\n".join(f.render() for f in findings)


def test_layering_pass_catches_deep_facade_import():
    """Regression for the retired hand-rolled scan: a facade file
    acquiring a deep import must still fail."""
    bad = SourceFile(FACADE_FILES[0],
                     "from repro.store.journal import EventJournal\n")
    findings = run_pass(LintContext([bad], root=str(REPO)))
    assert any(f.rule == "W402" for f in findings)


def test_layering_pass_catches_core_importing_api():
    bad = SourceFile("src/repro/core/newmod.py", "import repro.api\n")
    findings = run_pass(LintContext([bad], root=str(REPO)))
    assert any(f.rule == "W401" for f in findings)


# -- runtime half: public-surface names (needs the imported modules) -----

def _repro_imports(path: str):
    tree = ast.parse((REPO / path).read_text(), filename=path)
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro" or mod.startswith("repro."):
                yield mod, [a.name for a in node.names], node.lineno


@pytest.mark.parametrize("path", FACADE_FILES)
def test_facade_files_import_only_public_names(path):
    import repro.api
    import repro.fleet
    public = {"repro.api": set(repro.api.__all__),
              "repro.fleet": set(repro.fleet.__all__)}
    violations = []
    for mod, names, lineno in _repro_imports(path):
        for name in names:
            if mod in public and name not in public[mod]:
                violations.append(f"{path}:{lineno}: {name!r} is not a "
                                  f"public (__all__) name of {mod}")
    assert not violations, "\n".join(violations)


def test_api_all_names_exist():
    """Every advertised facade name must actually resolve."""
    import repro.api
    missing = [n for n in repro.api.__all__ if not hasattr(repro.api, n)]
    assert not missing, f"repro.api.__all__ names missing: {missing}"
