"""Online class-discovery acceptance (PR 9).

The contracts pinned here:

  * **discovery-inert** — enabling discovery (quarantine taps firing,
    classes promoted) never changes the decision of any high-confidence
    job: the tap observes decisions, it does not participate in them
    (hypothesis property);
  * **the full loop** — low-margin novel arrivals quarantine, re-cluster,
    shadow-evaluate, and promote a new library version that subsequent
    arrivals of the same family classify to; N-1 rollback restores the
    previous version;
  * **durable discovery** — crash at every journal boundary across a
    library-version bump and resume re-adopts the promoted version
    verbatim with **zero classifier queries** (quarantine entries, the
    promotion, and the rollback all replay from their journal records);
  * unit behavior of the pool (FIFO capacity, id monotonicity, restore),
    the profile-record codec (float64-exact round-trip), and the shadow
    gate (agreement threshold, confidence-gain gate).
"""
import json
import os
import shutil

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.pipeline.library as libmod
from repro.api import (DiscoveryController, MinosSession, QuarantinePool,
                       ReferenceLibrary, ShadowEvaluator, TPUPowerModel,
                       count_classifier_calls, micro_gemm, micro_idle_burst,
                       micro_spmv_memory, micro_stencil, micro_vector_search,
                       resolve_objective, stream_profile_workload,
                       stream_profiler, stream_telemetry, to_dict,
                       truth_selection)
from repro.discovery import (PoolEntry, Promotion, profile_from_record,
                             profile_record)
from repro.store.journal import JOURNAL_FILE

MODEL = TPUPowerModel()
TDP = MODEL.spec.tdp_w
FREQS = (0.6, 0.8, 1.0)
GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)
# permissive knobs so the micro novel family reliably promotes: margin
# confidence measures ambiguity, not wrongness, so a decisively-but-wrongly
# matched novel workload still scores ~0.7-0.9
DISC = {"quarantine_below": 0.9, "min_cluster": 3, "recluster_every": 100,
        "promote_agreement": 0.5, "cluster_distance": 0.5}

REFERENCE = [micro_gemm, micro_idle_burst, micro_spmv_memory, micro_stencil]


_SHARED: dict = {}       # module-level lazy singletons: the hypothesis
                         # shim's @given wrapper is zero-arg, so the
                         # property test cannot take pytest fixtures


def _library() -> ReferenceLibrary:
    if "library" not in _SHARED:
        _SHARED["library"] = ReferenceLibrary(
            (stream_profile_workload(s(), MODEL, FREQS, TDP, seed=i,
                                     target_duration=0.5)
             for i, s in enumerate(REFERENCE)),
            built_on="tpu-v5e")
    return _SHARED["library"]


@pytest.fixture(scope="module")
def micro_library():
    return _library()


def _telemetry(stream, seed):
    return stream_telemetry(stream, 1.0, MODEL, seed=seed,
                            target_duration=0.5)


def _novel_profiler():
    return stream_profiler([micro_vector_search()], MODEL, FREQS, TDP,
                           target_duration=0.5)


def _spy_library_classifiers():
    """Patch ``ReferenceLibrary.classifier`` so every classifier any
    library mints is query-counted; returns (restore_fn, counters)."""
    counters = []
    orig = libmod.ReferenceLibrary.classifier

    def patched(self, *a, **k):
        clf = orig(self, *a, **k)
        counters.append(count_classifier_calls(clf))
        return clf

    libmod.ReferenceLibrary.classifier = patched
    return (lambda: setattr(libmod.ReferenceLibrary, "classifier", orig),
            counters)


# ---------------------------------------------------------------------------
# unit: quarantine pool
# ---------------------------------------------------------------------------
def _entry_record(profile, entry_id, confidence=0.5):
    return PoolEntry(id=entry_id, name=profile.name, confidence=confidence,
                     device_id="tpu-v5e/000", fraction=0.4,
                     profile=profile).record()


def test_pool_fifo_capacity_and_ids(micro_library):
    profiles = list(micro_library)
    pool = QuarantinePool(capacity=3)
    for i, p in enumerate(profiles):         # 4 adds into capacity 3
        assert pool.next_id == i + 1
        pool.add_record(_entry_record(p, pool.next_id))
    assert len(pool) == 3
    assert [e.name for e in pool] == [p.name for p in profiles[1:]]  # FIFO
    assert pool.next_id == 5                 # ids never reused after evict
    assert pool.remove([e.id for e in list(pool)[:2]]) == 2
    assert len(pool) == 1
    pool.clear()
    assert len(pool) == 0
    with pytest.raises(ValueError):
        QuarantinePool(capacity=0)


def test_pool_restore_roundtrip(micro_library):
    profiles = list(micro_library)
    pool = QuarantinePool(capacity=8)
    for p in profiles[:3]:
        pool.add_record(_entry_record(p, pool.next_id))
    records = [e.record() for e in pool]
    again = QuarantinePool(capacity=8)
    again.restore(json.loads(json.dumps(records)), next_id=pool.next_id)
    assert [e.record() for e in again] == records
    assert again.next_id == pool.next_id


def test_profile_record_roundtrip_is_exact(micro_library):
    for p in micro_library:
        rec = json.loads(json.dumps(profile_record(p)))
        q = profile_from_record(rec)
        assert q.name == p.name and q.tdp == p.tdp and q.domain == p.domain
        assert np.array_equal(q.power_trace, p.power_trace)
        assert q.sm_util == p.sm_util and q.dram_util == p.dram_util
        assert q.exec_time == p.exec_time
        assert set(q.scaling) == set(p.scaling)
        for f, fp in p.scaling.items():
            # spike_vec is a builder-side cache (never read after
            # construction; ReferenceLibrary.save drops it too) — every
            # decision-bearing field must round-trip float64-exact
            for field in ("freq", "p90", "p95", "p99", "mean_power",
                          "exec_time"):
                assert getattr(q.scaling[f], field) == getattr(fp, field)
        # the rebuilt profile histogram-matches the original exactly
        assert np.array_equal(q.spike_vec(0.1), p.spike_vec(0.1))


# ---------------------------------------------------------------------------
# unit: shadow evaluation
# ---------------------------------------------------------------------------
def test_truth_selection_is_self_neighbor(micro_library):
    p = next(iter(micro_library))
    sel = truth_selection(p)
    assert sel.power_neighbor == p.name and sel.power_distance == 0.0
    assert sel.util_neighbor == p.name and sel.util_distance == 0.0
    policy = resolve_objective("powercentric")
    assert policy.cap(sel) in p.scaling


def test_shadow_gate_promotes_and_rejects(micro_library):
    full = stream_profile_workload(micro_vector_search(), MODEL, FREQS, TDP,
                                   seed=9, target_duration=0.5)
    members = [full] * 3
    confs = [0.3, 0.4, 0.5]
    report = ShadowEvaluator(micro_library,
                             promote_agreement=0.5).evaluate(
        full, members, confs)
    assert report.promote and report.agreement == 1.0
    assert report.mean_confidence_after > report.mean_confidence_before
    # an unreachable agreement bar rejects the same candidate
    strict = ShadowEvaluator(micro_library, promote_agreement=1.01)
    assert not strict.evaluate(full, members, confs).promote
    # no members -> never promotes
    assert not ShadowEvaluator(micro_library).evaluate(full, [], []).promote


# ---------------------------------------------------------------------------
# unit: controller versioning + validation
# ---------------------------------------------------------------------------
def test_controller_requires_reference_library(micro_library):
    with pytest.raises(ValueError, match="ReferenceLibrary"):
        DiscoveryController(list(micro_library))
    with pytest.raises(ValueError, match="ReferenceLibrary"):
        MinosSession(micro_library.classifier(), discovery={}, **GATES)


def test_session_rejects_unknown_discovery_knob(micro_library):
    with pytest.raises(ValueError, match="quarantine_below"):
        MinosSession(micro_library, discovery={"zzz": 1}, **GATES)


def test_force_propose_without_profiler_raises(micro_library):
    session = MinosSession(micro_library, discovery=DISC, **GATES)
    for i in range(3):
        session.submit(_telemetry(micro_vector_search(), 500 + i),
                       chips=1).run()
    assert len(session.discovery.pool) == 3
    with pytest.raises(ValueError, match="profiler"):
        session.discover(force=True)


def test_promotions_apply_in_order_and_rollback_guards(micro_library):
    d = DiscoveryController(micro_library)
    with pytest.raises(ValueError, match="no previous library"):
        d.rollback()
    full = stream_profile_workload(micro_vector_search(), MODEL, FREQS, TDP,
                                   seed=3, target_duration=0.5)
    promo = Promotion(version=3, profiles=[full],
                      profile_records=[profile_record(full)], consumed=[])
    with pytest.raises(ValueError, match="in order"):
        d.apply(promo)                      # current is 1; 3 skips 2


def test_rollback_restores_previous_membership(micro_library):
    session = MinosSession(micro_library, discovery=DISC, **GATES)
    for i in range(4):
        session.submit(_telemetry(micro_vector_search(), 600 + i),
                       chips=1).run()
    session.discovery.profiler = _novel_profiler()
    out = session.discover(force=True)
    assert out is not None and out["version"] == 2
    assert any("discovered-v2" in n for n in session.discovery.library.names)
    rb = session.rollback_discovery()
    assert rb["version"] == 1
    assert list(session.discovery.library.names) \
        == list(micro_library.names)
    with pytest.raises(ValueError, match="no previous"):
        session.rollback_discovery()


# ---------------------------------------------------------------------------
# the discovery-inert pin (hypothesis property)
# ---------------------------------------------------------------------------
def _promoted_session() -> MinosSession:
    """A discovery session that has already quarantined novel traffic and
    promoted a discovered class — the maximally-perturbed counterpart the
    inert property compares against."""
    if "promoted" not in _SHARED:
        session = MinosSession(_library(), discovery=DISC, **GATES)
        for i in range(4):
            session.submit(_telemetry(micro_vector_search(), 700 + i),
                           chips=2).run()
        session.discovery.profiler = _novel_profiler()
        assert session.discover(force=True) is not None
        _SHARED["promoted"] = session
    return _SHARED["promoted"]


def _plain_session() -> MinosSession:
    if "plain" not in _SHARED:
        _SHARED["plain"] = MinosSession(_library(), **GATES)
    return _SHARED["plain"]


@pytest.fixture(scope="module")
def promoted_session():
    return _promoted_session()


@pytest.fixture(scope="module")
def plain_session():
    return _plain_session()


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(range(len(REFERENCE))),
       st.integers(min_value=0, max_value=9999),
       st.sampled_from([1, 2, 4]))
def test_discovery_never_changes_high_confidence_decisions(
        stream_idx, seed, chips):
    plain_session, promoted_session = _plain_session(), _promoted_session()
    """Property: the same in-library job, submitted to a discovery-less
    session and to a session that quarantined traffic AND promoted a new
    class, reaches the identical decision whenever the plain decision is
    high-confidence (the promoted class may legitimately shift the margin
    denominator, so only the decision itself — cap, neighbors, gating — is
    pinned)."""
    stream = REFERENCE[stream_idx]()
    plain = plain_session.submit(
        _telemetry(stream, 3000 + seed), chips=chips).run()
    disc = promoted_session.submit(
        _telemetry(stream, 3000 + seed), chips=chips).run()
    if plain.confidence < 0.5:
        return                              # low-margin: fair game
    assert disc.cap == plain.cap
    assert disc.early == plain.early
    assert disc.fraction == plain.fraction
    assert to_dict(disc.selection) == to_dict(plain.selection)


def test_report_discovery_field_inert_by_default(plain_session,
                                                 promoted_session):
    assert plain_session.report().discovery is None
    assert plain_session.discovery is None
    rep = promoted_session.report().discovery
    assert rep["version"] == 2 and rep["promotions"] == 1
    assert rep["classes"] and all("discovered-v2" in n
                                  for n in rep["classes"])


def test_promoted_class_absorbs_new_arrivals(promoted_session):
    dec = promoted_session.submit(
        _telemetry(micro_vector_search(), 4242), chips=2).run()
    assert "discovered-v2" in dec.selection.power_neighbor


# ---------------------------------------------------------------------------
# durable discovery: crash-at-every-boundary across a version bump
# ---------------------------------------------------------------------------
def _disc_state(session) -> dict:
    d = session.discovery
    return {
        "version": d.version,
        "names": list(d.library.names),
        "state": json.loads(json.dumps(d.state_record())),
        "decisions": {jid: to_dict(j.decision)
                      for jid, j in session._fleet.jobs.items()
                      if j.decision is not None},
    }


@pytest.fixture(scope="module")
def discovery_store(micro_library, tmp_path_factory):
    """A scripted durable discovery run — quarantines, a promotion, a
    post-promotion decision on the discovered class, and a rollback —
    with the discovery state recorded at every step boundary."""
    path = str(tmp_path_factory.mktemp("disc") / "session")
    session = MinosSession(micro_library, store=path, discovery=DISC,
                           **GATES)
    session.discovery.profiler = _novel_profiler()
    boundaries = {}

    def mark(tag):
        boundaries[session.store.journal.last_seq] = (tag,
                                                      _disc_state(session))

    mark("open")
    for i in range(4):
        session.submit(_telemetry(micro_vector_search(), 800 + i),
                       chips=2).run()
        mark(f"quarantine-{i}")
    out = session.discover(force=True)
    assert out is not None and out["version"] == 2
    mark("promote")
    session.submit(_telemetry(micro_vector_search(), 900), chips=2).run()
    mark("post-promotion-decision")
    session.rollback_discovery()
    mark("rollback")
    session.close()
    return path, boundaries


def _truncate_journal(src, dst, keep_records):
    shutil.rmtree(dst, ignore_errors=True)
    shutil.copytree(src, dst)
    jp = os.path.join(dst, JOURNAL_FILE)
    with open(jp, "rb") as f:
        lines = f.read().splitlines(keepends=True)
    with open(jp, "wb") as f:
        f.writelines(lines[:keep_records])


def test_resume_every_boundary_readopts_promotion_verbatim(
        discovery_store, micro_library, tmp_path):
    path, boundaries = discovery_store
    for seq, (tag, expected) in boundaries.items():
        crash = str(tmp_path / f"crash-{seq}")
        _truncate_journal(path, crash, seq)
        restore, counters = _spy_library_classifiers()
        try:
            session = MinosSession.resume(crash, references=micro_library)
        finally:
            restore()
        queries = sum(c["n"] for c in counters)
        assert queries == 0, \
            f"resume at {tag!r} (seq {seq}) made {queries} classifier queries"
        assert _disc_state(session) == expected, \
            f"discovery state diverged at boundary {tag!r}"
        session.close()


def test_resume_mid_promotion_then_continue(discovery_store, micro_library,
                                            tmp_path):
    """Crash right at the promotion boundary: the resumed session carries
    version 2 and a NEW arrival classifies to the discovered class —
    the promoted membership round-tripped through the journal alone."""
    path, boundaries = discovery_store
    promote_seq = next(seq for seq, (tag, _) in boundaries.items()
                       if tag == "promote")
    crash = str(tmp_path / "resume-continue")
    _truncate_journal(path, crash, promote_seq)
    session = MinosSession.resume(crash, references=micro_library)
    assert session.discovery.version == 2
    dec = session.submit(_telemetry(micro_vector_search(), 950),
                         chips=2).run()
    assert "discovered-v2" in dec.selection.power_neighbor
    # rollback still works after resume (the N-1 chain was rebuilt)
    assert session.rollback_discovery()["version"] == 1
    session.close()


def test_resume_without_discovery_key_warns_on_discovery_records(
        discovery_store, micro_library, tmp_path, monkeypatch):
    """A journal holding quarantine/promote records resumed by a session
    whose open record somehow lost its discovery config must warn and skip,
    not crash (forward-compatible replay)."""
    import glob
    path, boundaries = discovery_store
    crash = str(tmp_path / "strip")
    _truncate_journal(path, crash, max(boundaries))
    for snap in glob.glob(os.path.join(crash, "snapshot-*.json")):
        os.remove(snap)                  # force a full journal replay
    # strip the discovery key from the journaled open record via the
    # session's config reader
    from repro.api.session import MinosSession as MS
    orig = MS._init_discovery

    def no_discovery(self, discovery, references):
        return None

    monkeypatch.setattr(MS, "_init_discovery", no_discovery)
    with pytest.warns(RuntimeWarning, match="discovery"):
        session = MS.resume(crash, references=micro_library)
    assert session.discovery is None
    session.close()
