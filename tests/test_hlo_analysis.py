"""While-aware HLO cost parser: exactness on known-FLOP programs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import analyze_hlo_text
from repro.analysis.hlo import _shape_bytes, _shape_elems, parse_module


def test_shape_parsing():
    assert _shape_bytes("bf16[16,4096,8192]{2,1,0}") == 16 * 4096 * 8192 * 2
    assert _shape_bytes("f32[]") == 4
    assert _shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
    assert _shape_elems("pred[3,5]") == 15


def test_scan_flops_exact():
    D, L, B = 128, 5, 16

    def f(params, x):
        def body(h, w):
            return h @ w, ()
        h, _ = jax.lax.scan(body, x, params)
        return jnp.sum(h)

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L, D, D), jnp.float32),
        jax.ShapeDtypeStruct((B, D), jnp.float32)).compile()
    cost = analyze_hlo_text(c.as_text())
    analytic = 2 * B * D * D * L
    assert cost.unresolved_loops == 0
    assert abs(cost.flops - analytic) / analytic < 0.05
    # XLA's own number counts the body once (the bug we work around).
    # jax >= 0.4.30 returns the per-device list [dict]; older versions the
    # bare dict — normalize before reading.
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    xla = ca.get("flops", 0)
    assert xla < cost.flops / (L - 1)


def test_nested_scan_multiplies():
    D, L1, L2 = 64, 3, 4

    def f(params, x):
        def outer(h, w):
            def inner(hh, _):
                return hh @ w, ()
            h2, _ = jax.lax.scan(inner, h, None, length=L2)
            return h2, ()
        h, _ = jax.lax.scan(outer, x, params)
        return jnp.sum(h)

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((L1, D, D), jnp.float32),
        jax.ShapeDtypeStruct((8, D), jnp.float32)).compile()
    cost = analyze_hlo_text(c.as_text())
    analytic = 2 * 8 * D * D * L1 * L2
    assert abs(cost.flops - analytic) / analytic < 0.05


def test_dot_without_scan():
    def f(a, b):
        return a @ b
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 48), jnp.float32)).compile()
    cost = analyze_hlo_text(c.as_text())
    assert cost.flops == pytest.approx(2 * 32 * 64 * 48, rel=0.01)
    assert cost.hbm_bytes >= (32 * 64 + 64 * 48 + 32 * 48) * 4


def test_parse_module_structure():
    txt = """HloModule test

%helper (p: f32[4]) -> f32[4] {
  %p = f32[4]{0} parameter(0)
  ROOT %m = f32[4]{0} multiply(%p, %p)
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %c = f32[4]{0} call(%x), to_apply=%helper
}
"""
    comps, entry = parse_module(txt)
    assert entry == "main"
    assert "helper" in comps
    cost = analyze_hlo_text(txt)
    assert cost.flops == 4  # one multiply of 4 elements
