"""Serving engine + Minos-driven power scheduler."""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.hardware import V5E
from repro.configs import ARCHS
from repro.fleet import DeviceInstance
from repro.models.common import SMOKE_TOPO
from repro.serve import ServeEngine
from repro.core.classify import FreqPoint, MinosClassifier, WorkloadProfile
from repro.sched import PowerAwareScheduler, SimActuator

TDP = 200.0


def test_generate_shapes_and_determinism():
    cfg = ARCHS["glm4-9b"].reduced(num_layers=2)
    eng = ServeEngine(cfg, SMOKE_TOPO, max_len=40)
    params = eng.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)}
    out1 = eng.generate(params, batch, 6)
    out2 = eng.generate(params, batch, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)
    assert np.all(out1 >= 0) and np.all(out1 < cfg.vocab_size)


def test_generate_rejects_overflow_and_keyless_sampling():
    cfg = ARCHS["glm4-9b"].reduced(num_layers=2)
    eng = ServeEngine(cfg, SMOKE_TOPO, max_len=20)
    params = eng.init_params(jax.random.key(0))
    batch = {"tokens": np.zeros((1, 16), np.int32)}
    with pytest.raises(ValueError):
        eng.generate(params, batch, 10)
    # sampling without a PRNG key must raise, not silently fall back to
    # greedy decoding
    with pytest.raises(ValueError, match="requires a PRNG key"):
        eng.generate(params, batch, 2, greedy=False)
    out = eng.generate(params, batch, 2, greedy=False,
                       key=jax.random.key(1))
    assert out.shape == (1, 2)


def _ref(name, lvl, sm, dram, freq_sensitivity=1.0):
    rng = np.random.default_rng(abs(hash(name)) % 2**31)
    freqs = [0.6, 0.8, 1.0]
    scaling = {f: FreqPoint(freq=f, p90=lvl * (f ** freq_sensitivity),
                            p95=lvl * f + 0.03, p99=lvl * f + 0.06,
                            mean_power=lvl * f - 0.1, exec_time=1.0 / f)
               for f in freqs}
    return WorkloadProfile(name, TDP, rng.normal(lvl * TDP, 5.0, 400),
                           sm, dram, 1.0, scaling)


def test_actuator_clamps():
    act = SimActuator()
    act.set_cap(0.3)
    assert act.get_cap() == pytest.approx(0.6)
    act.set_cap(1.4)
    assert act.get_cap() == pytest.approx(1.0)


def test_power_scheduler_packs_within_budget():
    refs = [_ref("hot", 1.4, 0.95, 0.1), _ref("cool", 0.7, 0.1, 0.9)]
    clf = MinosClassifier(refs)
    sched = PowerAwareScheduler(clf, tdp_w=TDP, objective="powercentric")
    jobs = [(_ref("job-hot", 1.38, 0.93, 0.12), 16),
            (_ref("job-cool", 0.72, 0.12, 0.88), 16)]
    budget = 16 * TDP * 1.35 + 16 * TDP * 0.8
    res = sched.schedule(jobs, budget_w=budget)
    assert len(res.placed) == 2
    assert res.planned_power_w <= budget
    tight = sched.schedule(jobs, budget_w=16 * TDP * 0.9)
    assert len(tight.deferred) >= 1


def test_ffd_tie_break_is_deterministic_by_name():
    """Equal-power jobs must pack in name order regardless of queue order."""
    refs = [_ref("hot", 1.4, 0.95, 0.1), _ref("cool", 0.7, 0.1, 0.9)]
    clf = MinosClassifier(refs)
    sched = PowerAwareScheduler(clf, tdp_w=TDP, objective="powercentric")
    # four identical-power jobs (same profile shape, same chips)
    jobs = [(_ref(f"job-{tag}", 1.38, 0.93, 0.12), 16)
            for tag in ("delta", "alpha", "charlie", "bravo")]
    budget = 2.5 * 16 * TDP * 1.4          # room for ~2 of the 4
    res = sched.schedule(jobs, budget_w=budget)
    powers = {j.predicted_p90_w for j in res.placed}
    assert len(powers) == 1                # genuinely tied on power
    assert [j.name for j in res.placed] == sorted(j.name for j in res.placed)
    # any queue permutation packs the identical job set, in the same order
    for perm in ([3, 1, 0, 2], [2, 3, 1, 0]):
        res2 = sched.schedule([jobs[i] for i in perm], budget_w=budget)
        assert [j.name for j in res2.placed] == [j.name for j in res.placed]
        assert res2.deferred == res.deferred


def _zoo_scheduler(quantile="p90"):
    refs = [_ref("hot", 1.4, 0.95, 0.1), _ref("cool", 0.7, 0.1, 0.9)]
    return PowerAwareScheduler(MinosClassifier(refs), tdp_w=TDP,
                               objective="powercentric", quantile=quantile)


def test_zero_budget_defers_everything():
    sched = _zoo_scheduler()
    jobs = [(_ref("job-hot", 1.38, 0.93, 0.12), 16),
            (_ref("job-cool", 0.72, 0.12, 0.88), 16)]
    for budget in (0.0, -5.0):
        res = sched.schedule(jobs, budget_w=budget)
        assert res.placed == []
        assert sorted(res.deferred) == ["job-cool", "job-hot"]
        assert res.planned_power_w == 0.0
        assert res.nameplate_power_w == 0.0
        assert res.headroom_reclaimed_w == 0.0


def test_insufficient_budget_defers_all_and_empty_queue_is_empty():
    sched = _zoo_scheduler()
    jobs = [(_ref("job-hot", 1.38, 0.93, 0.12), 16),
            (_ref("job-cool", 0.72, 0.12, 0.88), 16)]
    # smaller than the smallest single job's need: nothing can ever fit
    res = sched.schedule(jobs, budget_w=1.0)
    assert res.placed == [] and len(res.deferred) == 2
    empty = sched.schedule([], budget_w=1e9)
    assert empty.placed == [] and empty.deferred == []


def test_scheduler_rejects_unknown_quantile():
    with pytest.raises(ValueError, match="quantile"):
        _zoo_scheduler(quantile="p50")


def test_heterogeneous_jobs_cost_their_devices_effective_tdp():
    sched = _zoo_scheduler()
    prof = _ref("job-cool", 0.72, 0.12, 0.88)
    weak = DeviceInstance("v5e/bad", "tpu-v5e",
                          dataclasses.replace(V5E, power_scale=1.25))
    plan_pod = sched.plan_job(prof, 4)
    plan_dev = sched.plan_job(prof, 4, weak)
    assert plan_dev.cap == plan_pod.cap
    assert plan_dev.device_id == "v5e/bad"
    assert plan_dev.nameplate_w == V5E.tdp_w
    assert plan_dev.predicted_p90_w == pytest.approx(
        1.25 * plan_pod.predicted_p90_w)
    # an inefficient chip eats part of the reclaimed headroom
    res_dev = sched.schedule([(prof, 4, weak)], budget_w=1e9)
    res_pod = sched.schedule([(prof, 4)], budget_w=1e9)
    assert 0 < res_dev.headroom_reclaimed_w < res_pod.headroom_reclaimed_w


@given(st.lists(st.sampled_from(["job-hot", "job-cool", "job-mid"]),
                min_size=0, max_size=6),
       st.integers(min_value=1, max_value=64),
       st.floats(min_value=0.0, max_value=1e6))
@settings(max_examples=40, deadline=None)
def test_fleet_plan_never_exceeds_budget(names, chips, budget):
    """Property (ISSUE 3): whatever the queue, chip counts, budget, or
    device variability, a schedule's planned power never exceeds its
    budget, and every job lands in exactly one of placed/deferred."""
    sched = _zoo_scheduler()
    levels = {"job-hot": (1.38, 0.93, 0.12), "job-cool": (0.72, 0.12, 0.88),
              "job-mid": (1.05, 0.5, 0.5)}
    jobs = []
    for i, name in enumerate(names):
        lvl, sm, dram = levels[name]
        dev = DeviceInstance(
            f"dev/{i}", "tpu-v5e",
            dataclasses.replace(V5E, power_scale=0.8 + 0.05 * i))
        jobs.append((_ref(f"{name}-{i}", lvl, sm, dram), chips, dev))
    res = sched.schedule(jobs, budget_w=budget)
    assert res.planned_power_w <= budget
    assert len(res.placed) + len(res.deferred) == len(jobs)
    assert {j.name for j in res.placed} | set(res.deferred) == \
        {p.name for p, _, _ in jobs}
