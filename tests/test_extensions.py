"""Extension coverage: elastic reshard roundtrip, VLM gating, RoPE
properties, Topo divisibility invariants, Mahalanobis alternative."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.configs import ARCHS
from repro.configs.base import MeshConfig, ShapeConfig
from repro.core.clustering import mahalanobis_distance_matrix
from repro.ft import plan_new_mesh, rescale_batch
from repro.models import build_model, make_batch
from repro.models.common import SMOKE_TOPO, Topo
from repro.models.layers import apply_rope


def test_elastic_reshard_roundtrip():
    """Checkpoint written under one mesh restores byte-exact onto another
    (the re-mesh path after losing hosts)."""
    cfg = ARCHS["glm4-9b"].reduced(num_layers=2)
    m = build_model(cfg, SMOKE_TOPO, kind="train")
    params = m.init_params(jax.random.key(0))
    with tempfile.TemporaryDirectory() as tmp:
        ckpt.save({"params": params}, tmp, 7)
        restored, step = ckpt.restore(tmp)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # the elastic plan shrinks data, preserves model, rescales batch
    plan = plan_new_mesh(MeshConfig((16, 16), ("data", "model")), 144)
    assert plan.new.model_axis_size == 16
    assert rescale_batch(256, plan) == 256 * plan.new.data_axis_size // 16


def test_vlm_gate_zero_init_is_identity():
    """tanh(0)-gated cross-attention must not perturb the text path at init:
    swapping the image embeddings leaves the loss unchanged."""
    cfg = ARCHS["llama-3.2-vision-11b"].reduced()
    shape = ShapeConfig("s", seq_len=32, global_batch=2, kind="train")
    m = build_model(cfg, SMOKE_TOPO, kind="train")
    params = m.init_params(jax.random.key(0))
    b1 = make_batch(cfg, shape, jax.random.key(1))
    b2 = dict(b1)
    b2["image_embeds"] = b1["image_embeds"] * -3.0 + 1.0
    l1, _ = jax.jit(m.loss)(params, b1)
    l2, _ = jax.jit(m.loss)(params, b2)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)


def test_rope_relative_property():
    """RoPE scores depend only on relative positions: shifting q and k
    positions by the same offset leaves q.k unchanged."""
    key = jax.random.key(0)
    q = jax.random.normal(key, (1, 4, 2, 32), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 2, 32), jnp.float32)
    pos = jnp.arange(4, dtype=jnp.int32)
    def scores(off):
        qr = apply_rope(q, pos + off, 10000.0)
        kr = apply_rope(k, pos + off, 10000.0)
        return jnp.einsum("bshd,bthd->bhst", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(0)), np.asarray(scores(17)),
                               rtol=1e-4, atol=1e-4)


@given(st.sampled_from(["batch", "tp", "fsdp", "seq_tp", "all", None]),
       st.integers(1, 4096))
@settings(max_examples=60, deadline=None)
def test_topo_resolve_divisibility(logical, dim):
    """resolve() never returns axes whose product fails to divide the dim."""
    topo = Topo(MeshConfig((2, 16, 16), ("pod", "data", "model")))
    phys = topo.resolve(logical, dim)
    if phys is not None:
        n = 1
        for a in phys:
            n *= topo.mesh_cfg.shape[topo.mesh_cfg.axis_names.index(a)]
        assert dim % n == 0
    spec = topo.pspec((logical,), (dim,))  # never raises


def test_mahalanobis_alternative():
    """Paper §4.1.2 mentions Mahalanobis as an alternative metric."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(12, 4))
    D = mahalanobis_distance_matrix(X)
    assert D.shape == (12, 12)
    assert np.allclose(D, D.T)
    assert np.allclose(np.diag(D), 0.0)
    assert np.all(D >= -1e-9)
