"""Sharded, atomic, elastic checkpointing.

Layout (one directory per step):
    <dir>/step_000100.tmp-<nonce>/   -> written, fsync'd
    <dir>/step_000100/               -> atomic rename on commit
        MANIFEST.json                -> step, tree structure, shapes, dtypes
        shard_<host>.npz             -> this host's addressable array shards

Elastic restore: arrays are saved with their *global* logical paths and
reassembled host-side, so a checkpoint written on one mesh restores onto any
other mesh (the new ``device_put`` shardings re-partition them) — this is the
re-shard path used when a pod is lost and the job re-meshes (ft/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any

import jax
import ml_dtypes
import numpy as np

# npz can't store bfloat16: persist as uint16 bit-pattern + dtype in manifest
_BITCAST = {"bfloat16": np.uint16}


def _flatten(tree: Any, prefix: str = "") -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
        return out
    out[prefix[:-1]] = tree
    return out


def _unflatten(leaves: dict[str, Any]) -> Any:
    tree: dict[str, Any] = {}
    for path, v in leaves.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save(state: Any, directory: str, step: int) -> str:
    """Atomic checkpoint commit. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=directory)
    leaves = _flatten(state)
    arrays = {}
    manifest = {"step": step, "time": time.time(), "leaves": {}}
    for i, (path, v) in enumerate(leaves.items()):
        arr = np.asarray(jax.device_get(v))
        dtype = str(arr.dtype)
        if dtype in _BITCAST:
            arr = arr.view(_BITCAST[dtype])
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"][path] = {
            "key": key, "shape": list(arr.shape), "dtype": dtype}
    np.savez(os.path.join(tmp, "shard_0.npz"), **arrays)
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and ".tmp-" not in d]
    return max(steps) if steps else None


def restore(directory: str, step: int | None = None,
            shardings: Any | None = None) -> tuple[Any, int]:
    """Restore (optionally onto new shardings — the elastic re-shard path)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    leaves = {}
    for lpath, meta in manifest["leaves"].items():
        arr = data[meta["key"]]
        if meta["dtype"] in _BITCAST:
            arr = arr.view(ml_dtypes.bfloat16)
        leaves[lpath] = arr
    state = _unflatten(leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state, manifest["step"]


def garbage_collect(directory: str, keep: int = 3) -> None:
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and ".tmp-" not in d)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
    # orphaned tmp dirs from crashed writers
    for d in os.listdir(directory):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
