"""W501/W502 · float contract.

The reference implementations are pinned to 1e-9 (or exact byte)
agreement, which makes two float idioms latent flakes in the pinned
modules:

* **W501** — bare ``==``/``!=`` against a non-integral float literal
  (``x == 0.3``): the comparison is exact, the literal is not exactly
  representable, and a kernel-vs-reference path differing in the last ulp
  flips the branch.  Integral-valued literals (``0.0``, ``2.0``) compare
  exactly and are allowed.
* **W502** — implicit float32 downcasts (``np.float32(...)``,
  ``.astype(np.float32)``, ``dtype="float32"``) in the float64 reference
  paths.  ``kernels/`` is exempt by scope: Pallas TPU kernels compute in
  float32 by design, and it is the *reference* halves these rules keep in
  float64.
"""
from __future__ import annotations

import ast

from . import contracts
from .core import Finding, LintContext

RULES = {
    "W501": "exact float equality against a non-integral literal",
    "W502": "implicit float32 downcast in a float64 reference module",
}


def _nonintegral_float(node: ast.AST) -> bool:
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        node = node.operand
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, float)
            and node.value == node.value          # not NaN
            and node.value not in (float("inf"), float("-inf"))
            and node.value != int(node.value))


def _float32_mention(node: ast.AST) -> str | None:
    """A float32 reference inside an expression, or None."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr == "float32":
            return "float32"
        if isinstance(sub, ast.Constant) and sub.value == "float32":
            return '"float32"'
    return None


def _scan_eq(sf) -> list[Finding]:
    findings = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Compare):
            continue
        operands = [node.left, *node.comparators]
        for op, left, right in zip(node.ops, operands, operands[1:]):
            if not isinstance(op, (ast.Eq, ast.NotEq)):
                continue
            if _nonintegral_float(left) or _nonintegral_float(right):
                findings.append(Finding(
                    "W501", sf.path, node.lineno,
                    "exact float comparison against a non-integral "
                    "literal in a 1e-9/byte-identity-pinned module",
                    hint="compare with math.isclose/abs(a-b)<tol, or "
                         "restructure so the sentinel is integral"))
    return findings


def _scan_downcast(sf) -> list[Finding]:
    findings = []
    for node in ast.walk(sf.tree):
        mention = None
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr == "float32":
                mention = f"{ast.unparse(fn)}(...)"
            elif isinstance(fn, ast.Attribute) and fn.attr == "astype" \
                    and node.args and _float32_mention(node.args[0]):
                mention = ".astype(float32)"
        elif isinstance(node, ast.keyword) and node.arg == "dtype" \
                and _float32_mention(node.value):
            mention = f"dtype={_float32_mention(node.value)}"
        if mention is not None:
            findings.append(Finding(
                "W502", sf.path, node.value.lineno
                if isinstance(node, ast.keyword) else node.lineno,
                f"implicit float32 downcast ({mention}) in a float64 "
                f"reference module",
                hint="keep reference paths in float64; downcasts belong "
                     "in kernels/ only"))
    return findings


def run_pass(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    for sf in ctx.under(*contracts.FLOAT_EQ_DIRS):
        if sf.tree is not None:
            findings.extend(_scan_eq(sf))
    for sf in ctx.under(*contracts.DOWNCAST_DIRS):
        if sf.tree is not None:
            findings.extend(_scan_downcast(sf))
    return findings
