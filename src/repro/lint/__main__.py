"""CLI entry point: ``python -m repro.lint [paths...]``.

Exit status is 0 when every finding is suppressed (or none exist) and 1
otherwise — CI keys on it.  ``--format json`` emits the machine report
(also written via ``--output``); the default text format prints
``path:line: RULE message [hint]`` per finding.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import RULES, load_context, render_json, render_text, run


def _find_root(start: Path) -> Path:
    """The repo root: nearest ancestor holding ``src/repro``.  Falls back
    to this package's own checkout when run from elsewhere."""
    for cand in (start, *start.parents):
        if (cand / "src" / "repro").is_dir():
            return cand
    return Path(__file__).resolve().parents[3]


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Minos contract checker (see ROADMAP.md § Checked "
                    "contracts)")
    parser.add_argument(
        "paths", nargs="*", type=Path,
        help="files to lint (default: the whole tree — src/repro, tests, "
             "examples, benchmarks; tests/lint_fixtures excluded)")
    parser.add_argument("--root", type=Path, default=None,
                        help="repo root (default: auto-detected)")
    parser.add_argument("--format", choices=("text", "json"),
                        default="text")
    parser.add_argument("--output", type=Path, default=None,
                        help="also write the JSON report to this path")
    parser.add_argument("--select", default=None,
                        help="comma-separated rule ids to keep "
                             "(e.g. W101,W401)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalogue and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES):
            print(f"{rule}  {RULES[rule]}")
        return 0

    root = (args.root or _find_root(Path.cwd())).resolve()
    select = ({r.strip() for r in args.select.split(",") if r.strip()}
              if args.select else None)
    ctx = load_context(root, list(args.paths) or None)
    findings = run(ctx, select=select)

    if args.output is not None:
        args.output.parent.mkdir(parents=True, exist_ok=True)
        args.output.write_text(render_json(findings, root=str(root))
                               + "\n")
    if args.format == "json":
        print(render_json(findings, root=str(root)))
    else:
        print(render_text(findings))

    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
