"""minoslint core: source loading, suppressions, pass runner, report.

The lint suite is pure-stdlib ``ast`` analysis — no runtime imports of the
code under audit, so it runs in CI before any heavy dependency loads.  A
*pass* is a callable ``(LintContext) -> list[Finding]``; the runner
concatenates pass output, applies inline suppressions, and renders either
a human ``path:line`` listing or the JSON report CI archives.

Two inline pragmas are recognized (comment anywhere on a line):

``# minoslint: disable=W101,W304``
    suppress those rules on this line.  Suppressed findings still appear
    in the report (counted separately) so suppressions stay auditable.

``# minoslint: path=src/repro/fleet/controller.py``
    override the file's *effective* repo-relative path (first 5 lines
    only).  Test fixtures use this to opt into a scoped rule — e.g. a
    snippet that pretends to live in ``pipeline/`` so the determinism
    pass applies — without polluting the real tree.
"""
from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path

_DISABLE_RE = re.compile(r"#\s*minoslint:\s*disable=([A-Z0-9,\s]+)")
_PATH_RE = re.compile(r"#\s*minoslint:\s*path=(\S+)")


@dataclass
class Finding:
    """One rule violation at ``path:line``."""

    rule: str
    path: str
    line: int
    message: str
    hint: str = ""
    suppressed: bool = False

    def sort_key(self):
        return (self.path, self.line, self.rule, self.message)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "hint": self.hint}

    def render(self) -> str:
        sup = " [suppressed]" if self.suppressed else ""
        hint = f"  [{self.hint}]" if self.hint else ""
        return f"{self.path}:{self.line}: {self.rule}{sup} {self.message}{hint}"


class SourceFile:
    """A parsed source file plus its pragma state.

    ``path`` is the *effective* repo-relative posix path (after any
    ``minoslint: path=`` override) — all scope matching and reporting key
    on it.  ``real_path`` is where the bytes actually live.
    """

    def __init__(self, path: str, text: str, real_path: str | None = None):
        self.real_path = real_path or path
        self.text = text
        self.lines = text.splitlines()
        self.suppressions: dict[int, set[str]] = {}
        for n, line in enumerate(self.lines, start=1):
            m = _DISABLE_RE.search(line)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                self.suppressions.setdefault(n, set()).update(rules)
        eff = path
        for line in self.lines[:5]:
            m = _PATH_RE.search(line)
            if m:
                eff = m.group(1)
                break
        self.path = Path(eff).as_posix()
        self.parse_error: SyntaxError | None = None
        try:
            self.tree: ast.AST | None = ast.parse(text)
        except SyntaxError as exc:  # surfaced as a finding by the runner
            self.tree = None
            self.parse_error = exc

    # -- scope helpers ---------------------------------------------------
    @property
    def module(self) -> str | None:
        """Dotted module name when the file lives under ``src/`` (the
        effective path decides), e.g. ``repro.fleet.controller``."""
        parts = Path(self.path).parts
        if len(parts) >= 2 and parts[0] == "src":
            mod = list(parts[1:])
            mod[-1] = mod[-1][:-3] if mod[-1].endswith(".py") else mod[-1]
            if mod[-1] == "__init__":
                mod = mod[:-1]
            return ".".join(mod)
        return None

    @property
    def package(self) -> str | None:
        """Top-level package under ``repro`` (``fleet``, ``store``, ...);
        top-level modules report their own name (``legacy``)."""
        mod = self.module
        if mod is None or not mod.startswith("repro"):
            return None
        parts = mod.split(".")
        return parts[1] if len(parts) > 1 else parts[0]

    def in_dir(self, *prefixes: str) -> bool:
        return any(self.path.startswith(p) for p in prefixes)


class LintContext:
    """Everything a pass may look at: the parsed files plus the repo root
    (for messages only — passes never touch the filesystem)."""

    def __init__(self, files: list[SourceFile], root: str = "."):
        self.files = files
        self.root = root
        self.by_path = {f.path: f for f in files}

    def under(self, *prefixes: str) -> list[SourceFile]:
        return [f for f in self.files if f.in_dir(*prefixes)]

    def in_package(self, *packages: str) -> list[SourceFile]:
        return [f for f in self.files if f.package in packages]


# -- file discovery ------------------------------------------------------

#: directories the default (no-argument) run scans, relative to the root.
DEFAULT_SCAN_DIRS = ("src/repro", "tests", "examples", "benchmarks")

#: subtrees never scanned by default: fixtures are *intentionally* bad.
EXCLUDED_DIRS = ("tests/lint_fixtures",)


def discover_files(root: Path) -> list[Path]:
    out: list[Path] = []
    for d in DEFAULT_SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            rel = p.relative_to(root).as_posix()
            if "__pycache__" in rel:
                continue
            if any(rel == ex or rel.startswith(ex + "/")
                   for ex in EXCLUDED_DIRS):
                continue
            out.append(p)
    return out


def load_context(root: Path, paths: list[Path] | None = None) -> LintContext:
    targets = paths if paths else discover_files(root)
    files = []
    for p in targets:
        p = p.resolve()
        try:
            rel = p.relative_to(root.resolve()).as_posix()
        except ValueError:
            rel = p.as_posix()
        files.append(SourceFile(rel, p.read_text(), real_path=str(p)))
    return LintContext(files, root=str(root))


# -- runner --------------------------------------------------------------

def run(ctx: LintContext, select: set[str] | None = None) -> list[Finding]:
    """Run every registered pass, apply suppressions, return sorted
    findings (suppressed ones included, flagged)."""
    from . import PASSES
    findings: list[Finding] = []
    for f in ctx.files:
        if f.parse_error is not None:
            findings.append(Finding(
                "E000", f.path, f.parse_error.lineno or 1,
                f"syntax error: {f.parse_error.msg}"))
    for run_pass in PASSES:
        findings.extend(run_pass(ctx))
    for f in findings:
        sf = ctx.by_path.get(f.path)
        if sf is not None and f.rule in sf.suppressions.get(f.line, set()):
            f.suppressed = True
    if select:
        findings = [f for f in findings if f.rule in select]
    findings.sort(key=Finding.sort_key)
    return findings


def report_dict(findings: list[Finding], root: str = ".") -> dict:
    active = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]
    by_rule: dict[str, int] = {}
    for f in active:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "version": 1,
        "root": root,
        "ok": not active,
        "counts": {"findings": len(active), "suppressed": len(suppressed),
                   "by_rule": by_rule},
        "findings": [f.to_dict() for f in active],
        "suppressed": [f.to_dict() for f in suppressed],
    }


def render_text(findings: list[Finding]) -> str:
    lines = [f.render() for f in findings]
    active = sum(1 for f in findings if not f.suppressed)
    suppressed = len(findings) - active
    lines.append(f"minoslint: {active} finding(s), {suppressed} suppressed")
    return "\n".join(lines)


def render_json(findings: list[Finding], root: str = ".") -> str:
    return json.dumps(report_dict(findings, root=root), indent=2,
                      sort_keys=True)
