"""W301–W304 · determinism discipline.

The byte-identity pins (classification sha256, journal checksums, the
1e-9 reference agreements) only hold if the pinned packages are
*functions of their inputs*.  Four classic leaks are banned there:

* **W301** — wall-clock reads (``time.time``, ``datetime.now``,
  ``time.monotonic`` …): two runs of the same inputs produce different
  bytes.
* **W302** — unseeded randomness: module-level ``random.*`` /
  ``np.random.*`` globals and no-argument ``Random()`` /
  ``default_rng()`` constructions.  Seeded generator *objects* are fine —
  determinism requires the seed to flow in from the caller.
* **W303** — iterating a ``set`` expression straight into ordered output
  (``list(set(...))``, ``for x in {…}``): set order is hash-salt
  dependent across processes.  Wrap in ``sorted(...)``.
* **W304** — ``id(...)`` used as a container key: CPython re-uses
  addresses, so dict/set membership keyed on ``id()`` is run-dependent
  the moment an object dies.  Key on a stable identity instead.
"""
from __future__ import annotations

import ast

from . import contracts
from .core import Finding, LintContext

RULES = {
    "W301": "wall-clock read in a byte-identity-pinned module",
    "W302": "unseeded random source in a byte-identity-pinned module",
    "W303": "set iteration feeding ordered output",
    "W304": "id()-keyed container in a byte-identity-pinned module",
}

_CLOCK_ATTRS = {
    "time": {"time", "time_ns", "monotonic", "monotonic_ns",
             "perf_counter", "perf_counter_ns"},
    "datetime": {"now", "utcnow", "today"},
    "date": {"today"},
}

_RANDOM_MODULES = ("random", "np.random", "numpy.random")


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` attribute chain as a dotted string, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_clock_call(call: ast.Call) -> bool:
    dotted = _dotted(call.func)
    if dotted is None:
        return False
    parts = dotted.split(".")
    if len(parts) < 2:
        return False
    base, attr = parts[-2], parts[-1]
    return attr in _CLOCK_ATTRS.get(base, ())


def _is_unseeded_random(call: ast.Call) -> bool:
    dotted = _dotted(call.func)
    if dotted is None:
        return False
    # global-state draws: random.random(), np.random.rand(), ...
    for mod in _RANDOM_MODULES:
        if dotted.startswith(mod + ".") and dotted != mod + ".Random" \
                and not dotted.endswith(".default_rng") \
                and not dotted.endswith(".seed") \
                and not dotted.endswith(".PRNGKey") \
                and not dotted.endswith(".Generator"):
            return True
    # generator construction without a seed argument
    tail = dotted.split(".")[-1]
    if tail in ("Random", "default_rng", "PRNGKey") and not call.args \
            and not call.keywords:
        return True
    return False


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    return False


def _is_id_call(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name) and node.func.id == "id")


def _contains_id_call(node: ast.AST) -> bool:
    return any(_is_id_call(n) for n in ast.walk(node))


def _scan_file(sf) -> list[Finding]:
    findings: list[Finding] = []

    def flag(rule: str, lineno: int, message: str, hint: str) -> None:
        findings.append(Finding(rule, sf.path, lineno, message, hint=hint))

    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call):
            if _is_clock_call(node):
                flag("W301", node.lineno,
                     f"wall-clock call `{_dotted(node.func)}(...)` in a "
                     f"byte-identity-pinned module",
                     "take the timestamp as a parameter (or journal it) "
                     "so replay reproduces identical bytes")
            elif _is_unseeded_random(node):
                flag("W302", node.lineno,
                     f"unseeded random source "
                     f"`{_dotted(node.func)}(...)`",
                     "thread an explicitly seeded generator through the "
                     "call instead of global RNG state")
            # list(set(...)) / tuple(set(...)) / enumerate(set(...))
            if isinstance(node.func, ast.Name) \
                    and node.func.id in ("list", "tuple", "enumerate") \
                    and node.args and _is_set_expr(node.args[0]):
                flag("W303", node.lineno,
                     f"`{node.func.id}()` over a set expression leaks "
                     f"hash order into ordered output",
                     "wrap the set in sorted(...) before ordering "
                     "matters")
            # container.setdefault(id(x), ...) and dict(...)[id(x)]-like
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "setdefault" and node.args \
                    and _contains_id_call(node.args[0]):
                flag("W304", node.lineno,
                     "setdefault key derived from id(): address re-use "
                     "makes lookups run-dependent",
                     "key on a stable identity (job_id, name, index)")
        elif isinstance(node, (ast.For, ast.comprehension)):
            it = node.iter
            if _is_set_expr(it):
                flag("W303", it.lineno,
                     "iterating a set expression: order depends on the "
                     "process hash seed",
                     "iterate sorted(...) of the set")
        elif isinstance(node, ast.Subscript):
            if _contains_id_call(node.slice):
                flag("W304", node.lineno,
                     "container subscript keyed on id(): address re-use "
                     "makes the mapping run-dependent",
                     "key on a stable identity (job_id, name, index)")
        elif isinstance(node, ast.Dict):
            for key in node.keys:
                if key is not None and _contains_id_call(key):
                    flag("W304", key.lineno,
                         "dict literal keyed on id()",
                         "key on a stable identity (job_id, name, index)")
    return findings


def run_pass(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    for sf in ctx.under(*contracts.PINNED_DIRS):
        if sf.tree is not None:
            findings.extend(_scan_file(sf))
    return findings
