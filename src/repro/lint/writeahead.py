"""W101 · write-ahead discipline.

In the journaled mutators (fleet controller, session facade, discovery
controller, store), every mutation of instance state must be *dominated*
by a journal call in the same method: on every control-flow path reaching
the mutation, a ``self._journal(...)`` / ``<store>.record(...)`` /
``<journal>.append(...)`` call (or a delegate that journals internally)
has already executed.  That is the crash-safety contract — a crash
between the record landing and the mutation replays the record; a crash
the other way round silently loses state.

Dominance is computed by a conservative walk over structured control
flow:

* statements in sequence: a journal call turns the flag on for everything
  after it;
* ``if``/``else``: the flag holds after the statement only when *both*
  branches (or the code before) set it — except the store-presence guard
  ``if self._store is not None: ... record ...``, which counts as
  dominating because a ``None`` store is the inert-by-default mode with
  nothing to journal;
* loop bodies see the flag from before the loop, and the loop contributes
  nothing afterwards (the body may run zero times);
* ``try`` bodies likewise contribute nothing afterwards (any statement
  may raise).

Scope is auto-detected: only classes containing at least one journal call
are audited, so value/codec classes in the same files are skipped.  The
allowlists in :mod:`.contracts` exempt derived caches (rebuilt by replay)
and the apply-halves replay itself calls.
"""
from __future__ import annotations

import ast

from . import contracts
from .core import Finding, LintContext

RULES = {"W101": "state mutation not dominated by a write-ahead journal call"}

_MUTATOR_METHODS = frozenset({
    "append", "appendleft", "add", "insert", "extend", "update", "pop",
    "popleft", "popitem", "remove", "discard", "clear", "setdefault",
})


def _self_attr(node: ast.AST) -> str | None:
    """``self.<attr>`` -> attr name, else None."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def is_journal_call(node: ast.AST) -> bool:
    """True for the calls the write-ahead contract recognizes as 'the
    record is durable now': ``self._journal(...)``, ``<x>.record(...)``,
    ``<x>.append(...)``/``<x>.write(...)`` where ``x`` names a journal,
    and journal-delegating calls ``self._fleet.<delegate>(...)``."""
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    if not isinstance(fn, ast.Attribute):
        return False
    if fn.attr == "_journal" and isinstance(fn.value, ast.Name) \
            and fn.value.id == "self":
        return True
    if fn.attr == "record":
        # a *kind* argument distinguishes SessionStore.record(kind, ...)
        # from the zero-arg .record() codec serializers
        return bool(node.args)
    if fn.attr in ("append", "write"):
        # only when the receiver is journal-named: self.journal.append(...)
        recv = fn.value
        name = recv.attr if isinstance(recv, ast.Attribute) else (
            recv.id if isinstance(recv, ast.Name) else "")
        return "journal" in name
    if fn.attr in contracts.JOURNAL_DELEGATES:
        recv = _self_attr(fn.value)
        if recv in ("_fleet", "fleet", "_discovery"):
            return True
    return False


def _contains_journal_call(node: ast.AST) -> bool:
    return any(is_journal_call(n) for n in ast.walk(node))


def _is_store_guard(test: ast.AST) -> bool:
    """``self._store is not None`` (or any store/journal-named presence
    check): the inert-by-default gate around record calls."""
    def _names_store(expr: ast.AST) -> bool:
        for n in ast.walk(expr):
            label = None
            if isinstance(n, ast.Attribute):
                label = n.attr
            elif isinstance(n, ast.Name):
                label = n.id
            if label and ("store" in label or "journal" in label):
                return True
        return False
    return _names_store(test)


#: statement types scanned for mutations; compound statements are
#: excluded — the dominance walk recurses into their bodies itself.
_SIMPLE_STMTS = (ast.Assign, ast.AugAssign, ast.AnnAssign, ast.Expr,
                 ast.Delete, ast.Return, ast.Raise, ast.Assert)


def _mutations(stmt: ast.stmt):
    """Yield ``(attr, lineno, what)`` for instance-state mutations rooted
    at this single simple statement."""
    if not isinstance(stmt, _SIMPLE_STMTS):
        return
    if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = stmt.targets if isinstance(stmt, ast.Assign) \
            else [stmt.target]
        for t in targets:
            attr = _self_attr(t)
            if attr is not None:
                yield attr, t.lineno, f"assignment to self.{attr}"
            elif isinstance(t, ast.Subscript):
                attr = _self_attr(t.value)
                if attr is not None:
                    yield attr, t.lineno, f"item write into self.{attr}"
            elif isinstance(t, (ast.Tuple, ast.List)):
                for el in t.elts:
                    a = _self_attr(el)
                    if a is not None:
                        yield a, el.lineno, f"assignment to self.{a}"
    elif isinstance(stmt, ast.Delete):
        for t in stmt.targets:
            if isinstance(t, ast.Subscript):
                attr = _self_attr(t.value)
                if attr is not None:
                    yield attr, t.lineno, f"item delete from self.{attr}"
    # mutator method calls anywhere in the statement's expressions —
    # catches `job = self.jobs.pop(id)` as well as bare `self.x.add(...)`
    for node in ast.walk(stmt):
        if isinstance(node, ast.Call) and not is_journal_call(node):
            fn = node.func
            if isinstance(fn, ast.Attribute) \
                    and fn.attr in _MUTATOR_METHODS:
                attr = _self_attr(fn.value)
                if attr is not None:
                    yield attr, node.lineno, \
                        f"self.{attr}.{fn.attr}(...) mutation"


class _DominanceWalker:
    def __init__(self, path: str):
        self.path = path
        self.findings: list[Finding] = []

    def walk(self, body: list[ast.stmt], journaled: bool) -> bool:
        """Process a statement sequence; return whether a journal call
        dominates the exit of the sequence."""
        for stmt in body:
            if not journaled:
                for attr, lineno, what in _mutations(stmt):
                    if attr in contracts.DERIVED_ATTRS:
                        continue
                    self.findings.append(Finding(
                        "W101", self.path, lineno,
                        f"{what} is not preceded by a journal call on "
                        f"every path through this method",
                        hint="journal the causing record first, route "
                             "through a *_apply method, or add the attr "
                             "to DERIVED_ATTRS with a justification"))
            journaled = self._step(stmt, journaled)
        return journaled

    def _step(self, stmt: ast.stmt, journaled: bool) -> bool:
        if isinstance(stmt, ast.If):
            then_j = self.walk(stmt.body, journaled)
            else_j = self.walk(stmt.orelse, journaled) if stmt.orelse \
                else journaled
            if stmt.orelse:
                return then_j and else_j
            # store-presence guard: `if self._store is not None: record`
            # dominates what follows — no store means nothing to journal.
            if then_j and _is_store_guard(stmt.test):
                return True
            return journaled
        if isinstance(stmt, (ast.For, ast.While)):
            self.walk(stmt.body, journaled)
            self.walk(stmt.orelse, journaled)
            return journaled
        if isinstance(stmt, ast.Try):
            self.walk(stmt.body, journaled)
            for handler in stmt.handlers:
                self.walk(handler.body, journaled)
            self.walk(stmt.orelse, journaled)
            final_j = self.walk(stmt.finalbody, journaled)
            return final_j if stmt.finalbody else journaled
        if isinstance(stmt, ast.With):
            return self.walk(stmt.body, journaled)
        if isinstance(stmt, ast.Match):
            arms = [self.walk(case.body, journaled) for case in stmt.cases]
            has_wildcard = any(
                isinstance(c.pattern, ast.MatchAs) and c.pattern.pattern
                is None for c in stmt.cases)
            if arms and has_wildcard and all(arms):
                return True
            return journaled
        # plain statement: does it itself journal?
        if _contains_journal_call(stmt):
            return True
        return journaled


def _journaled_classes(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            if any(is_journal_call(n) for n in ast.walk(node)):
                yield node


def run_pass(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    for sf in ctx.under(*contracts.JOURNALED_FILES):
        if sf.tree is None:
            continue
        for cls in _journaled_classes(sf.tree):
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                if meth.name.startswith("__"):
                    continue  # constructors/dunders build, not mutate
                if meth.name in contracts.APPLY_METHODS:
                    continue
                walker = _DominanceWalker(sf.path)
                walker.walk(meth.body, journaled=False)
                findings.extend(walker.findings)
    return findings
