"""W401/W402/W403 · layering / import boundary.

The package DAG in :data:`contracts.ALLOWED_EDGES` is the declarative
source of truth for who may import whom inside ``repro`` (the old
hand-rolled scan in ``tests/test_import_boundary.py`` now delegates
here).  Function-local (lazy) imports count: an edge is an edge, lazy or
not — lazy edges that are *intended* (the ``core -> pipeline`` shim) are
listed in the DAG like any other.

* **W401** — a module in package P imports ``repro.Q`` with Q outside
  ``ALLOWED_EDGES[P]``.  The north-star edge this guards: ``core`` (and
  everything below it) never imports ``api``; ``store`` imports nothing.
* **W402** — a facade file (examples, fleet benchmarks) imports a
  ``repro`` module outside the public surface (``repro.api`` /
  ``repro.fleet``).
* **W403** — ``repro.legacy`` imported outside ``tests/`` /
  ``benchmarks/``: the frozen pre-refactor surface exists only for
  characterization tests and the throughput benchmark.
"""
from __future__ import annotations

import ast

from . import contracts
from .core import Finding, LintContext, SourceFile

RULES = {
    "W401": "package imports outside its allowed DAG edges",
    "W402": "facade file imports past the public repro.api/repro.fleet "
            "surface",
    "W403": "repro.legacy imported outside tests/ and benchmarks/",
}


def _imports(sf: SourceFile):
    """Yield ``(dotted_module, lineno)`` for every import in the file,
    with relative imports resolved against the file's own module."""
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                yield alias.name, node.lineno
        elif isinstance(node, ast.ImportFrom):
            if node.level == 0:
                if node.module:
                    yield node.module, node.lineno
                continue
            base = (sf.module or "").split(".")
            # a module's level-1 relative import resolves against its
            # package: drop the module segment plus (level - 1) parents.
            # For a package __init__ the module IS the package, so one
            # fewer segment comes off.
            if base:
                drop = node.level - 1 if sf.path.endswith("__init__.py") \
                    else node.level
                anchor = base[:len(base) - drop]
                mod = ".".join(anchor + ([node.module]
                                         if node.module else []))
                if mod:
                    yield mod, node.lineno


def _target_package(module: str) -> str | None:
    """Top-level repro package a dotted import lands in, else None."""
    parts = module.split(".")
    if parts[0] != "repro":
        return None
    return parts[1] if len(parts) > 1 else "repro"


def run_pass(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    facades = set(contracts.FACADE_FILES)
    for sf in ctx.files:
        if sf.tree is None:
            continue
        is_facade = sf.path in facades
        pkg = sf.package
        for module, lineno in _imports(sf):
            target = _target_package(module)
            if target is None:
                continue
            # W403 first: legacy has one rule for the whole repo
            if target == "legacy":
                if not sf.path.startswith(contracts.LEGACY_ALLOWED_DIRS):
                    findings.append(Finding(
                        "W403", sf.path, lineno,
                        "repro.legacy is the frozen pre-refactor surface; "
                        "only tests/ and benchmarks/ may import it",
                        hint="use repro.api (MinosSession) instead"))
                continue
            if is_facade:
                if not (module in contracts.FACADE_ALLOWED or any(
                        module.startswith(a + ".")
                        for a in contracts.FACADE_ALLOWED)):
                    findings.append(Finding(
                        "W402", sf.path, lineno,
                        f"facade file imports {module}; facades consume "
                        f"only {' / '.join(contracts.FACADE_ALLOWED)}",
                        hint="re-export what you need through repro.api "
                             "or drop the file from FACADE_FILES with a "
                             "rationale"))
                continue
            if pkg is None or pkg == "repro" or target == "repro":
                continue  # tests/benchmarks may import anything non-legacy
            if target == pkg:
                continue
            allowed = contracts.ALLOWED_EDGES.get(pkg)
            if allowed is None:
                findings.append(Finding(
                    "W401", sf.path, lineno,
                    f"package {pkg!r} has no entry in ALLOWED_EDGES",
                    hint="declare the package's allowed imports in "
                         "lint/contracts.py"))
            elif target not in allowed:
                findings.append(Finding(
                    "W401", sf.path, lineno,
                    f"illegal package edge {pkg} -> {target} "
                    f"(allowed: {', '.join(sorted(allowed)) or 'none'})",
                    hint="invert the dependency or add the edge to "
                         "ALLOWED_EDGES with a rationale"))
    return findings
