"""minoslint — the repo's contract checker.

``python -m repro.lint`` statically enforces the architectural
invariants the runtime tests pin behaviorally: write-ahead journaling
(W1xx), journal-record exhaustiveness (W2xx), determinism in the
byte-identity-pinned packages (W3xx), the package import DAG (W4xx), and
the float contract of the 1e-9 reference paths (W5xx).  Pure stdlib
``ast`` — nothing under audit is imported.

See ROADMAP.md § "Checked contracts" for the rule catalogue, and
:mod:`repro.lint.contracts` for the policy (scopes, allowlists, DAG).
"""
from __future__ import annotations

from . import (determinism, floatcontract, layering, record_kinds,
               writeahead)
from .core import (Finding, LintContext, SourceFile, load_context,
                   render_json, render_text, report_dict, run)

#: pass execution order (report order comes from sorting, not this).
PASSES = (
    writeahead.run_pass,
    record_kinds.run_pass,
    determinism.run_pass,
    layering.run_pass,
    floatcontract.run_pass,
)

#: rule id -> one-line description, for --list-rules and the docs.
RULES = {}
for _mod in (writeahead, record_kinds, determinism, layering,
             floatcontract):
    RULES.update(_mod.RULES)

__all__ = [
    "Finding", "LintContext", "SourceFile", "PASSES", "RULES",
    "load_context", "render_json", "render_text", "report_dict", "run",
]
