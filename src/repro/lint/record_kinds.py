"""W201/W202/W203 · journal-record exhaustiveness.

The write-ahead journal only buys crash-safety if every record kind that
can land in ``journal.jsonl`` is (a) registered in the canonical
``store/kinds.py`` registry and (b) consumed by the resume dispatch
(``MinosSession._apply_record``) — an emitted-but-unhandled kind is state
that silently evaporates on resume, and a handled-but-never-emitted kind
is dead replay code hiding a retired (or misspelled) emitter.

The pass is fully static: it resolves ``kinds.X`` constants against the
registry module's ``NAME = "literal"`` assignments, collects every emit
site (``self._journal(<kind>, ...)`` / ``<store>.record(<kind>, ...)``
with a resolvable first argument) under ``src/``, and reads the handled
set out of the dispatch function's ``match`` statement (``MatchOr``
patterns flattened).  Cross-checks:

* **W201** — kind emitted somewhere but absent from the dispatch;
* **W202** — dispatch ``case`` (or registry entry) for a kind nothing
  emits;
* **W203** — emit site whose kind is not in the registry at all.
"""
from __future__ import annotations

import ast

from . import contracts
from .core import Finding, LintContext

RULES = {
    "W201": "record kind emitted but not handled by the resume dispatch",
    "W202": "dead record-kind handler (or registered kind) nothing emits",
    "W203": "emitted record kind missing from the kinds registry",
}


def _load_registry(sf) -> dict[str, tuple[str, int]]:
    """``CONST -> (value, lineno)`` from module-level string assignments."""
    reg: dict[str, tuple[str, int]] = {}
    if sf.tree is None:
        return reg
    for node in sf.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, str):
            reg[node.targets[0].id] = (node.value.value, node.lineno)
    return reg


def _find_registry(ctx: LintContext):
    sf = ctx.by_path.get(contracts.KINDS_REGISTRY)
    if sf is not None:
        return sf
    for f in ctx.files:
        if f.path.startswith("src/") and f.tree is not None \
                and any(isinstance(n, ast.Assign)
                        and any(isinstance(t, ast.Name)
                                and t.id == "ALL_KINDS"
                                for t in n.targets)
                        for n in f.tree.body):
            return f
    return None


def _kind_of_arg(arg: ast.AST, registry: dict) -> tuple[str | None, bool]:
    """Resolve an emit site's first argument to a kind value.

    Returns ``(value, known)``: ``known`` is False for dynamic arguments
    (plain variables) the pass cannot resolve and must skip."""
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return arg.value, True
    if isinstance(arg, ast.Attribute):
        if arg.attr in registry:
            return registry[arg.attr][0], True
        # kinds.X where X is not a registered constant
        recv = arg.value
        if isinstance(recv, ast.Name) and recv.id == "kinds":
            return arg.attr, True
    return None, False


def _emit_sites(ctx: LintContext, registry: dict):
    """Yield ``(kind, path, lineno)`` for every resolvable emit site."""
    for sf in ctx.files:
        if not sf.path.startswith("src/") or sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            fn = node.func
            if not isinstance(fn, ast.Attribute):
                continue
            if fn.attr not in ("_journal", "record"):
                continue
            if fn.attr == "_journal" and not (
                    isinstance(fn.value, ast.Name)
                    and fn.value.id == "self"):
                continue
            kind, known = _kind_of_arg(node.args[0], registry)
            if known:
                yield kind, sf.path, node.args[0].lineno


def _match_values(pattern: ast.pattern, registry: dict):
    """Kind values named by one ``case`` pattern (Or-patterns flattened)."""
    if isinstance(pattern, ast.MatchOr):
        for p in pattern.patterns:
            yield from _match_values(p, registry)
    elif isinstance(pattern, ast.MatchValue):
        v = pattern.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            yield v.value, pattern.value.lineno
        elif isinstance(v, ast.Attribute) and v.attr in registry:
            yield registry[v.attr][0], v.lineno
        elif isinstance(v, ast.Attribute):
            yield v.attr, v.lineno


def _dispatch_handlers(ctx: LintContext, registry: dict):
    """``kind -> (path, lineno)`` handled by the resume dispatch, plus the
    dispatch location itself (None when no dispatch exists in context)."""
    for sf in ctx.files:
        if not sf.path.startswith("src/") or sf.tree is None:
            continue
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == contracts.DISPATCH_FUNC:
                handled: dict[str, tuple[str, int]] = {}
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Match):
                        for case in sub.cases:
                            for value, lineno in _match_values(
                                    case.pattern, registry):
                                handled.setdefault(value,
                                                   (sf.path, lineno))
                return handled, (sf.path, node.lineno)
    return None, None


def run_pass(ctx: LintContext) -> list[Finding]:
    findings: list[Finding] = []
    reg_file = _find_registry(ctx)
    registry = _load_registry(reg_file) if reg_file is not None else {}
    registered = {v for v, _ in registry.values()}

    emitted: dict[str, tuple[str, int]] = {}
    for kind, path, lineno in _emit_sites(ctx, registry):
        emitted.setdefault(kind, (path, lineno))
        if reg_file is not None and kind not in registered:
            findings.append(Finding(
                "W203", path, lineno,
                f"record kind {kind!r} is not in the kinds registry "
                f"({reg_file.path})",
                hint="add a constant to store/kinds.py (wire format: add, "
                     "never rename) and emit that constant"))

    handled, dispatch_loc = _dispatch_handlers(ctx, registry)
    if handled is None:
        return findings  # no dispatch in scope: registry checks only

    for kind, (path, lineno) in sorted(emitted.items()):
        if kind not in handled:
            findings.append(Finding(
                "W201", path, lineno,
                f"record kind {kind!r} is emitted here but "
                f"{contracts.DISPATCH_FUNC} never handles it — the record "
                f"is silently dropped on resume",
                hint=f"add a `case` for it in {contracts.DISPATCH_FUNC} "
                     f"or register it as a marker kind"))
    for kind, (path, lineno) in sorted(handled.items()):
        if kind not in emitted:
            findings.append(Finding(
                "W202", path, lineno,
                f"{contracts.DISPATCH_FUNC} handles record kind {kind!r} "
                f"but no emit site produces it",
                hint="delete the dead handler or restore the lost "
                     "emitter"))
    if reg_file is not None:
        for const, (value, lineno) in sorted(registry.items()):
            if value not in emitted and value not in handled:
                findings.append(Finding(
                    "W202", reg_file.path, lineno,
                    f"registered record kind {value!r} ({const}) is "
                    f"neither emitted nor handled",
                    hint="remove the constant or wire up its emitter and "
                         "handler"))
    return findings
