"""Fault-tolerance: straggler detection + preemption handling.

On a real multi-host deployment these bind to ``jax.distributed`` heartbeats;
the detection logic is host-agnostic and fully unit-testable with injected
clocks (per the dry-run-first philosophy of this repo).
"""
from __future__ import annotations

import signal
import statistics
from collections import defaultdict, deque
from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    """Flags hosts whose per-step durations exceed median + k * MAD.

    Hosts are any hashable id (ints for training hosts, device_id strings
    for fleet devices).  A host that stops reporting is aged out: once no
    sample has arrived from it in the last ``window`` steps (tracked via the
    ``step`` argument to ``record``), its stale duration window is evicted
    and ``healthy_hosts`` stops vouching for it — ``dead_hosts()`` reports
    it instead, until it records again.
    """

    window: int = 20
    k: float = 6.0
    min_samples: int = 5
    _durations: dict = field(default_factory=lambda: defaultdict(deque))
    _last_step: dict = field(default_factory=dict)
    _dead: set = field(default_factory=set)
    _latest_step: int = field(default=-1)

    def record(self, host, step: int, duration_s: float) -> None:
        step = int(step)
        self._dead.discard(host)           # a reporting host is back alive
        prev = self._last_step.get(host, step)
        self._last_step[host] = max(prev, step)
        if step > self._latest_step:
            self._latest_step = step
        d = self._durations[host]
        d.append(duration_s)
        if len(d) > self.window:
            d.popleft()
        self._evict_stale()

    def _evict_stale(self) -> None:
        cutoff = self._latest_step - self.window
        for host in [h for h, s in self._last_step.items() if s < cutoff]:
            del self._last_step[host]
            self._durations.pop(host, None)
            self._dead.add(host)

    def dead_hosts(self) -> list:
        """Hosts aged out for silence (no sample in the last ``window``
        steps), in eviction order-independent sorted form."""
        return sorted(self._dead, key=str)

    def stragglers(self) -> list:
        per_host = {h: statistics.median(d) for h, d in self._durations.items()
                    if len(d) >= self.min_samples}
        if len(per_host) < 3:
            return []
        meds = sorted(per_host.values())
        med = statistics.median(meds)
        mad = statistics.median([abs(x - med) for x in meds]) or 1e-9
        return [h for h, v in per_host.items() if v > med + self.k * mad]

    def healthy_hosts(self, all_hosts: list) -> list:
        bad = set(self.stragglers()) | self._dead
        return [h for h in all_hosts if h not in bad]


class PreemptionHandler:
    """SIGTERM -> set flag; the training loop checkpoints and exits cleanly."""

    def __init__(self, install: bool = True):
        self.preempted = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:
                pass  # non-main thread (tests)

    def _on_signal(self, signum, frame) -> None:
        self.preempted = True

    def trigger(self) -> None:  # test hook
        self.preempted = True
