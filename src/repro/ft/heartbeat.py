"""Fault-tolerance: straggler detection + preemption handling.

On a real multi-host deployment these bind to ``jax.distributed`` heartbeats;
the detection logic is host-agnostic and fully unit-testable with injected
clocks (per the dry-run-first philosophy of this repo).
"""
from __future__ import annotations

import signal
import statistics
from collections import defaultdict, deque
from dataclasses import dataclass, field


@dataclass
class StragglerMonitor:
    """Flags hosts whose per-step durations exceed median + k * MAD."""

    window: int = 20
    k: float = 6.0
    min_samples: int = 5
    _durations: dict = field(default_factory=lambda: defaultdict(deque))

    def record(self, host: int, step: int, duration_s: float) -> None:
        d = self._durations[host]
        d.append(duration_s)
        if len(d) > self.window:
            d.popleft()

    def stragglers(self) -> list[int]:
        per_host = {h: statistics.median(d) for h, d in self._durations.items()
                    if len(d) >= self.min_samples}
        if len(per_host) < 3:
            return []
        meds = sorted(per_host.values())
        med = statistics.median(meds)
        mad = statistics.median([abs(x - med) for x in meds]) or 1e-9
        return [h for h, v in per_host.items() if v > med + self.k * mad]

    def healthy_hosts(self, all_hosts: list[int]) -> list[int]:
        bad = set(self.stragglers())
        return [h for h in all_hosts if h not in bad]


class PreemptionHandler:
    """SIGTERM -> set flag; the training loop checkpoints and exits cleanly."""

    def __init__(self, install: bool = True):
        self.preempted = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._on_signal)
            except ValueError:
                pass  # non-main thread (tests)

    def _on_signal(self, signum, frame) -> None:
        self.preempted = True

    def trigger(self) -> None:  # test hook
        self.preempted = True
