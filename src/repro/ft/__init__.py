from repro.ft.elastic import ElasticPlan, plan_new_mesh, rescale_batch
from repro.ft.heartbeat import PreemptionHandler, StragglerMonitor
