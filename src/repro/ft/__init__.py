from repro.ft.elastic import ElasticPlan, plan_new_mesh, rescale_batch
from repro.ft.fleetwatch import FleetStragglerAdapter
from repro.ft.heartbeat import PreemptionHandler, StragglerMonitor

__all__ = [
    "ElasticPlan", "plan_new_mesh", "rescale_batch",
    "FleetStragglerAdapter", "PreemptionHandler", "StragglerMonitor",
]
