"""Fleet-level straggler detection: ``ft.StragglerMonitor`` over telemetry.

The training-loop ``StragglerMonitor`` watches per-host step durations; on
the fleet there are no steps, but the multiplexed telemetry feed carries the
same signal for free — each ``FleetChunk``'s ``t_end`` is the wall-clock
edge of its last sample, so the gap between consecutive chunks from one
device is that device's effective polling cadence.  A degrading chip (
thermal throttling, a flaky interconnect, a dying HBM stack) stretches its
cadence long before it stops answering entirely.

``FleetStragglerAdapter`` converts the chunk feed into monitor samples:
``observe`` one ``FleetChunk`` at a time (device keyed by ``device_id``,
each device's own chunk count as its step clock — a fleet-wide counter
would out-run the monitor window on large fleets and age out perfectly
healthy devices between their own polls), then read ``degraded()`` /
``dead()``.  A device whose chunk count falls a full monitor window behind
the busiest device ages out as dead — the heartbeat contract.  ``dead()``
is advisory, never auto-acted on: a device also goes silent when its jobs
simply finish early, so only the operator (or a harness that knows the
job mix, like ``bench_chaos``) should escalate it to ``fail_device``.
``FleetCapController`` wires ``degraded()`` to proactive migration: a
flagged device gets its decided jobs re-planned onto healthy silicon
*before* it fails, with zero re-classification.
"""
from __future__ import annotations

from repro.ft.heartbeat import StragglerMonitor


class FleetStragglerAdapter:
    """Feed per-device inter-chunk timings into a ``StragglerMonitor``.

    ``check_every`` throttles ``should_check()`` (the controller's cue to
    recompute the fleet-wide straggler statistics): the median+MAD sweep is
    O(devices x window), far heavier than a chunk ingest, and its verdict
    only drifts as samples accumulate — every 8th chunk is plenty."""

    def __init__(self, monitor: StragglerMonitor | None = None,
                 check_every: int = 8):
        self.monitor = monitor or StragglerMonitor()
        self.check_every = max(int(check_every), 1)
        self._last_t_end: dict[str, float] = {}
        self._counts: dict[str, int] = {}
        self._step = 0

    def observe(self, fchunk) -> None:
        """Record one multiplexed chunk's arrival for its device.  The first
        chunk from a device only seeds its clock (a gap needs two edges)."""
        device_id, t_end = fchunk.device_id, float(fchunk.t_end)
        self._step += 1
        count = self._counts.get(device_id, 0) + 1
        self._counts[device_id] = count
        last = self._last_t_end.get(device_id)
        self._last_t_end[device_id] = t_end
        if last is None:
            return
        # same-t_end chunks (dense multiplexing) contribute a zero gap —
        # still a heartbeat, so the device's liveness clock advances
        self.monitor.record(device_id, count, max(t_end - last, 0.0))

    def should_check(self) -> bool:
        """True every ``check_every``-th observed chunk — the throttled cue
        to run the O(devices x window) straggler sweep."""
        return self._step % self.check_every == 0

    def degraded(self) -> list[str]:
        """Devices whose chunk cadence is a straggler outlier (median +
        k*MAD across the fleet) — candidates for proactive migration."""
        return sorted(self.monitor.stragglers(), key=str)

    def dead(self) -> list[str]:
        """Devices aged out of the monitor entirely (a full window of polls
        behind the busiest device) — surfaced for the operator to escalate
        (``fail_device``), never auto-acted on: silence can also mean the
        device's jobs finished early."""
        return self.monitor.dead_hosts()

    def devices(self) -> list[str]:
        """Every device that has ever reported, sorted."""
        return sorted(self._last_t_end)
