"""Elastic re-meshing: plan a new mesh after losing hosts/pods.

The production mesh is (pod, data, model); losing a pod or a data-slice
shrinks the data-parallel extent while keeping the model extent (weights must
still fit).  ``plan_new_mesh`` picks the largest valid mesh from the surviving
device count; restore then re-shards the last checkpoint onto it
(checkpoint/ckpt.py restore(shardings=...)).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import MeshConfig


@dataclass(frozen=True)
class ElasticPlan:
    old: MeshConfig
    new: MeshConfig
    surviving_devices: int

    @property
    def lost_devices(self) -> int:
        """Devices actually lost to the failure (NOT devices idled by the
        power-of-two rounding of the new data extent — see ``idle_devices``)."""
        return self.old.num_devices - self.surviving_devices

    @property
    def idle_devices(self) -> int:
        """Surviving devices the new mesh cannot use: the remainder of the
        model-axis division plus the power-of-two rounding of the data
        extent.  They stay healthy and re-join on the next re-mesh."""
        return self.surviving_devices - self.new.num_devices

    @property
    def data_scale(self) -> float:
        return self.new.data_axis_size / self.old.data_axis_size


def plan_new_mesh(mesh: MeshConfig, surviving_devices: int) -> ElasticPlan:
    """Shrink the data/pod extent to the largest power-of-two that fits."""
    model = mesh.model_axis_size
    if surviving_devices < model:
        raise RuntimeError(
            f"only {surviving_devices} devices left; model axis needs {model}")
    data = surviving_devices // model
    # largest power of two <= data (keeps batch divisibility simple)
    p = 1
    while p * 2 <= data:
        p *= 2
    new = MeshConfig(shape=(p, model), axis_names=("data", "model"))
    return ElasticPlan(old=mesh, new=new, surviving_devices=surviving_devices)


def rescale_batch(global_batch: int, plan: ElasticPlan) -> int:
    """Keep the *integer* per-device batch constant: each surviving data
    slice keeps exactly the per-device batch it had on the old mesh, so the
    new global batch is ``per_device * new_data_extent`` (never a truncated
    float ratio, which could silently change the per-device batch when the
    old global batch did not divide evenly)."""
    per_device = max(global_batch // plan.old.data_axis_size, 1)
    return per_device * plan.new.data_axis_size
