"""Elastic re-meshing: plan a new mesh after losing hosts/pods.

The production mesh is (pod, data, model); losing a pod or a data-slice
shrinks the data-parallel extent while keeping the model extent (weights must
still fit).  ``plan_new_mesh`` picks the largest valid mesh from the surviving
device count; restore then re-shards the last checkpoint onto it
(checkpoint/ckpt.py restore(shardings=...)).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import MeshConfig


@dataclass(frozen=True)
class ElasticPlan:
    old: MeshConfig
    new: MeshConfig
    lost_devices: int

    @property
    def data_scale(self) -> float:
        return self.new.data_axis_size / self.old.data_axis_size


def plan_new_mesh(mesh: MeshConfig, surviving_devices: int) -> ElasticPlan:
    """Shrink the data/pod extent to the largest power-of-two that fits."""
    model = mesh.model_axis_size
    if surviving_devices < model:
        raise RuntimeError(
            f"only {surviving_devices} devices left; model axis needs {model}")
    data = surviving_devices // model
    # largest power of two <= data (keeps batch divisibility simple)
    p = 1
    while p * 2 <= data:
        p *= 2
    new = MeshConfig(shape=(p, model), axis_names=("data", "model"))
    return ElasticPlan(old=mesh, new=new,
                       lost_devices=mesh.num_devices - new.num_devices)


def rescale_batch(global_batch: int, plan: ElasticPlan) -> int:
    """Keep per-device batch constant: shrink global batch with the mesh."""
    scaled = int(global_batch * plan.data_scale)
    return max(scaled, 1)
