"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2. Mamba+attention 1:7 interleave (one attention layer
per 8), MoE on alternating layers. [arXiv:2403.19887; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    attn_period=8,          # layers 7, 15, ... are attention; rest mamba
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=24576,
    moe_layer_period=2,     # MoE on every other layer
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    rope_theta=10_000.0,
    source="arXiv:2403.19887; hf",
)
