"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512,
vocab=49155, MoE 40e top-8. 40 experts are not divisible by the 16-way model
axis -> expert weights use tensor parallelism over d_ff instead of expert
parallelism; 24 heads -> sequence-sharded attention (DESIGN.md).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    moe_num_experts=40,
    moe_top_k=8,
    moe_d_ff=512,
    moe_layer_period=1,
    moe_group_size=128,
    rope_theta=10_000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
