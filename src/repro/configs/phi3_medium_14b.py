"""phi3-medium-14b [dense]: 40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352.

RoPE SwiGLU GQA. 40 heads are not divisible by the 16-way model axis, so
this arch uses sequence-sharded attention (see DESIGN.md sharding table).
[arXiv:2404.14219; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    qkv_bias=False,
    rope_theta=10_000.0,
    source="arXiv:2404.14219; unverified",
)
