"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA kv_lora=512 (+64 rope dims), MoE: 2 shared + 160 routed experts, top-6.
[arXiv:2405.04434; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,       # MLA decompresses to full heads
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    moe_num_experts=160,
    moe_top_k=6,
    moe_d_ff=1536,
    moe_num_shared=2,
    moe_layer_period=1,     # every layer MoE
    rope_theta=10_000.0,
    source="arXiv:2405.04434; hf",
)
