"""Architecture registry: ``get_config(name)`` / ``--arch <id>`` support."""
from __future__ import annotations

from repro.configs.base import (
    MeshConfig,
    ModelConfig,
    MULTI_POD,
    RunConfig,
    ShapeConfig,
    SINGLE_POD,
    SMOKE_MESH,
)
from repro.configs.shapes import ALL_SHAPES, SHAPES, shape_applicable

from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.glm4_9b import CONFIG as _glm4
from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.qwen2_5_14b import CONFIG as _qwen25
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_vision
from repro.configs.jamba_1_5_large_398b import CONFIG as _jamba
from repro.configs.deepseek_v2_236b import CONFIG as _deepseek
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite
from repro.configs.whisper_medium import CONFIG as _whisper

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _falcon_mamba,
        _glm4,
        _command_r,
        _phi3,
        _qwen25,
        _llama_vision,
        _jamba,
        _deepseek,
        _granite,
        _whisper,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells() -> list[tuple[ModelConfig, ShapeConfig, bool, str]]:
    """Every (arch x shape) cell with its applicability flag + skip reason."""
    cells = []
    for cfg in ARCHS.values():
        for shape in ALL_SHAPES:
            ok, reason = shape_applicable(cfg.family, shape)
            cells.append((cfg, shape, ok, reason))
    return cells


__all__ = [
    "ARCHS",
    "ALL_SHAPES",
    "SHAPES",
    "MeshConfig",
    "ModelConfig",
    "MULTI_POD",
    "RunConfig",
    "ShapeConfig",
    "SINGLE_POD",
    "SMOKE_MESH",
    "all_cells",
    "get_config",
    "shape_applicable",
]
