"""Config dataclasses for models, shapes, meshes and runs.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig``s.  Configs are plain frozen
dataclasses so they can be hashed, diffed and serialized into experiment
records.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (exact per the assignment block)."""

    name: str
    family: str  # dense | ssm | hybrid | moe | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention flavor ---
    qkv_bias: bool = False
    attn_out_bias: bool = False
    rope_theta: float = 10_000.0
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0      # MLA value head dim (defaults to head_dim)
    qk_nope_dim: int = 0     # MLA non-rope q/k head dim (defaults to head_dim)

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_num_shared: int = 0
    moe_layer_period: int = 1     # MoE on layers where (layer % period == period-1)
    moe_group_size: int = 256     # dispatch group size (tokens)
    capacity_factor: float = 1.25

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0          # 0 -> ceil(d_model/16)
    # "sequential" (O(state) HBM traffic; §Perf F1) | "associative" (baseline)
    ssm_scan_impl: str = "sequential"

    # --- hybrid (jamba): one attention layer per `attn_period` layers ---
    attn_period: int = 0

    # --- VLM: one cross-attention layer per `cross_attn_period` layers ---
    cross_attn_period: int = 0
    num_image_tokens: int = 0

    # --- encoder-decoder (whisper backbone) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0

    # --- misc ---
    layers_per_period: int = 0       # 0 -> family default; >1 stacks several
                                     # layers per scan period (halves the
                                     # seq-resharding boundaries; §Perf C4)
    mlp_activation: str = "swiglu"   # swiglu | gelu
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # Notes from the assignment (provenance, applicability).
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so embedding/logits shard evenly (multiple of 256)."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        if self.ssm_dt_rank:
            return self.ssm_dt_rank
        return -(-self.d_model // 16)

    @property
    def mla_qk_nope(self) -> int:
        return self.qk_nope_dim or self.head_dim

    @property
    def mla_v_dim(self) -> int:
        return self.v_head_dim or self.head_dim

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.moe_num_experts == 0:
            return False
        p = self.moe_layer_period
        return (layer_idx % p) == (p - 1)

    def is_attn_layer(self, layer_idx: int) -> bool:
        """For hybrid stacks: which layers are attention (vs mamba)."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return (layer_idx % self.attn_period) == (self.attn_period - 1)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (dense accounting, experts included)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: shared + top_k routed)."""
        return _param_count(self, active_only=True)

    def reduced(self, **overrides: Any) -> "ModelConfig":
        """A small config of the same family for CPU smoke tests."""
        small: dict[str, Any] = dict(
            num_layers=max(2, min(4, self.num_layers)),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
        )
        if self.use_mla:
            small.update(kv_lora_rank=32, qk_rope_dim=16, qk_nope_dim=32,
                         v_head_dim=32, num_kv_heads=4)
        if self.moe_num_experts:
            small.update(moe_num_experts=4, moe_top_k=min(2, self.moe_top_k),
                         moe_d_ff=64, moe_group_size=16,
                         moe_num_shared=min(1, self.moe_num_shared))
        if self.family in ("ssm", "hybrid"):
            small.update(ssm_state=8, ssm_dt_rank=8)
        if self.family == "hybrid":
            small.update(attn_period=2, num_layers=4, moe_layer_period=2)
        if self.family == "vlm":
            small.update(cross_attn_period=2, num_image_tokens=8, num_layers=4)
        if self.is_encoder_decoder:
            small.update(num_encoder_layers=2)
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2)


def _param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    n = 0
    # embeddings (+ untied logits head)
    n += cfg.padded_vocab * d
    if not cfg.tie_embeddings:
        n += cfg.padded_vocab * d

    def attn_params() -> int:
        if cfg.use_mla:
            q = d * cfg.num_heads * (cfg.mla_qk_nope + cfg.qk_rope_dim)
            kv_a = d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            kv_b = cfg.kv_lora_rank * cfg.num_heads * (cfg.mla_qk_nope + cfg.mla_v_dim)
            o = cfg.num_heads * cfg.mla_v_dim * d
            return q + kv_a + kv_b + o
        q = d * cfg.num_heads * cfg.head_dim
        kv = 2 * d * cfg.num_kv_heads * cfg.head_dim
        o = cfg.num_heads * cfg.head_dim * d
        b = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim if cfg.qkv_bias else 0
        return q + kv + o + b

    def mlp_params(ff: int) -> int:
        mult = 3 if cfg.mlp_activation == "swiglu" else 2
        return mult * d * ff

    def mamba_params() -> int:
        di, ds, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
        return (d * 2 * di          # in_proj
                + di * cfg.ssm_conv  # conv
                + di * (dr + 2 * ds)  # x_proj
                + dr * di + di       # dt_proj
                + di * ds + di       # A_log, D
                + di * d)            # out_proj

    def moe_params() -> int:
        routed = cfg.moe_num_experts * mlp_params(cfg.moe_d_ff)
        if active_only:
            routed = cfg.moe_top_k * mlp_params(cfg.moe_d_ff)
        shared = cfg.moe_num_shared * mlp_params(cfg.moe_d_ff)
        router = d * cfg.moe_num_experts
        return routed + shared + router

    layers = range(cfg.num_layers)
    for i in layers:
        n += 2 * d  # norms
        if cfg.family == "ssm":
            n += mamba_params()
            continue
        if cfg.family == "hybrid" and not cfg.is_attn_layer(i):
            n += mamba_params()
        else:
            n += attn_params()
        if cfg.family == "vlm" and cfg.cross_attn_period and \
                (i % cfg.cross_attn_period) == (cfg.cross_attn_period - 1):
            n += attn_params()  # cross-attention block
        if cfg.is_moe_layer(i):
            n += moe_params()
        elif cfg.d_ff:
            n += mlp_params(cfg.d_ff)
    if cfg.is_encoder_decoder:
        for _ in range(cfg.num_encoder_layers):
            n += 2 * d + attn_params() + mlp_params(cfg.d_ff)
        # decoder cross-attention blocks
        n += cfg.num_layers * (attn_params() + d)
    return n


@dataclass(frozen=True)
class ShapeConfig:
    """An assigned input shape: (seq_len, global_batch, step kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclass(frozen=True)
class MeshConfig:
    shape: tuple[int, ...]
    axis_names: tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def batch_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.axis_names if a in ("pod", "data"))

    @property
    def model_axis_size(self) -> int:
        return self.shape[self.axis_names.index("model")]

    @property
    def data_axis_size(self) -> int:
        n = 1
        for a, s in zip(self.axis_names, self.shape):
            if a in ("pod", "data"):
                n *= s
        return n


SINGLE_POD = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axis_names=("pod", "data", "model"))
SMOKE_MESH = MeshConfig(shape=(1, 1), axis_names=("data", "model"))


@dataclass(frozen=True)
class RunConfig:
    """Training-run hyperparameters."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1          # gradient accumulation
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer memory
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    telemetry_sample_ms: float = 1.0
