"""whisper-medium [audio]: 24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.

Encoder-decoder backbone; the conv/mel frontend is a STUB — ``input_specs()``
provides precomputed frame embeddings (batch, frames, d_model).
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,              # decoder layers
    num_encoder_layers=24,
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    mlp_activation="gelu",
    norm_type="layernorm",
    qkv_bias=True,
    attn_out_bias=True,
    source="arXiv:2212.04356; unverified",
)
