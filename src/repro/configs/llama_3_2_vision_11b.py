"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

Cross-attention image layers every 5th layer; the vision frontend is a STUB
(``input_specs()`` provides precomputed patch embeddings already projected to
d_model). [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    qkv_bias=False,
    rope_theta=500_000.0,
    cross_attn_period=5,
    num_image_tokens=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
