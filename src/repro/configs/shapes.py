"""The four assigned input shapes (seq_len x global_batch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``.  ``long_500k`` requires
sub-quadratic attention and is only lowered for SSM/hybrid families (see
DESIGN.md and the dry-run skip table).
"""
from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524_288, global_batch=1, kind="decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}

# Families for which long_500k decode is runnable (sub-quadratic / O(1)-state).
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(family: str, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not) for an (arch family x shape) cell."""
    if shape.name == "long_500k" and family not in LONG_CONTEXT_FAMILIES:
        return False, ("long_500k needs sub-quadratic attention; this arch is "
                       "pure full-attention (skip per assignment spec)")
    return True, ""
