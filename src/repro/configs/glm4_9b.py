"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE, GQA with 2 KV heads, QKV bias (GLM convention), SwiGLU MLP.
[hf:THUDM/glm-4-9b; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    qkv_bias=True,
    rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b; hf",
)
