"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.

GQA with QKV bias (Qwen convention). 40 heads -> sequence-sharded attention.
[hf:Qwen/Qwen2.5-0.5B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)
