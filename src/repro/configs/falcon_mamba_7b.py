"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024, ssm_state=16.

Mamba-1 architecture (selective SSM), no attention, no MLP (d_ff=0):
each layer is a Mamba block with d_inner = 2*d_model.
[arXiv:2410.05355; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    norm_type="rmsnorm",
    source="arXiv:2410.05355 (mamba1 arch); unverified",
)
