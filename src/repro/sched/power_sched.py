"""Power-aware cluster scheduling on top of Minos predictions (paper §4.3:
POLCA/TAPAS/PAL-style use cases).

Given a power budget and a queue of jobs (each a WorkloadProfile from a
single low-cost profiling run), the scheduler:
  1. runs Algorithm 1 per job to pick a frequency cap for the objective,
  2. estimates each job's per-chip power at that cap from its *neighbor's*
     scaling data (no extra profiling),
  3. packs jobs into the budget (first-fit decreasing), oversubscribing
     against nameplate TDP — the paper's motivating scenario.

Heterogeneity-aware extension: queue entries may carry a fleet
``DeviceInstance`` as a third element, in which case the neighbor's
*relative* power quantile is converted to watts with that device's
effective TDP (nameplate x per-chip power variability) instead of the
scheduler-wide ``tdp_w`` — slow-silicon chips cost more budget, efficient
ones less.  Two-element entries behave exactly as before.
"""
from __future__ import annotations

import operator
from dataclasses import dataclass, field

from repro.core.algorithm1 import (FreqSelection, resolve_objective,
                                   select_optimal_freq)
from repro.core.classify import MinosClassifier, WorkloadProfile

_BUILTIN_QUANTILES = ("p90", "p95", "p99")


def resolve_quantile(quantile):
    """Resolve a provisioning quantile to ``(name, rel_fn)`` where
    ``rel_fn(FreqPoint) -> float`` is the relative per-chip power to reserve.

    Builtin names read the matching ``FreqPoint`` attribute; anything else
    must be a ``QuantilePolicy``-like callable carrying a ``.name`` (custom
    quantiles register by name in ``repro.api.QUANTILES``)."""
    if isinstance(quantile, str):
        if quantile not in _BUILTIN_QUANTILES:
            raise ValueError(f"unknown provisioning quantile {quantile!r} "
                             f"(builtins: {', '.join(_BUILTIN_QUANTILES)}; "
                             f"custom quantiles resolve by name through "
                             f"repro.api.QUANTILES)")
        return quantile, operator.attrgetter(quantile)
    name = getattr(quantile, "name", None)
    if name and callable(quantile):
        return str(name), quantile
    raise ValueError(f"quantile must be a builtin name or a QuantilePolicy-"
                     f"like callable with a .name, got {quantile!r}")


@dataclass
class JobPlan:
    name: str
    chips: int
    cap: float
    predicted_p90_w: float       # per chip, at the scheduler's quantile
    selection: FreqSelection
    device_id: str = ""          # fleet device ("" = homogeneous pod)
    nameplate_w: float = 0.0     # per-chip TDP a non-Minos scheduler reserves
    job_id: str = ""             # queue-entry tag ("" = keyed by name)

    def __post_init__(self):
        # pack()'s first-fit-decreasing sort key, precomputed because a
        # fleet re-pack sorts the same (immutable) plans again and again;
        # a plain attribute so ``attrgetter`` stays a C-level lookup
        self._order_key = (-self.predicted_p90_w * self.chips, self.name,
                           self.device_id, self.job_id)


@dataclass
class ScheduleResult:
    placed: list[JobPlan] = field(default_factory=list)
    deferred: list[str] = field(default_factory=list)
    budget_w: float = 0.0

    @property
    def planned_power_w(self) -> float:
        return sum(j.predicted_p90_w * j.chips for j in self.placed)

    @property
    def nameplate_power_w(self) -> float:
        # what a TDP-provisioned (non-Minos) scheduler would have to reserve
        return sum(j.nameplate_w * j.chips for j in self.placed)

    @property
    def headroom_reclaimed_w(self) -> float:
        """Watts of provisioning headroom Minos recovers vs nameplate TDP."""
        return self.nameplate_power_w - self.planned_power_w


class PowerAwareScheduler:
    """First-fit-decreasing packer over Minos per-job power predictions.

    ``quantile`` selects which spike quantile of the neighbor's scaling data
    is provisioned per chip ("p90" reproduces the original behavior; the
    fleet controller packs at "p99" so coincident cross-job spikes stay
    inside a shared budget).
    """

    def __init__(self, clf: MinosClassifier, tdp_w: float,
                 objective="powercentric", quantile="p90"):
        self.clf = clf
        self.tdp_w = tdp_w
        self.objective_policy = resolve_objective(objective)
        self.objective = self.objective_policy.name
        self.quantile, self._rel = resolve_quantile(quantile)
        # per-(neighbor, cap) relative-power memo: the lookup chain below is
        # a pure function of the reference set, which is immutable
        self._rel_memo: dict[tuple[str, float], float] = {}
        self._ref_by_name: dict[str, WorkloadProfile] | None = None

    def plan_job(self, profile: WorkloadProfile, chips: int,
                 device=None) -> JobPlan:
        sel = select_optimal_freq(profile, self.clf)
        return self.plan_from_selection(sel, chips, device)

    def plan_from_selection(self, sel: FreqSelection, chips: int,
                            device=None, job_id: str = "") -> JobPlan:
        """Build a ``JobPlan`` from an already-made Algorithm 1 selection —
        the fleet controller's path: a job's online ``CapDecision`` carries
        the selection, so re-packing never re-classifies."""
        cap = self.objective_policy.cap(sel)
        rel = self._rel_memo.get((sel.power_neighbor, cap))
        if rel is None:
            if self._ref_by_name is None:
                self._ref_by_name = {r.name: r for r in self.clf.references}
            neighbor = self._ref_by_name[sel.power_neighbor]
            # nearest available frequency in the neighbor's scaling data
            f = min(neighbor.scaling, key=lambda x: abs(x - cap))
            rel = self._rel(neighbor.scaling[f])
            self._rel_memo[(sel.power_neighbor, cap)] = rel
        if device is None:
            watts_base, nameplate, did = self.tdp_w, self.tdp_w, ""
        else:
            watts_base = device.effective_tdp_w
            nameplate = device.nameplate_w
            did = device.device_id
        return JobPlan(sel.target, chips, cap, rel * watts_base, sel,
                       device_id=did, nameplate_w=nameplate, job_id=job_id)

    def migrate_plan(self, plan: JobPlan, device,
                     chips: int | None = None) -> JobPlan:
        """Re-host an existing plan on ``device`` (optionally at a new chip
        count — the elastic-shrink path): the cached Algorithm 1 selection
        is re-costed against the new device's effective TDP, so a migration
        is a dictionary lookup plus arithmetic — **never** a
        re-classification.  Device-portable classification makes this free:
        the neighbor's relative power curve is intrinsic to the workload,
        only the watts conversion is per-device."""
        return self.plan_from_selection(
            plan.selection, plan.chips if chips is None else int(chips),
            device, job_id=plan.job_id)

    def pack(self, plans, budget_w: float) -> ScheduleResult:
        """First-fit-decreasing over prebuilt ``JobPlan``s with a
        deterministic tie-break: equal-power jobs pack in (name, device,
        job) order regardless of queue order (repacking the same queue must
        always produce the same placement)."""
        plans = sorted(plans, key=operator.attrgetter("_order_key"))
        res = ScheduleResult(budget_w=budget_w)
        used = 0.0
        for plan in plans:
            need = plan.predicted_p90_w * plan.chips
            if used + need <= budget_w:
                res.placed.append(plan)
                used += need
            else:
                res.deferred.append(plan.name)
        return res

    def schedule(self, jobs, budget_w: float) -> ScheduleResult:
        """Plan and pack ``jobs`` — ``(profile, chips)`` or ``(profile,
        chips, device)`` tuples — into ``budget_w``."""
        return self.pack((self.plan_job(*job) for job in jobs), budget_w)
