"""Power-aware cluster scheduling on top of Minos predictions (paper §4.3:
POLCA/TAPAS/PAL-style use cases).

Given a pod power budget and a queue of jobs (each a WorkloadProfile from a
single low-cost profiling run), the scheduler:
  1. runs Algorithm 1 per job to pick a frequency cap for the objective,
  2. estimates each job's p90 chip power at that cap from its *neighbor's*
     scaling data (no extra profiling),
  3. packs jobs into the budget (first-fit decreasing), oversubscribing
     against nameplate TDP — the paper's motivating scenario.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.algorithm1 import FreqSelection, select_optimal_freq
from repro.core.classify import MinosClassifier, WorkloadProfile


@dataclass
class JobPlan:
    name: str
    chips: int
    cap: float
    predicted_p90_w: float
    selection: FreqSelection


@dataclass
class ScheduleResult:
    placed: list[JobPlan] = field(default_factory=list)
    deferred: list[str] = field(default_factory=list)
    budget_w: float = 0.0

    @property
    def planned_power_w(self) -> float:
        return sum(j.predicted_p90_w * j.chips for j in self.placed)

    @property
    def nameplate_power_w(self) -> float:
        # what a TDP-provisioned (non-Minos) scheduler would have to assume
        return sum(j.chips for j in self.placed)


class PowerAwareScheduler:
    def __init__(self, clf: MinosClassifier, tdp_w: float,
                 objective: str = "powercentric"):
        self.clf = clf
        self.tdp_w = tdp_w
        self.objective = objective

    def plan_job(self, profile: WorkloadProfile, chips: int) -> JobPlan:
        sel = select_optimal_freq(profile, self.clf)
        cap = sel.cap(self.objective)
        neighbor = next(r for r in self.clf.references
                        if r.name == sel.power_neighbor)
        # nearest available frequency in the neighbor's scaling data
        f = min(neighbor.scaling, key=lambda x: abs(x - cap))
        p90_rel = neighbor.scaling[f].p90
        return JobPlan(profile.name, chips, cap, p90_rel * self.tdp_w, sel)

    def schedule(self, jobs: list[tuple[WorkloadProfile, int]],
                 budget_w: float) -> ScheduleResult:
        # first-fit decreasing with a deterministic tie-break: equal-power
        # jobs pack in name order regardless of queue order (repacking the
        # same queue must always produce the same placement)
        plans = sorted((self.plan_job(p, c) for p, c in jobs),
                       key=lambda j: (-j.predicted_p90_w * j.chips, j.name))
        res = ScheduleResult(budget_w=budget_w)
        used = 0.0
        for plan in plans:
            need = plan.predicted_p90_w * plan.chips
            if used + need <= budget_w:
                res.placed.append(plan)
                used += need
            else:
                res.deferred.append(plan.name)
        return res
