"""Power-aware cluster scheduling on top of Minos predictions (paper §4.3:
POLCA/TAPAS/PAL-style use cases).

Given a power budget and a queue of jobs (each a WorkloadProfile from a
single low-cost profiling run), the scheduler:
  1. runs Algorithm 1 per job to pick a frequency cap for the objective,
  2. estimates each job's per-chip power at that cap from its *neighbor's*
     scaling data (no extra profiling),
  3. packs jobs into the budget (first-fit decreasing), oversubscribing
     against nameplate TDP — the paper's motivating scenario.

Heterogeneity-aware extension: queue entries may carry a fleet
``DeviceInstance`` as a third element, in which case the neighbor's
*relative* power quantile is converted to watts with that device's
effective TDP (nameplate x per-chip power variability) instead of the
scheduler-wide ``tdp_w`` — slow-silicon chips cost more budget, efficient
ones less.  Two-element entries behave exactly as before.
"""
from __future__ import annotations

import math
import operator
from bisect import bisect_left
from dataclasses import dataclass, field

from repro.core.algorithm1 import (FreqSelection, resolve_objective,
                                   select_optimal_freq)
from repro.core.classify import MinosClassifier, WorkloadProfile

_BUILTIN_QUANTILES = ("p90", "p95", "p99")

# Exact fixed-point scale for power accounting.  Every finite float is
# p/q with q a power of two <= 2**1074, so scaling by 2**1100 embeds all
# per-job needs and budgets losslessly into integers: greedy first-fit
# accumulation becomes associative, which is what lets the incremental
# packer's checkpointed partial sums reproduce ``pack()`` byte-for-byte
# (float partial sums would drift by an ulp at block boundaries).
_SCALE = 1 << 1100

# budget sentinels for non-finite budgets (match float comparison
# semantics: +inf admits every finite need, -inf/NaN admit nothing)
_FIT_ALL = object()
_FIT_NONE = object()


def _exact(x: float) -> int:
    """Losslessly embed a finite float into the ``_SCALE`` integer grid."""
    n, d = x.as_integer_ratio()
    return n * (_SCALE // d)


def _exact_budget(budget_w) -> "int | object":
    b = float(budget_w)
    if math.isfinite(b):
        return _exact(b)
    return _FIT_ALL if b > 0 else _FIT_NONE


def _fits(total: int, budget) -> bool:
    if type(budget) is int:
        return total <= budget
    return budget is _FIT_ALL


def resolve_quantile(quantile):
    """Resolve a provisioning quantile to ``(name, rel_fn)`` where
    ``rel_fn(FreqPoint) -> float`` is the relative per-chip power to reserve.

    Builtin names read the matching ``FreqPoint`` attribute; anything else
    must be a ``QuantilePolicy``-like callable carrying a ``.name`` (custom
    quantiles register by name in ``repro.api.QUANTILES``)."""
    if isinstance(quantile, str):
        if quantile not in _BUILTIN_QUANTILES:
            raise ValueError(f"unknown provisioning quantile {quantile!r} "
                             f"(builtins: {', '.join(_BUILTIN_QUANTILES)}; "
                             f"custom quantiles resolve by name through "
                             f"repro.api.QUANTILES)")
        return quantile, operator.attrgetter(quantile)
    name = getattr(quantile, "name", None)
    if name and callable(quantile):
        return str(name), quantile
    raise ValueError(f"quantile must be a builtin name or a QuantilePolicy-"
                     f"like callable with a .name, got {quantile!r}")


@dataclass
class JobPlan:
    name: str
    chips: int
    cap: float
    predicted_p90_w: float       # per chip, at the scheduler's quantile
    selection: FreqSelection
    device_id: str = ""          # fleet device ("" = homogeneous pod)
    nameplate_w: float = 0.0     # per-chip TDP a non-Minos scheduler reserves
    job_id: str = ""             # queue-entry tag ("" = keyed by name)

    def __post_init__(self):
        # pack()'s first-fit-decreasing sort key, precomputed because a
        # fleet re-pack sorts the same (immutable) plans again and again;
        # a plain attribute so ``attrgetter`` stays a C-level lookup
        self._order_key = (-self.predicted_p90_w * self.chips, self.name,
                           self.device_id, self.job_id)
        # exact fixed-point power terms (None when non-finite): packing
        # arithmetic runs on these so incremental and full packs agree
        # bit-for-bit no matter how the additions associate
        need = self.predicted_p90_w * self.chips
        self._need = need
        self._need_exact = _exact(need) if math.isfinite(need) else None
        nameplate = self.nameplate_w * self.chips
        self._nameplate_exact = (_exact(nameplate)
                                 if math.isfinite(nameplate) else None)


@dataclass
class ScheduleResult:
    placed: list[JobPlan] = field(default_factory=list)
    deferred: list[str] = field(default_factory=list)
    budget_w: float = 0.0

    @property
    def planned_power_w(self) -> float:
        return sum(j.predicted_p90_w * j.chips for j in self.placed)

    @property
    def nameplate_power_w(self) -> float:
        # what a TDP-provisioned (non-Minos) scheduler would have to reserve
        return sum(j.nameplate_w * j.chips for j in self.placed)

    @property
    def headroom_reclaimed_w(self) -> float:
        """Watts of provisioning headroom Minos recovers vs nameplate TDP."""
        return self.nameplate_power_w - self.planned_power_w


class RepackStats:
    """Power accounting for a superseded fleet re-pack.

    The fleet's ``repacks`` history materializes full ``ScheduleResult``s
    lazily; once the live packer has moved past an entry, only its exact
    power totals are retained — enough for every aggregate consumer
    (budget-compliance sweeps, reports).  Reading ``placed``/``deferred``
    on a superseded entry raises: per-job placements of historical packs
    are not kept at fleet scale."""

    __slots__ = ("planned_power_w", "nameplate_power_w", "budget_w")

    def __init__(self, planned_power_w: float, nameplate_power_w: float,
                 budget_w: float):
        self.planned_power_w = planned_power_w
        self.nameplate_power_w = nameplate_power_w
        self.budget_w = budget_w

    @property
    def headroom_reclaimed_w(self) -> float:
        return self.nameplate_power_w - self.planned_power_w

    @property
    def placed(self):
        raise AttributeError(
            "this re-pack has been superseded; per-job placements are only "
            "materialized for the most recent pack (read fleet.repacks[-1] "
            "before mutating the fleet, or use PowerAwareScheduler.pack)")

    deferred = placed

    def __repr__(self):
        return (f"RepackStats(planned_power_w={self.planned_power_w!r}, "
                f"nameplate_power_w={self.nameplate_power_w!r}, "
                f"budget_w={self.budget_w!r})")


class PowerAwareScheduler:
    """First-fit-decreasing packer over Minos per-job power predictions.

    ``quantile`` selects which spike quantile of the neighbor's scaling data
    is provisioned per chip ("p90" reproduces the original behavior; the
    fleet controller packs at "p99" so coincident cross-job spikes stay
    inside a shared budget).
    """

    def __init__(self, clf: MinosClassifier, tdp_w: float,
                 objective="powercentric", quantile="p90"):
        self.clf = clf
        self.tdp_w = tdp_w
        self.objective_policy = resolve_objective(objective)
        self.objective = self.objective_policy.name
        self.quantile, self._rel = resolve_quantile(quantile)
        # per-(neighbor, cap) relative-power memo: the lookup chain below is
        # a pure function of the reference set, which is immutable for the
        # lifetime of the attached classifier (adopt_classifier resets it)
        self._rel_memo: dict[tuple[str, float], float] = {}
        self._ref_by_name: dict[str, WorkloadProfile] | None = None

    def adopt_classifier(self, clf: MinosClassifier) -> None:
        """Swap the reference classifier (a discovery promotion/rollback
        published a new library version) and drop the per-reference memos —
        they key on neighbor *names*, whose resolution must follow the new
        membership.  Plans already built keep their cached selections;
        re-costing them resolves names against the new reference set."""
        self.clf = clf
        self._rel_memo.clear()
        self._ref_by_name = None

    def plan_job(self, profile: WorkloadProfile, chips: int,
                 device=None) -> JobPlan:
        sel = select_optimal_freq(profile, self.clf)
        return self.plan_from_selection(sel, chips, device)

    def plan_from_selection(self, sel: FreqSelection, chips: int,
                            device=None, job_id: str = "") -> JobPlan:
        """Build a ``JobPlan`` from an already-made Algorithm 1 selection —
        the fleet controller's path: a job's online ``CapDecision`` carries
        the selection, so re-packing never re-classifies."""
        cap = self.objective_policy.cap(sel)
        rel = self._rel_memo.get((sel.power_neighbor, cap))
        if rel is None:
            if self._ref_by_name is None:
                self._ref_by_name = {r.name: r for r in self.clf.references}
            neighbor = self._ref_by_name[sel.power_neighbor]
            # nearest available frequency in the neighbor's scaling data
            f = min(neighbor.scaling, key=lambda x: abs(x - cap))
            rel = self._rel(neighbor.scaling[f])
            self._rel_memo[(sel.power_neighbor, cap)] = rel
        if device is None:
            watts_base, nameplate, did = self.tdp_w, self.tdp_w, ""
        else:
            watts_base = device.effective_tdp_w
            nameplate = device.nameplate_w
            did = device.device_id
        return JobPlan(sel.target, chips, cap, rel * watts_base, sel,
                       device_id=did, nameplate_w=nameplate, job_id=job_id)

    def migrate_plan(self, plan: JobPlan, device,
                     chips: int | None = None) -> JobPlan:
        """Re-host an existing plan on ``device`` (optionally at a new chip
        count — the elastic-shrink path): the cached Algorithm 1 selection
        is re-costed against the new device's effective TDP, so a migration
        is a dictionary lookup plus arithmetic — **never** a
        re-classification.  Device-portable classification makes this free:
        the neighbor's relative power curve is intrinsic to the workload,
        only the watts conversion is per-device."""
        return self.plan_from_selection(
            plan.selection, plan.chips if chips is None else int(chips),
            device, job_id=plan.job_id)

    def pack(self, plans, budget_w: float) -> ScheduleResult:
        """First-fit-decreasing over prebuilt ``JobPlan``s with a
        deterministic tie-break: equal-power jobs pack in (name, device,
        job) order regardless of queue order (repacking the same queue must
        always produce the same placement).

        Accounting runs on exact fixed-point integers (``plan._need_exact``)
        rather than floats, so the sum of placed needs never exceeds the
        budget by rounding and — critically — ``IncrementalPacker`` can
        reproduce this result byte-for-byte from checkpointed partial sums.
        Plans with non-finite need always defer under a finite budget, and
        a non-finite budget admits everything (+inf) or nothing (-inf/NaN),
        matching the float comparison semantics this loop always had."""
        plans = sorted(plans, key=operator.attrgetter("_order_key"))
        res = ScheduleResult(budget_w=budget_w)
        budget = _exact_budget(budget_w)
        used = 0
        for plan in plans:
            need = plan._need_exact
            if need is not None and _fits(used + need, budget):
                res.placed.append(plan)
                used += need
            else:
                res.deferred.append(plan.name)
        return res

    def schedule(self, jobs, budget_w: float) -> ScheduleResult:
        """Plan and pack ``jobs`` — ``(profile, chips)`` or ``(profile,
        chips, device)`` tuples — into ``budget_w``."""
        return self.pack((self.plan_job(*job) for job in jobs), budget_w)

    def packer(self, budget_w: float = 0.0,
               block_size: int = 128) -> "IncrementalPacker":
        """A fresh :class:`IncrementalPacker` seeded with ``budget_w`` —
        the control-plane companion to one-shot :meth:`pack`."""
        return IncrementalPacker(budget_w=budget_w, block_size=block_size)


class _Block:
    """One chunk of the packer's FFD-ordered plan sequence.

    ``placed_need``/``placed_nameplate`` are exact sums over the block's
    placed plans; ``min_fit`` is the minimum over the block's *deferred*
    plans of (in-block placed need before it + its own need) — the
    tightest admission that could flip if upstream usage shrinks.  Both
    let a re-flow decide in O(1) that a block's placements cannot change."""

    __slots__ = ("plans", "keys", "placed", "placed_need",
                 "placed_nameplate", "min_fit", "dirty")

    def __init__(self, plans, keys, placed):
        self.plans = plans
        self.keys = keys
        self.placed = placed
        self.placed_need = 0
        self.placed_nameplate = 0
        self.min_fit = None
        self.dirty = True


class IncrementalPacker:
    """First-fit-decreasing packing as a maintained structure, not a pass.

    Holds the live ``JobPlan`` population in ``_order_key`` order, chunked
    into ~``block_size`` blocks with checkpointed exact power sums, so one
    insert/remove or a budget change re-runs the greedy scan only over the
    blocks whose placements can actually change: the mutated block, plus
    any downstream block where the shifted entry usage could flip a
    placement (checked in O(1) per block via ``placed_need``/``min_fit``).
    Everything upstream — and every downstream block that provably packs
    the same — is skipped.  Per-event cost is O(block + n/block) instead
    of the full pack's O(n log n).

    Re-flows are **read-coalesced**: a mutation only splices the plan into
    its block and marks the dirty range (cheap list surgery, no exact
    arithmetic), and the greedy re-flow runs once at the next read
    (``result()`` / ``stats()`` / the power properties).  A burst of
    mutations between reads — a fleet tick deciding hundreds of jobs, one
    coalesced repack at the end — pays for ONE re-flow, not one per event,
    while a read-per-event caller sees exactly the per-event incremental
    cost.

    ``result()`` materializes a ``ScheduleResult`` **byte-identical** to
    ``PowerAwareScheduler.pack(plans, budget_w)`` over the same population
    (hypothesis-pinned in ``tests/test_incremental_pack.py``); both sides
    run on the same exact fixed-point arithmetic, so the equivalence is
    exact, not approximate.  ``version`` increments on every mutation —
    consumers holding a lazy reference can tell whether their snapshot is
    still the live state.

    Restrictions that keep the equivalence honest: plans must have finite
    need/nameplate and pairwise-distinct ``_order_key``s (the fleet always
    satisfies both — ``job_id`` is unique per controller); violations
    raise ``ValueError`` and the caller falls back to full packs."""

    def __init__(self, budget_w: float = 0.0, block_size: int = 128):
        self.budget_w = budget_w
        self._budget = _exact_budget(budget_w)
        self._block_size = max(8, int(block_size))
        self._blocks: list[_Block] = []
        self._last_keys: list[tuple] = []
        self._n = 0
        self.version = 0
        self._placed_need = 0          # exact, over all blocks
        self._placed_nameplate = 0     # exact, over all blocks
        self._dirty_lo: int | None = None   # pending re-flow block range
        self._dirty_hi: int | None = None
        self._prune_pending = False

    def __len__(self) -> int:
        return self._n

    @property
    def planned_power_w(self) -> float:
        self._flush()
        return self._placed_need / _SCALE

    @property
    def nameplate_power_w(self) -> float:
        self._flush()
        return self._placed_nameplate / _SCALE

    @property
    def headroom_reclaimed_w(self) -> float:
        self._flush()
        return (self._placed_nameplate - self._placed_need) / _SCALE

    # -- mutation ----------------------------------------------------------

    def insert(self, plan: JobPlan) -> None:
        """Admit ``plan`` into the packed population.

        O(block) list surgery now; the exact-arithmetic re-flow is
        deferred to the next read and shared by every mutation since."""
        if plan._need_exact is None or plan._nameplate_exact is None:
            raise ValueError(
                f"incremental packing requires finite power terms: "
                f"{plan.job_id or plan.name} has need={plan._need!r}, "
                f"nameplate={plan.nameplate_w * plan.chips!r}")
        key = plan._order_key
        if not self._blocks:
            self._blocks.append(_Block([plan], [key], [False]))
            self._last_keys.append(key)
            bi = 0
        else:
            bi = min(bisect_left(self._last_keys, key),
                     len(self._blocks) - 1)
            b = self._blocks[bi]
            pos = bisect_left(b.keys, key)
            if pos < len(b.keys) and b.keys[pos] == key:
                raise ValueError(
                    f"duplicate packing key for {plan.job_id or plan.name}: "
                    f"incremental packing requires distinct (need, name, "
                    f"device, job) identities")
            b.plans.insert(pos, plan)
            b.keys.insert(pos, key)
            b.placed.insert(pos, False)
            b.dirty = True
            if pos == len(b.keys) - 1:
                self._last_keys[bi] = key
        self._n += 1
        self.version += 1
        self._mark(bi)
        if len(self._blocks[bi].keys) > 2 * self._block_size:
            self._split(bi)

    def remove(self, plan: JobPlan) -> None:
        """Evict ``plan`` from the packed population.

        O(block) list surgery now; re-flow (and empty-block pruning) is
        deferred to the next read.  An emptied block keeps its stale last
        key until then — sound, because the vacated key range holds no
        plans, so lookups routed there correctly miss."""
        key = plan._order_key
        bi = bisect_left(self._last_keys, key)
        if bi == len(self._blocks):
            raise KeyError(f"plan not packed: {plan.job_id or plan.name}")
        b = self._blocks[bi]
        pos = bisect_left(b.keys, key)
        if (pos >= len(b.keys) or b.keys[pos] != key
                or (b.plans[pos] is not plan and b.plans[pos] != plan)):
            raise KeyError(f"plan not packed: {plan.job_id or plan.name}")
        del b.plans[pos], b.keys[pos], b.placed[pos]
        b.dirty = True
        if b.keys:
            self._last_keys[bi] = b.keys[-1]
        else:
            self._prune_pending = True
        self._n -= 1
        self.version += 1
        self._mark(bi)

    def replace(self, old: JobPlan, new: JobPlan) -> None:
        """Migration/shrink: swap one plan for its re-costed successor."""
        self.remove(old)
        self.insert(new)

    def set_budget(self, budget_w: float) -> None:
        """Re-flow every block against a new budget — still O(1) per block
        whose placements provably cannot change."""
        b, cur = float(budget_w), float(self.budget_w)
        if b == cur and math.copysign(1.0, b) == math.copysign(1.0, cur):
            self.budget_w = budget_w    # bit-identical budget: no re-flow
            return
        old = self._budget
        self.budget_w = budget_w
        self._budget = _exact_budget(budget_w)
        self.version += 1
        if self._budget is old or (type(old) is int and
                                   type(self._budget) is int and
                                   old == self._budget):
            return                      # same admissions (e.g. int vs float)
        self._flush(budget_changed=True)

    # -- reads -------------------------------------------------------------

    def result(self) -> ScheduleResult:
        """Materialize the current placement as a ``ScheduleResult``
        byte-identical to ``pack()`` over the same plans and budget."""
        self._flush()
        res = ScheduleResult(budget_w=self.budget_w)
        placed, deferred = res.placed, res.deferred
        for b in self._blocks:
            flags = b.placed
            for i, plan in enumerate(b.plans):
                if flags[i]:
                    placed.append(plan)
                else:
                    deferred.append(plan.name)
        return res

    def stats(self) -> RepackStats:
        """O(1) power totals of the current placement."""
        return RepackStats(self.planned_power_w, self.nameplate_power_w,
                           self.budget_w)

    # -- internals ---------------------------------------------------------

    def _mark(self, bi: int) -> None:
        # widen the pending re-flow range to cover block ``bi``
        if self._dirty_lo is None:
            self._dirty_lo = self._dirty_hi = bi
        else:
            if bi < self._dirty_lo:
                self._dirty_lo = bi
            if bi > self._dirty_hi:
                self._dirty_hi = bi

    def _flush(self, budget_changed: bool = False) -> None:
        # run the deferred re-flow over the marked range (everything, on a
        # budget change), then prune blocks emptied by pending removes
        if budget_changed:
            lo, hi = 0, len(self._blocks) - 1
        elif self._dirty_lo is None:
            return
        else:
            lo, hi = self._dirty_lo, self._dirty_hi
        self._dirty_lo = self._dirty_hi = None
        self._reflow(lo, budget_changed=budget_changed, until=hi)
        if self._prune_pending:
            self._prune_pending = False
            if any(not b.keys for b in self._blocks):
                self._blocks[:] = [b for b in self._blocks if b.keys]
                self._last_keys[:] = [b.keys[-1] for b in self._blocks]

    def _split(self, bi: int) -> None:
        b = self._blocks[bi]
        half = len(b.keys) // 2
        left = _Block(b.plans[:half], b.keys[:half], b.placed[:half])
        right = _Block(b.plans[half:], b.keys[half:], b.placed[half:])
        self._blocks[bi:bi + 1] = [left, right]
        self._last_keys[bi:bi + 1] = [left.keys[-1], right.keys[-1]]
        # the split shifts every block index > bi by one; keep the pending
        # dirty range spanning the same (now wider) set of blocks
        if self._dirty_lo is not None and self._dirty_lo > bi:
            self._dirty_lo += 1
        if self._dirty_hi is not None and self._dirty_hi >= bi:
            self._dirty_hi += 1

    def _can_skip(self, b: _Block, enter: int) -> bool:
        # sound O(1) stability test for a clean block under the (possibly
        # shifted) entry usage ``enter`` and the current budget: every
        # placed plan would still place (worst case is the block's full
        # placed need on top of ``enter``) and every deferred plan would
        # still defer (best case is the block's tightest deferred fit)
        if not _fits(enter + b.placed_need, self._budget):
            return False
        return b.min_fit is None or not _fits(enter + b.min_fit,
                                              self._budget)

    def _reflow(self, bi: int, budget_changed: bool = False,
                until: int | None = None) -> None:
        if until is None:
            until = bi
        blocks = self._blocks
        prefix = 0
        for j in range(bi):
            prefix += blocks[j].placed_need
        enter_old = enter_new = prefix
        for j in range(bi, len(blocks)):
            b = blocks[j]
            ps_old = b.placed_need
            if not b.dirty:
                if not budget_changed and j > until and enter_new == enter_old:
                    break               # nothing downstream can differ
                if self._can_skip(b, enter_new):
                    enter_old += ps_old
                    enter_new += ps_old
                    continue
            self._recompute(b, enter_new)
            b.dirty = False
            enter_old += ps_old
            enter_new += b.placed_need
        self._placed_need = sum(b.placed_need for b in blocks)
        self._placed_nameplate = sum(b.placed_nameplate for b in blocks)

    def _recompute(self, b: _Block, enter: int) -> None:
        budget = self._budget
        used = enter
        placed_need = placed_nameplate = within = 0
        min_fit = None
        flags = b.placed
        for i, plan in enumerate(b.plans):
            need = plan._need_exact
            if _fits(used + need, budget):
                flags[i] = True
                used += need
                within += need
                placed_need += need
                placed_nameplate += plan._nameplate_exact
            else:
                flags[i] = False
                fit = within + need
                if min_fit is None or fit < min_fit:
                    min_fit = fit
        b.placed_need = placed_need
        b.placed_nameplate = placed_nameplate
        b.min_fit = min_fit
