from repro.sched.dvfs import FrequencyActuator, SimActuator
from repro.sched.power_sched import (IncrementalPacker, JobPlan,
                                     PowerAwareScheduler, RepackStats,
                                     ScheduleResult)
