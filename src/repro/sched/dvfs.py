"""DVFS actuation interface.

On a real deployment ``FrequencyActuator`` binds to the platform power API
(the TPU analogue of ``rocm-smi --setsclk``); here the simulated actuator
just records the cap and exposes it to the telemetry simulator.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.analysis.hardware import ChipSpec, V5E


class FrequencyActuator:
    """Abstract actuator: set/get a normalized SM/MXU frequency cap."""

    def set_cap(self, freq: float) -> None:
        raise NotImplementedError

    def get_cap(self) -> float:
        raise NotImplementedError


@dataclass
class SimActuator(FrequencyActuator):
    spec: ChipSpec = V5E
    _cap: float = 1.0
    history: list = field(default_factory=list)
    device_id: str = ""          # fleet device this actuator drives

    @classmethod
    def for_device(cls, device) -> "SimActuator":
        """Actuator bound to a fleet ``DeviceInstance``: clamps to that
        instance's DVFS range and records which device it drives."""
        return cls(spec=device.spec, device_id=device.device_id)

    def set_cap(self, freq: float) -> None:
        freq = min(max(freq, self.spec.f_min), self.spec.f_max)
        self._cap = freq
        self.history.append(freq)

    def get_cap(self) -> float:
        return self._cap
