"""Deterministic synthetic data pipeline.

Two sources:
  * ``SyntheticTokens`` — iid zipf-ish token streams, deterministic per
    (seed, step, host_shard) so multi-host runs produce disjoint shards and
    restarts resume exactly (step-indexed, no hidden iterator state).
  * ``ByteCorpus`` — next-byte prediction over a repeating text corpus, used
    by examples so training loss visibly decreases.

Both yield {"tokens": (b, s) int32, "labels": (b, s) int32} plus optional
modality stubs (image_embeds / frames) for vlm/audio archs.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


class SyntheticTokens:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 host_index: int = 0, host_count: int = 1):
        if shape.global_batch % host_count:
            raise ValueError("global batch must divide host count")
        self.cfg, self.shape = cfg, shape
        self.seed, self.host_index, self.host_count = seed, host_index, host_count
        self.local_batch = shape.global_batch // host_count

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4099 + self.host_index)
        b, s, v = self.local_batch, self.shape.seq_len, self.cfg.vocab_size
        # zipf-flavored marginal: realistic token frequency skew
        u = rng.random((b, s + 1))
        toks = np.minimum((v * u ** 3).astype(np.int64), v - 1).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        self._add_modalities(out, rng, b, s)
        return out

    def _add_modalities(self, out: dict, rng, b: int, s: int) -> None:
        cfg = self.cfg
        if cfg.family == "vlm":
            out["image_embeds"] = (rng.standard_normal(
                (b, cfg.num_image_tokens, cfg.d_model)) * 0.02).astype(np.float32)
        if cfg.family == "audio":
            out["frames"] = (rng.standard_normal(
                (b, s, cfg.d_model)) * 0.02).astype(np.float32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


_DEFAULT_TEXT = (
    "minos judges every workload that enters the cluster. the power spikes "
    "are binned by magnitude and the spikes vector is clustered with cosine "
    "distance. compute bound workloads shift left under frequency caps while "
    "memory bound workloads barely move. "
) * 64


class ByteCorpus:
    """Next-byte LM over a repeating corpus; vocab is bytes (<=256)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 text: str = _DEFAULT_TEXT):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        data = np.frombuffer(text.encode(), np.uint8).astype(np.int32)
        self.data = data % cfg.vocab_size
        self.shape_cfg = shape

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(self.seed * 7919 + step)
        b, s = self.shape.global_batch, self.shape.seq_len
        starts = rng.integers(0, len(self.data) - s - 1, size=b)
        toks = np.stack([self.data[st:st + s + 1] for st in starts])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
