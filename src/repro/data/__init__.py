from repro.data.synthetic import ByteCorpus, SyntheticTokens
