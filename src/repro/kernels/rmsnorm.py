"""Fused RMSNorm as a Pallas TPU kernel (row-tiled, fp32 statistics)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-5,
                   block_rows: int = 256, interpret: bool = True) -> jax.Array:
    """x: (n, d); scale: (d,)."""
    n, d = x.shape
    block_rows = min(block_rows, n)
    while n % block_rows:
        block_rows -= 1
    grid = (n // block_rows,)
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale[None, :])
