"""EMA power filter (paper §4.1 step 2) as a Pallas TPU scan kernel.

The on-device analogue of ``repro.core.spikes.ema_filter``: a fleet-scale
deployment filters millions of 1 kHz energy-counter samples per chip per day
next to the ``spike_hist`` binning kernel, so the trace never leaves the
device raw.

The first-order recurrence out_t = alpha*x_t + (1-alpha)*out_{t-1} is
strictly sequential in time, so the trace is laid out time-major as
(rows, 128) tiles and the grid walks row-blocks sequentially with the filter
state carried in SMEM scratch.  Within a row the 128-sample inclusive scan
is one (1, 128) @ (128, 128) matmul against a precomputed lower-triangular
decay matrix L[j, i] = w^(i-j) — MXU work instead of 128 dependent VPU steps
— and the carry enters as h * w^(lane+1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_COLS = 128


def _ema_kernel(x_ref, l_ref, wp_ref, o_ref, h_ref, *, alpha: float,
                block_rows: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        # filter state is seeded with the first sample (out[-1] := x[0])
        h_ref[0, 0] = x_ref[0, 0]

    L = l_ref[...]                       # (128, 128) decay matrix
    wp = wp_ref[...]                     # (1, 128): w^(lane+1) carry weights

    def row(r, h):
        c = alpha * x_ref[pl.ds(r, 1), :].astype(jnp.float32)
        out = jnp.dot(c, L, preferred_element_type=jnp.float32) + h * wp
        o_ref[pl.ds(r, 1), :] = out
        return out[0, _COLS - 1]

    h_ref[0, 0] = jax.lax.fori_loop(0, block_rows, row, h_ref[0, 0])


def ema_scan_pallas(power: jax.Array, alpha: float = 0.5,
                    block_rows: int = 8,
                    interpret: bool | None = None) -> jax.Array:
    """power: (n,) samples -> (n,) EMA-filtered samples (float32).

    ``interpret=None`` autodetects: compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = power.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.float32)
    w = jnp.float32(1.0 - alpha)
    x = power.astype(jnp.float32)
    rows = -(-n // _COLS)
    rows = -(-rows // block_rows) * block_rows          # pad to grid multiple
    x = jnp.pad(x, (0, rows * _COLS - n)).reshape(rows, _COLS)
    # L[j, i] = w^(i-j) for i >= j: one matmul performs the in-row scan
    jj = jax.lax.broadcasted_iota(jnp.float32, (_COLS, _COLS), 0)
    ii = jax.lax.broadcasted_iota(jnp.float32, (_COLS, _COLS), 1)
    L = jnp.where(ii >= jj, w ** jnp.maximum(ii - jj, 0.0), 0.0)
    wp = (w ** (jax.lax.broadcasted_iota(jnp.float32, (1, _COLS), 1) + 1.0))
    kernel = functools.partial(_ema_kernel, alpha=alpha,
                               block_rows=block_rows)
    out = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, _COLS), lambda i: (i, 0)),
            pl.BlockSpec((_COLS, _COLS), lambda i: (0, 0)),
            pl.BlockSpec((1, _COLS), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, _COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _COLS), jnp.float32),
        scratch_shapes=[pltpu.SMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(x, L, wp)
    return out.reshape(-1)[:n]
