"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """Exact softmax attention with GQA. q: (b, sq, H, dh); k/v: (b, skv, KV, dh)."""
    b, sq, H, dh = q.shape
    skv, KV = k.shape[1], k.shape[2]
    qper = H // KV
    qg = q.reshape(b, sq, KV, qper, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkpd,bjkd->bkpqj", qg, kf) * (dh ** -0.5)
    if causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        mask = (qpos + (skv - sq)) >= kpos
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkpqj,bjkd->bqkpd", p, vf)
    return o.reshape(b, sq, H, dh).astype(q.dtype)


def ssm_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array, D: jax.Array,
                 h0: jax.Array | None = None):
    """Selective-SSM scan oracle.

    x, dt: (b, s, di); A: (di, ds); B, C: (b, s, ds); D: (di,).
    Returns (y (b, s, di), h_last (b, di, ds)); fp32 internally.
    """
    b, s, di = x.shape
    ds = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, di, ds), jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t, :, None] * Af[None])              # (b, di, ds)
        u = (dtf[:, t] * xf[:, t])[:, :, None] * Bf[:, t, None, :]
        h = a * h + u
        y = jnp.einsum("bin,bn->bi", h, Cf[:, t])
        return h, y

    h_last, ys = jax.lax.scan(step, h0, jnp.arange(s))
    y = ys.transpose(1, 0, 2) + D.astype(jnp.float32)[None, None] * xf
    return y.astype(x.dtype), h_last


def spike_hist_ref(rel_power: jax.Array, n_bins: int, lo: float = 0.5,
                   hi: float = 2.0) -> jax.Array:
    """Histogram of relative power magnitudes r in [lo, hi) over n_bins.

    Matches core.spikes.spike_vector *counts* (un-normalized), computed in
    jnp. rel_power: (n,) float32.
    """
    r = rel_power.astype(jnp.float32)
    width = (hi - lo) / n_bins
    idx = jnp.clip(((r - lo) / width).astype(jnp.int32), 0, n_bins - 1)
    valid = r >= lo
    onehot = jax.nn.one_hot(idx, n_bins, dtype=jnp.float32) * valid[:, None]
    return jnp.sum(onehot, axis=0)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """x: (n, d); scale: (d,)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
