"""Flash attention (fwd) as a Pallas TPU kernel.

TPU-native adaptation (DESIGN.md hardware-adaptation notes): blocks are
MXU-aligned (q/kv block x head_dim multiples of 128), the online-softmax
state (acc, m, l) lives in VMEM scratch and is carried across the kv grid
dimension, which is declared "arbitrary" (sequential) so the carry is legal.
Causal blocks above the diagonal are skipped with ``pl.when`` — the
dominant win over the masked jnp fallback at long sequence.

Layout: q (b, H, sq, dh), k/v (b, KV, skv, dh) — heads in the grid, seq x
head_dim as the (sublane, lane) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               causal: bool, scale: float, block_q: int, block_k: int,
               kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    run = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())))

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, block_q: int = 512,
                         block_k: int = 512,
                         interpret: bool = True) -> jax.Array:
    """q: (b, H, sq, dh); k/v: (b, KV, skv, dh) -> (b, H, sq, dh)."""
    b, H, sq, dh = q.shape
    KV, skv = k.shape[1], k.shape[2]
    qper = H // KV
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    grid = (b, H, sq // block_q, skv // block_k)
    kernel = functools.partial(
        _fa_kernel, causal=causal, scale=dh ** -0.5,
        block_q=block_q, block_k=block_k, kv_len=skv)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik, qper=qper: (ib, ih // qper, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda ib, ih, iq, ik, qper=qper: (ib, ih // qper, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, H, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # m
            pltpu.VMEM((block_q, 1), jnp.float32),    # l
        ],
        interpret=interpret,
    )(q, k, v)
