from repro.kernels.ops import (ema_scan, flash_attention, rmsnorm, spike_hist,
                               ssm_scan)
from repro.kernels import ref
