"""Power-spike histogram as a Pallas TPU kernel — Minos's own telemetry
binning (paper §4.1.1) as an on-device streaming op.

A fleet-scale deployment bins millions of 1 kHz power samples per chip per
day; doing it on-device (VPU compare + reduce per bin over VMEM-resident
sample tiles, accumulated across the sequential grid) avoids shipping raw
traces to the host.  The op is bandwidth-bound streaming: one pass over the
samples, one (8, 128) accumulator tile resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_OUT_COLS = 128   # one padded output tile; n_bins <= 128


def _hist_kernel(r_ref, o_ref, *, n_bins: int, lo: float, hi: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    r = r_ref[...].astype(jnp.float32)            # (rows, 128)
    width = (hi - lo) / n_bins
    # bin index per sample; out-of-range -> -1 (not counted)
    idx = jnp.floor((r - lo) / width).astype(jnp.int32)
    idx = jnp.where(r >= lo, jnp.minimum(idx, n_bins - 1), -1)
    # accumulate counts: compare against the 128 bin ids held in the lanes
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, _OUT_COLS), 1)
    counts = jnp.sum(
        (idx.reshape(-1, 1) == bins).astype(jnp.float32), axis=0, keepdims=True)
    o_ref[0:1, :] += counts


def spike_hist_pallas(rel_power: jax.Array, n_bins: int, lo: float = 0.5,
                      hi: float = 2.0, block_rows: int = 64,
                      interpret: bool | None = None) -> jax.Array:
    """rel_power: (n,) f32 relative magnitudes -> (n_bins,) counts.

    n is padded to a (rows x 128) layout; padding uses -inf (never counted).
    ``interpret=None`` autodetects: compiled on TPU, interpreter elsewhere.
    """
    assert n_bins <= _OUT_COLS
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = rel_power.shape[0]
    cols = 128
    block_rows = min(block_rows, -(-n // cols))
    # pad the row count up to a block multiple (padding is -inf, never
    # counted) so every grid step runs a full requested block — strictly
    # better than shrinking block_rows to a divisor of rows (the seed's
    # decrement search, or math.gcd, which can degrade to 1-row blocks)
    rows = -(-n // (cols * block_rows)) * block_rows
    pad = rows * cols - n
    r = jnp.pad(rel_power.astype(jnp.float32), (0, pad),
                constant_values=-jnp.inf).reshape(rows, cols)
    grid = (rows // block_rows,)
    kernel = functools.partial(_hist_kernel, n_bins=n_bins, lo=lo, hi=hi)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, _OUT_COLS), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, _OUT_COLS), jnp.float32),
        interpret=interpret,
    )(r)
    return out[0, :n_bins]


def _batch_hist_kernel(r_ref, o_ref, *, n_bins: int, lo: float,
                       bin_width: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    r = r_ref[...].astype(jnp.float32)            # (block_jobs, 128)
    idx = jnp.floor((r - lo) / bin_width).astype(jnp.int32)
    idx = jnp.where(r >= lo, jnp.minimum(idx, n_bins - 1), -1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 1, _OUT_COLS), 2)
    # one-hot over the lane-held bin ids, reduced across this sample tile
    counts = jnp.sum((idx[:, :, None] == bins).astype(jnp.float32), axis=1)
    o_ref[...] += counts                           # (block_jobs, _OUT_COLS)


def spike_hist_batch_pallas(rel_power: jax.Array, n_bins: int,
                            lo: float = 0.5, hi: float = 2.0,
                            bin_width: float | None = None,
                            block_jobs: int = 8,
                            interpret: bool | None = None) -> jax.Array:
    """Batched fleet variant: (jobs, samples) f32 -> (jobs, n_bins) counts.

    One kernel launch bins every live job's newly committed samples at once —
    the TPU half of ``pipeline.batch.BatchProfileEngine``'s histogram
    scatter.  Rows are jobs; sample padding uses -inf (never counted), so
    ragged per-job sample counts are handled by masking before the call.
    ``bin_width`` defaults to ``(hi - lo) / n_bins`` but callers that track
    histograms keyed by an exact bin size should pass it explicitly —
    ``(hi - lo) / n_bins`` re-derived in float can differ in the last ulp
    from the originating bin size (e.g. 0.15).  ``interpret=None``
    autodetects like ``spike_hist_pallas``.
    """
    assert n_bins <= _OUT_COLS
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if bin_width is None:
        bin_width = (hi - lo) / n_bins
    jobs, n = rel_power.shape
    cols = 128
    jb = -(-jobs // block_jobs) * block_jobs
    sb = -(-n // cols) * cols
    r = jnp.pad(rel_power.astype(jnp.float32),
                ((0, jb - jobs), (0, sb - n)), constant_values=-jnp.inf)
    grid = (jb // block_jobs, sb // cols)
    kernel = functools.partial(_batch_hist_kernel, n_bins=n_bins, lo=lo,
                               bin_width=bin_width)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_jobs, cols), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_jobs, _OUT_COLS), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((jb, _OUT_COLS), jnp.float32),
        interpret=interpret,
    )(r)
    return out[:jobs, :n_bins]
