"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels are validated in interpret mode against ref.py and lower natively
on TPU backends).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ema_scan import ema_scan_pallas
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.spike_hist import spike_hist_pallas
from repro.kernels.ssm_scan import ssm_scan_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 512,
                    block_k: int = 512,
                    interpret: bool | None = None) -> jax.Array:
    """q: (b, sq, H, dh); k/v: (b, skv, KV, dh) -> (b, sq, H, dh)."""
    interpret = _default_interpret() if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    ot = flash_attention_bhsd(qt, kt, vt, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return ot.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_s", "block_d", "interpret"))
def ssm_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, D: jax.Array, *, block_s: int = 64,
             block_d: int = 256, interpret: bool | None = None) -> jax.Array:
    interpret = _default_interpret() if interpret is None else interpret
    return ssm_scan_pallas(x, dt, A, B, C, D, block_s=block_s,
                           block_d=block_d, interpret=interpret)


@partial(jax.jit, static_argnames=("n_bins", "lo", "hi", "interpret"))
def spike_hist(power: jax.Array, tdp: float | jax.Array, n_bins: int = 15,
               lo: float = 0.5, hi: float = 2.0,
               interpret: bool | None = None) -> jax.Array:
    """Power samples (W) -> normalized spike vector (n_bins,)."""
    interpret = _default_interpret() if interpret is None else interpret
    rel = power.astype(jnp.float32) / tdp
    counts = spike_hist_pallas(rel, n_bins, lo=lo, hi=hi, interpret=interpret)
    total = jnp.sum(counts)
    return jnp.where(total > 0, counts / total, counts)


@partial(jax.jit, static_argnames=("alpha", "interpret"))
def ema_scan(power: jax.Array, alpha: float = 0.5,
             interpret: bool | None = None) -> jax.Array:
    """Power samples (W) -> EMA-filtered samples (paper's alpha=0.5 filter)."""
    interpret = _default_interpret() if interpret is None else interpret
    return ema_scan_pallas(power.astype(jnp.float32), alpha=alpha,
                           interpret=interpret)


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5,
            interpret: bool | None = None) -> jax.Array:
    interpret = _default_interpret() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    return rmsnorm_pallas(x2, scale, eps=eps, interpret=interpret).reshape(shape)
