"""Selective-SSM (Mamba-1) scan as a Pallas TPU kernel.

TPU adaptation: ``d_inner`` is the 128-lane dimension (blocked at Bd), the
per-(channel, state) hidden h lives in VMEM scratch (ds x Bd fp32) and is
carried across the sequential seq-block grid dimension; within a block the
recurrence runs as a ``fori_loop`` over time steps — each step is pure VPU
work (exp, multiply-add) on (ds, Bd) tiles, with the state never leaving
VMEM (the whole point vs materializing (s, di, ds) in HBM).

Layouts: x, dt: (b, s, di); A: (ds, di) [transposed for lane alignment];
B, C: (b, s, ds); y: (b, s, di).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref, *,
                block_s: int):
    # grid = (b, di-blocks, seq-blocks): seq is the innermost (sequential)
    # dimension so the VMEM state carry is private to each (b, d-block)
    isq = pl.program_id(2)

    @pl.when(isq == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)            # (ds, Bd)
    D = d_ref[...].astype(jnp.float32)            # (1, Bd)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)[None, :]       # (1, Bd)
        x_t = x_ref[0, t].astype(jnp.float32)[None, :]
        b_t = b_ref[0, t].astype(jnp.float32)[:, None]         # (ds, 1)
        c_t = c_ref[0, t].astype(jnp.float32)[:, None]
        a_t = jnp.exp(dt_t * A)                                # (ds, Bd)
        h = a_t * h + (dt_t * x_t) * b_t
        y_t = jnp.sum(c_t * h, axis=0, keepdims=True) + D * x_t
        y_ref[0, t] = y_t[0].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_s, step, h_ref[...])
    h_ref[...] = h


def ssm_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                    C: jax.Array, D: jax.Array, *, block_s: int = 64,
                    block_d: int = 256, interpret: bool = True) -> jax.Array:
    """x, dt: (b, s, di); A: (di, ds); B, C: (b, s, ds); D: (di,) -> y."""
    b, s, di = x.shape
    ds = A.shape[1]
    block_s = min(block_s, s)
    block_d = min(block_d, di)
    assert s % block_s == 0 and di % block_d == 0
    grid = (b, di // block_d, s // block_s)
    a_t = A.T                                 # (ds, di)
    d_2d = D[None, :]                         # (1, di)
    kernel = functools.partial(_ssm_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_d), lambda ib, idd, isq: (ib, isq, idd)),
            pl.BlockSpec((1, block_s, block_d), lambda ib, idd, isq: (ib, isq, idd)),
            pl.BlockSpec((ds, block_d), lambda ib, idd, isq: (0, idd)),
            pl.BlockSpec((1, block_s, ds), lambda ib, idd, isq: (ib, isq, 0)),
            pl.BlockSpec((1, block_s, ds), lambda ib, idd, isq: (ib, isq, 0)),
            pl.BlockSpec((1, block_d), lambda ib, idd, isq: (0, idd)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_d),
                               lambda ib, idd, isq: (ib, isq, idd)),
        out_shape=jax.ShapeDtypeStruct((b, s, di), x.dtype),
        scratch_shapes=[pltpu.VMEM((ds, block_d), jnp.float32)],
        interpret=interpret,
    )(x, dt, a_t, B, C, d_2d)
