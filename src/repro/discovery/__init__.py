"""Online class discovery: the reference library learns from production
traffic.

Minos' premise is that a finite class library absorbs new workloads cheaply
— but workload populations drift, and unseen application families are
exactly where the low-margin decisions pile up.  This package closes the
loop:

  * :class:`QuarantinePool` accumulates the finalized low-margin profiles
    the ``OnlineCapController`` confidence gate surfaces;
  * :class:`DiscoveryController` periodically re-clusters the pool
    (``core/clustering`` linkage over cosine spike distances) to mint
    candidate classes;
  * :class:`ShadowEvaluator` scores every candidate against full-profile
    ground truth *before* it can affect a live decision;
  * the promotion path publishes a new versioned ``ReferenceLibrary``
    (spike cache grown incrementally, N-1 rollback retained) which the
    session and fleet controller adopt atomically between ticks — zero
    classifier queries on the swap.

Discovery is inert-by-default: a session without a ``discovery`` config key
takes byte-identical code paths to a build without this package.
"""
from repro.discovery.controller import (DISCOVERY_KEYS, DiscoveryController,
                                        Promotion, stream_profiler)
from repro.discovery.pool import PoolEntry, QuarantinePool
from repro.discovery.records import profile_from_record, profile_record
from repro.discovery.shadow import (ShadowEvaluator, ShadowReport,
                                    truth_selection)

__all__ = [
    "DISCOVERY_KEYS", "DiscoveryController", "Promotion", "PoolEntry",
    "QuarantinePool", "ShadowEvaluator", "ShadowReport",
    "profile_from_record", "profile_record", "stream_profiler",
    "truth_selection",
]
