"""Quarantine pool: finalized low-margin profiles awaiting re-clustering.

Entries enter through the ``OnlineCapController`` confidence-gate tap and
leave either by promotion (their cluster minted a new reference class) or by
FIFO eviction once the pool exceeds capacity.  Both paths are deterministic
functions of the entry records, so journal replay reproduces the pool
byte-for-byte without touching a classifier.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.classify import WorkloadProfile
from repro.discovery.records import profile_from_record, profile_record


@dataclass
class PoolEntry:
    """One quarantined job profile plus the decision context that gated it."""

    id: int
    name: str
    confidence: float
    device_id: str
    fraction: float
    profile: WorkloadProfile

    def record(self) -> dict:
        """JSON-safe dict embedding the full profile codec."""
        return {
            "id": self.id,
            "name": self.name,
            "confidence": float(self.confidence),
            "device_id": self.device_id,
            "fraction": float(self.fraction),
            "profile": profile_record(self.profile),
        }

    @classmethod
    def from_record(cls, rec: dict) -> "PoolEntry":
        return cls(
            id=int(rec["id"]),
            name=rec["name"],
            confidence=float(rec["confidence"]),
            device_id=rec.get("device_id", ""),
            fraction=float(rec.get("fraction", 0.0)),
            profile=profile_from_record(rec["profile"]),
        )


class QuarantinePool:
    """Bounded FIFO pool of low-margin profiles.

    ``next_id`` is monotone across evictions and removals so entry ids in
    journal records stay unique for the life of a session; ``add_record``
    honours the id already stamped into the record, which lets write-ahead
    journaling record an entry before the live pool admits it and replay
    admit the identical entry later.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.entries: list[PoolEntry] = []
        self._next_id = 1

    @property
    def next_id(self) -> int:
        """Id the next admitted record should carry."""
        return self._next_id

    def add_record(self, rec: dict) -> PoolEntry:
        """Admit an entry record (live tap and journal replay both land here)."""
        entry = PoolEntry.from_record(rec)
        self._next_id = max(self._next_id, entry.id + 1)
        self.entries.append(entry)
        while len(self.entries) > self.capacity:
            self.entries.pop(0)
        return entry

    def remove(self, ids) -> int:
        """Drop the entries with the given ids; returns how many were dropped."""
        drop = set(int(i) for i in ids)
        before = len(self.entries)
        self.entries = [e for e in self.entries if e.id not in drop]
        return before - len(self.entries)

    def restore(self, records, next_id: int) -> None:
        """Rebuild the pool from snapshot state."""
        self.entries = [PoolEntry.from_record(rec) for rec in records]
        self._next_id = max(
            int(next_id), *(e.id + 1 for e in self.entries), 1
        )

    def clear(self) -> None:
        self.entries = []

    def __len__(self) -> int:
        return len(self.entries)

    def __iter__(self):
        return iter(self.entries)
