"""JSON codec for full workload profiles.

Quarantine and promotion records must carry complete
:class:`~repro.core.classify.WorkloadProfile` objects through the session
journal — numpy traces and the per-frequency scaling table included — so a
crashed session resumes its discovery state with zero classifier calls.
The generic dataclass codec in ``repro.api.results`` deliberately excludes
numpy arrays, so profiles get their own record shape here, mirroring the
on-disk format of ``ReferenceLibrary.save`` (``repr(float)`` keys round-trip
float64 frequencies exactly, as do JSON float lists for traces).
"""
from __future__ import annotations

import numpy as np

from repro.core.classify import FreqPoint, WorkloadProfile


def profile_record(profile: WorkloadProfile) -> dict:
    """Encode a full profile as a JSON-safe dict."""
    return {
        "name": profile.name,
        "tdp": float(profile.tdp),
        "power_trace": [float(x) for x in np.asarray(profile.power_trace)],
        "sm_util": float(profile.sm_util),
        "dram_util": float(profile.dram_util),
        "exec_time": float(profile.exec_time),
        "domain": profile.domain,
        "scaling": {
            repr(float(f)): {
                "freq": float(pt.freq),
                "p90": float(pt.p90),
                "p95": float(pt.p95),
                "p99": float(pt.p99),
                "mean_power": float(pt.mean_power),
                "exec_time": float(pt.exec_time),
            }
            for f, pt in profile.scaling.items()
        },
    }


def profile_from_record(rec: dict) -> WorkloadProfile:
    """Rebuild a :class:`WorkloadProfile` from :func:`profile_record`."""
    scaling = {
        float(key): FreqPoint(
            freq=float(pt["freq"]),
            p90=float(pt["p90"]),
            p95=float(pt["p95"]),
            p99=float(pt["p99"]),
            mean_power=float(pt["mean_power"]),
            exec_time=float(pt["exec_time"]),
        )
        for key, pt in rec.get("scaling", {}).items()
    }
    return WorkloadProfile(
        name=rec["name"],
        tdp=float(rec["tdp"]),
        power_trace=np.asarray(rec["power_trace"], dtype=np.float64),
        sm_util=float(rec["sm_util"]),
        dram_util=float(rec["dram_util"]),
        exec_time=float(rec["exec_time"]),
        scaling=scaling,
        domain=rec.get("domain", ""),
    )
