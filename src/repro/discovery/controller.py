"""Discovery controller: quarantine -> re-cluster -> shadow-evaluate ->
promote.

``DiscoveryController`` owns the full online-discovery loop around a
versioned ``ReferenceLibrary``:

  * low-margin ``CapDecision``s feed the :class:`QuarantinePool` through the
    fleet's gate tap (``wants``/``entry_record``/``admit_record`` — split so
    the session can journal each entry write-ahead);
  * ``propose`` re-clusters the pool through ``core/clustering`` (average
    linkage over cosine spike distances), picks each viable cluster's medoid,
    profiles it to a full scaling sweep via the injected ``profiler``, and
    shadow-evaluates the candidate before it may promote;
  * ``apply``/``adopt_promoted`` publish the next library version — a fresh
    ``ReferenceLibrary`` built by row-append on the cached spike matrices
    (no re-histogramming of existing members), with the previous version
    retained for N-1 ``rollback``;
  * ``state_record``/``restore`` round-trip the whole thing through session
    snapshots, and replay re-adopts promotions from their journal records
    with zero classifier calls (``adopt_promoted`` never classifies).

The controller itself never touches a live classifier: proposing uses
private shadow objects, and adopting a promoted library is the session /
fleet controller's job, done atomically between ticks.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.algorithm1 import DEFAULT_BIN_CANDIDATES
from repro.core.classify import WorkloadProfile
from repro.core.clustering import cosine_distance_matrix, cut, linkage
from repro.discovery.pool import PoolEntry, QuarantinePool
from repro.discovery.records import profile_from_record, profile_record
from repro.discovery.shadow import ShadowEvaluator
from repro.pipeline.library import ReferenceLibrary

import numpy as np

# the serializable knobs accepted by the session's {"discovery": {...}}
# config key (the profiler is injected programmatically — it is code)
DISCOVERY_KEYS = ("quarantine_below", "min_cluster", "cluster_distance",
                  "promote_agreement", "recluster_every", "capacity",
                  "min_confidence_gain", "bin_size")


@dataclass
class Promotion:
    """One accepted library-version bump, ready to journal and apply."""

    version: int                         # the version being promoted to
    profiles: list                       # new WorkloadProfile references
    profile_records: list                # their JSON records (journal payload)
    consumed: list                       # pool entry ids folded into classes
    reports: list = field(default_factory=list)   # ShadowReport per candidate


class DiscoveryController:
    """Online class discovery around a versioned reference library."""

    def __init__(self, library: ReferenceLibrary, objective="powercentric",
                 profiler=None, quarantine_below: float = 0.3,
                 min_cluster: int = 3, cluster_distance: float = 0.25,
                 promote_agreement: float = 0.9, recluster_every: int = 8,
                 capacity: int = 256, min_confidence_gain: float | None = 0.0,
                 bin_size: float = 0.1,
                 bin_candidates=DEFAULT_BIN_CANDIDATES):
        if not isinstance(library, ReferenceLibrary):
            raise ValueError(
                "discovery requires a ReferenceLibrary (it versions the "
                f"membership), got {type(library).__name__}")
        self.base_library = library
        self.library = library           # current (promoted) version
        self._previous: ReferenceLibrary | None = None   # N-1 rollback
        self.version = 1
        self.batches: list[list] = []    # profile-record lists per promotion
        self.objective = objective
        self.profiler = profiler         # full-profile oracle; injected code
        self.quarantine_below = float(quarantine_below)
        self.min_cluster = int(min_cluster)
        self.cluster_distance = float(cluster_distance)
        self.promote_agreement = float(promote_agreement)
        self.recluster_every = int(recluster_every)
        self.min_confidence_gain = (None if min_confidence_gain is None
                                    else float(min_confidence_gain))
        self.bin_size = float(bin_size)
        self.bin_candidates = tuple(bin_candidates)
        self.pool = QuarantinePool(capacity=capacity)
        self.quarantined = 0             # admissions over the session's life
        self._since_recluster = 0

    # -- quarantine intake ----------------------------------------------
    def wants(self, decision) -> bool:
        """Does this finalized decision belong in quarantine?"""
        return decision.confidence < self.quarantine_below

    def entry_record(self, profile: WorkloadProfile, decision) -> dict:
        """Build the entry record for a wanted decision *without* admitting
        it — the caller journals the record first (write-ahead), then feeds
        the same record to ``admit_record``."""
        return PoolEntry(
            id=self.pool.next_id, name=profile.name,
            confidence=float(decision.confidence),
            device_id=decision.device_id, fraction=float(decision.fraction),
            profile=profile).record()

    def admit_record(self, rec: dict) -> PoolEntry:
        """Admit a journaled entry record (live path and replay path)."""
        entry = self.pool.add_record(rec)
        self.quarantined += 1
        self._since_recluster += 1
        return entry

    # -- re-clustering + shadow evaluation -------------------------------
    def due(self) -> bool:
        return (self._since_recluster >= self.recluster_every
                and len(self.pool) >= self.min_cluster
                and self.profiler is not None)

    def propose(self, force: bool = False) -> Promotion | None:
        """Re-cluster the pool and shadow-evaluate the candidates; returns a
        ``Promotion`` when at least one candidate passed the gate, else
        ``None``.  Pure proposal — nothing is applied or journaled here."""
        if not force and not self.due():
            return None
        if len(self.pool) < self.min_cluster:
            return None
        if self.profiler is None:
            if force:
                raise ValueError(
                    "discovery has no profiler: set session.discovery"
                    ".profiler to a full-profile callable before forcing "
                    "a proposal")
            return None
        self._since_recluster = 0
        entries = list(self.pool)
        clusters = self._clusters(entries)
        if not clusters:
            return None
        evaluator = ShadowEvaluator(
            self.library, objective=self.objective,
            bin_candidates=self.bin_candidates,
            promote_agreement=self.promote_agreement,
            min_confidence_gain=self.min_confidence_gain,
            bin_size=self.bin_size)
        new_version = self.version + 1
        profiles, records, consumed, reports = [], [], [], []
        taken: set[str] = set()
        for members in clusters:
            rep = self._medoid(members)
            full = self.profiler(rep.profile)
            candidate = self._as_candidate(full, new_version, taken)
            report = evaluator.evaluate(
                candidate, [e.profile for e in members],
                [e.confidence for e in members])
            reports.append(report)
            if not report.promote:
                continue
            taken.add(candidate.name)
            profiles.append(candidate)
            records.append(profile_record(candidate))
            consumed.extend(e.id for e in members)
        if not profiles:
            return None
        return Promotion(version=new_version, profiles=profiles,
                         profile_records=records, consumed=consumed,
                         reports=reports)

    def _clusters(self, entries) -> list[list[PoolEntry]]:
        """Group pool entries by average-linkage cosine clustering of their
        spike vectors; clusters below ``min_cluster`` members are left in
        the pool for later rounds.  Cluster order follows leaf first
        appearance (deterministic in entry order)."""
        if len(entries) < 2:
            return []
        V = np.stack([e.profile.spike_vec(self.bin_size) for e in entries])
        labels = cut(linkage(cosine_distance_matrix(V), method="average"),
                     self.cluster_distance)
        by_label: dict[int, list[PoolEntry]] = {}
        for entry, lab in zip(entries, labels):
            by_label.setdefault(int(lab), []).append(entry)
        return [members for members in by_label.values()
                if len(members) >= self.min_cluster]

    def _medoid(self, members) -> PoolEntry:
        """Cluster representative: the member minimizing the summed cosine
        distance to the rest (first wins on ties)."""
        V = np.stack([e.profile.spike_vec(self.bin_size) for e in members])
        sums = cosine_distance_matrix(V).sum(axis=1)
        return members[int(np.argmin(sums))]

    def _as_candidate(self, full: WorkloadProfile, version: int,
                      taken: set[str]) -> WorkloadProfile:
        """Rebrand the profiled representative with a unique, versioned
        reference name (library names are unique keys)."""
        base = f"discovered-v{version}:{full.name}"
        name, k = base, 2
        while name in self.library or name in taken:
            name, k = f"{base}#{k}", k + 1
        return WorkloadProfile(
            name=name, tdp=full.tdp, power_trace=full.power_trace,
            sm_util=full.sm_util, dram_util=full.dram_util,
            exec_time=full.exec_time, scaling=dict(full.scaling),
            domain=full.domain or "discovered")

    # -- promotion / rollback --------------------------------------------
    def apply(self, promo: Promotion) -> ReferenceLibrary:
        """Publish ``promo`` as the next library version (live path; the
        caller journals the promotion record first)."""
        return self._apply(promo.version, promo.profiles,
                           promo.profile_records, promo.consumed)

    def adopt_promoted(self, version: int, profile_records,
                       consumed) -> ReferenceLibrary:
        """Re-adopt a journaled promotion verbatim (replay path) — rebuilds
        the promoted profiles from their records; zero classifier calls."""
        profiles = [profile_from_record(rec) for rec in profile_records]
        return self._apply(int(version), profiles, list(profile_records),
                           list(consumed))

    def _apply(self, version, profiles, records, consumed):
        if version != self.version + 1:
            raise ValueError(
                f"promotion targets version {version}, current is "
                f"{self.version} (promotions apply in order)")
        new_lib = self.library.subset(lambda p: True)
        for p in profiles:
            new_lib.add(p)               # row-append on cached spike matrices
        self.pool.remove(consumed)
        # a promotion closes the current re-cluster window on BOTH paths
        # (live apply and journal replay) — propose() already zeroed it on
        # the live path, so this keeps replayed state bit-identical
        self._since_recluster = 0
        self._previous = self.library
        self.library = new_lib
        self.version = version
        self.batches.append(list(records))
        return new_lib

    def rollback(self) -> ReferenceLibrary:
        """Revert to the N-1 library version (one step only — older versions
        are gone once a newer promotion lands)."""
        if self._previous is None:
            raise ValueError("no previous library version to roll back to")
        self.library = self._previous
        self._previous = None
        self.batches.pop()
        self.version -= 1
        return self.library

    # -- persistence ------------------------------------------------------
    def state_record(self) -> dict:
        """Snapshot state: pool + promoted batches (JSON-safe)."""
        return {
            "version": self.version,
            "next_id": self.pool.next_id,
            "quarantined": self.quarantined,
            "since_recluster": self._since_recluster,
            "pool": [e.record() for e in self.pool],
            "batches": [list(batch) for batch in self.batches],
        }

    def restore(self, state: dict) -> None:
        """Rebuild from ``state_record`` output: replays every promoted
        batch on top of the base library (row-append only — no classifier,
        no re-histogramming of existing members)."""
        self.library = self.base_library
        self._previous = None
        self.version = 1
        self.batches = []
        for batch in state.get("batches", ()):
            self.adopt_promoted(self.version + 1, batch, ())
        self.pool.restore(state.get("pool", ()),
                          int(state.get("next_id", 1)))
        self.quarantined = int(state.get("quarantined", 0))
        self._since_recluster = int(state.get("since_recluster", 0))

    def config_record(self) -> dict:
        """The serializable knobs, for the store's open record."""
        return {
            "quarantine_below": self.quarantine_below,
            "min_cluster": self.min_cluster,
            "cluster_distance": self.cluster_distance,
            "promote_agreement": self.promote_agreement,
            "recluster_every": self.recluster_every,
            "capacity": self.pool.capacity,
            "min_confidence_gain": self.min_confidence_gain,
            "bin_size": self.bin_size,
        }

    def report_record(self) -> dict:
        """Session-report summary of the discovery state."""
        discovered = [name for batch in self.batches
                      for name in (rec["name"] for rec in batch)]
        return {
            "version": self.version,
            "pool": len(self.pool),
            "quarantined": self.quarantined,
            "promotions": len(self.batches),
            "classes": discovered,
        }


def stream_profiler(streams, model=None, freqs=None, tdp=None, seed: int = 0,
                    target_duration: float = 3.0, chunk_samples: int = 256):
    """Full-profile oracle over a set of known ``KernelStream``s: returns a
    callable mapping a quarantined partial profile to the full frequency
    sweep of the stream it came from (matched by name — exact, else the
    longest stream name the profile name starts with).

    This stands in for the production act of scheduling a one-off full
    profiling run for a newly discovered family; benchmarks and tests hand
    it the novel zoo streams."""
    from repro.analysis.hardware import FREQ_SWEEP
    from repro.pipeline.builder import stream_profile_workload
    from repro.telemetry.power_model import TPUPowerModel

    model = model or TPUPowerModel()
    freqs = FREQ_SWEEP if freqs is None else freqs
    tdp = model.spec.tdp_w if tdp is None else float(tdp)
    by_name = {s.name: (i, s) for i, s in enumerate(streams)}
    memo: dict[str, WorkloadProfile] = {}

    def profiler(profile: WorkloadProfile) -> WorkloadProfile:
        key = profile.name
        if key not in by_name:
            prefixes = [n for n in by_name
                        if key.startswith(n) or key.split("@")[0] == n]
            if not prefixes:
                raise KeyError(
                    f"no stream matches quarantined profile {key!r}")
            key = max(prefixes, key=len)
        if key not in memo:
            i, stream = by_name[key]
            memo[key] = stream_profile_workload(
                stream, model, freqs, tdp, seed=seed + i,
                target_duration=target_duration,
                chunk_samples=chunk_samples)
        return memo[key]

    return profiler
