"""Shadow evaluation: score a candidate class before it touches production.

A candidate minted by re-clustering is only as good as the decisions it
would change.  The evaluator builds a *shadow* library (current library plus
the candidate), classifies every quarantined cluster member against it, and
compares each member's shadow cap with the candidate's full-profile ground
truth (``cap_power_centric``/``cap_perf_centric`` over its measured scaling
table — the same truth the benchmarks use).  Shadow classifiers are private
objects; no live classifier is queried, so evaluation can never perturb a
running session's decisions or its zero-call accounting.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.algorithm1 import (DEFAULT_BIN_CANDIDATES, FreqSelection,
                                   cap_perf_centric, cap_power_centric,
                                   resolve_objective)
from repro.core.classify import WorkloadProfile
from repro.pipeline.library import ReferenceLibrary
from repro.pipeline.online import classify_with_margin


@dataclass
class ShadowReport:
    """Outcome of evaluating one candidate class against its members."""

    candidate: str
    n_members: int
    agreement: float             # fraction of members whose shadow cap hits truth
    mean_confidence_before: float
    mean_confidence_after: float
    truth_cap: float
    promote: bool

    def record(self) -> dict:
        return {
            "candidate": self.candidate,
            "n_members": self.n_members,
            "agreement": float(self.agreement),
            "mean_confidence_before": float(self.mean_confidence_before),
            "mean_confidence_after": float(self.mean_confidence_after),
            "truth_cap": float(self.truth_cap),
            "promote": self.promote,
        }


def truth_selection(profile: WorkloadProfile,
                    bin_size: float = 0.1) -> FreqSelection:
    """Ground-truth selection for a fully profiled workload: it is its own
    neighbor, so both caps come straight from its measured scaling table."""
    return FreqSelection(
        target=profile.name, bin_size=float(bin_size),
        power_neighbor=profile.name, power_distance=0.0,
        util_neighbor=profile.name, util_distance=0.0,
        f_pwr=cap_power_centric(profile),
        f_perf=cap_perf_centric(profile))


class ShadowEvaluator:
    """Gatekeeper between re-clustering and promotion."""

    def __init__(self, library: ReferenceLibrary, objective="powercentric",
                 bin_candidates=DEFAULT_BIN_CANDIDATES,
                 promote_agreement: float = 0.9,
                 min_confidence_gain: float | None = 0.0,
                 bin_size: float = 0.1):
        self.library = library
        self.objective_policy = resolve_objective(objective)
        self.bin_candidates = tuple(bin_candidates)
        self.promote_agreement = float(promote_agreement)
        self.min_confidence_gain = (None if min_confidence_gain is None
                                    else float(min_confidence_gain))
        self.bin_size = float(bin_size)

    def evaluate(self, candidate: WorkloadProfile, members,
                 member_confidences) -> ShadowReport:
        """Score ``candidate`` (a fully profiled class representative)
        against its quarantined ``members`` (partial profiles) and the
        margin confidences they were quarantined with."""
        shadow = self.library.subset(lambda p: True)
        shadow.add(candidate)
        shadow_clf = shadow.classifier(bin_size=self.bin_size)
        truth_cap = self.objective_policy.cap(
            truth_selection(candidate, self.bin_size))
        hits = 0
        conf_after = []
        for member in members:
            sel, conf = classify_with_margin(member, shadow_clf,
                                             self.bin_candidates)
            conf_after.append(conf)
            if self.objective_policy.cap(sel) == truth_cap:
                hits += 1
        n = len(conf_after)
        agreement = hits / n if n else 0.0
        before = (sum(float(c) for c in member_confidences)
                  / len(member_confidences)) if member_confidences else 0.0
        after = sum(conf_after) / n if n else 0.0
        promote = n > 0 and agreement >= self.promote_agreement and (
            self.min_confidence_gain is None
            or after - before >= self.min_confidence_gain)
        return ShadowReport(
            candidate=candidate.name, n_members=n, agreement=agreement,
            mean_confidence_before=before, mean_confidence_after=after,
            truth_cap=truth_cap, promote=promote)
