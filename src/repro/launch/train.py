"""Training launcher with Minos frequency-cap selection as a first-class step.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \\
        --steps 20 --minos-cap powercentric

With ``--minos-cap``, the launcher (1) loads (or builds once) the versioned
Minos ``ReferenceLibrary`` — warm-starting the classifier from its persisted
spike-matrix cache, (2) opens a ``repro.api.MinosSession`` and submits this
job's one low-cost profiling run, capping through the DVFS actuator as soon
as the partial-profile classification is confident (often well before the
profile run would have finished), and only then starts training.
"""
from __future__ import annotations

import argparse

import jax

from repro.api import (MinosSession, ReferenceLibrary,
                       build_reference_library)
from repro.configs import ARCHS, SHAPES, RunConfig
from repro.configs.base import ShapeConfig
from repro.models.common import SMOKE_TOPO, Topo
from repro.sched import SimActuator
from repro.telemetry import TPUPowerModel
from repro.telemetry.kernel_stream import build_stream
from repro.train import Trainer


def minos_select_cap(arch: str, shape, objective: str, store_dir: str,
                     actuator: SimActuator | None = None) -> float:
    model = TPUPowerModel()

    def build():
        print("[minos] building reference library (one-time)...")
        return build_reference_library(model, target_duration=2.0).profiles

    lib = ReferenceLibrary.load_or_build(store_dir, build)
    # hold this arch out of its own reference set
    lib = lib.subset(lambda r: not r.name.startswith(arch))
    session = MinosSession(lib, objective=objective,
                           actuator=actuator if actuator is not None
                           else "none")
    job = session.submit(build_stream(ARCHS[arch], shape))
    decision = job.run()               # stops profiling at the early cap
    sel = decision.selection
    how = "early, from partial profile" if decision.early else "full profile"
    print(f"[minos] target={decision.target} bin={sel.bin_size} "
          f"pwr_nn={sel.power_neighbor} (d={sel.power_distance:.3f}) "
          f"perf_nn={sel.util_neighbor} (d={sel.util_distance:.2f}) "
          f"-> cap={decision.cap:.2f} ({objective}; {how} at "
          f"{decision.fraction:.0%} of the trace, "
          f"confidence {decision.confidence:.2f})")
    return decision.cap


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--minos-cap", choices=["powercentric", "perfcentric"],
                    default=None)
    ap.add_argument("--minos-store", default="/tmp/minos_reference_store")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    shape = SHAPES[args.shape]
    actuator = SimActuator()
    if args.minos_cap:
        minos_select_cap(args.arch, shape, args.minos_cap,
                         args.minos_store, actuator=actuator)

    if args.smoke:
        cfg = cfg.reduced()
        shape = ShapeConfig("smoke", args.seq_len, args.batch, "train")
        topo = SMOKE_TOPO
    else:
        from repro.launch.mesh import mesh_config
        topo = Topo(mesh_config())

    run_cfg = RunConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                        checkpoint_every=max(args.steps // 2, 1),
                        checkpoint_dir=args.ckpt_dir)
    trainer = Trainer(cfg, shape, run_cfg, topo)
    res = trainer.run(num_steps=args.steps)
    if res.steps_run:
        print(f"ran {res.steps_run} steps; loss {res.losses[0]:.4f} -> "
              f"{res.losses[-1]:.4f}; cap={actuator.get_cap():.2f}")
    else:
        # a resumed checkpoint at/past --steps leaves nothing to run
        print(f"ran 0 steps (checkpoint already at step {res.final_step}); "
              f"cap={actuator.get_cap():.2f}")


if __name__ == "__main__":
    main()
