"""Training launcher with Minos frequency-cap selection as a first-class step.

    PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \\
        --steps 20 --minos-cap powercentric

With ``--minos-cap``, the launcher (1) builds/loads the Minos reference
library, (2) profiles this job once at the uncapped clock (the paper's
low-cost profile — here via the telemetry simulator attached to this arch's
kernel stream), (3) runs Algorithm 1 and applies the selected cap through the
DVFS actuator before training starts.
"""
from __future__ import annotations

import argparse
import os

import jax

from repro.configs import ARCHS, SHAPES, RunConfig
from repro.configs.base import ShapeConfig
from repro.core import MinosClassifier, select_optimal_freq
from repro.core.reference_store import load_profiles, save_profiles
from repro.models.common import SMOKE_TOPO, Topo
from repro.sched import SimActuator
from repro.telemetry import TPUPowerModel, build_reference_set, profile_once
from repro.telemetry.kernel_stream import build_stream
from repro.train import Trainer


def minos_select_cap(arch: str, shape, objective: str, store_dir: str) -> float:
    model = TPUPowerModel()
    if os.path.isdir(store_dir) and os.path.exists(
            os.path.join(store_dir, "profiles.json")):
        refs = load_profiles(store_dir)
    else:
        print("[minos] building reference library (one-time)...")
        refs = build_reference_set(model, target_duration=2.0)
        save_profiles(refs, store_dir)
    refs = [r for r in refs if not r.name.startswith(arch)]
    clf = MinosClassifier(refs)
    stream = build_stream(ARCHS[arch], shape)
    target = profile_once(stream, model, model.spec.tdp_w)
    sel = select_optimal_freq(target, clf)
    cap = sel.cap(objective)
    print(f"[minos] target={target.name} bin={sel.bin_size} "
          f"pwr_nn={sel.power_neighbor} (d={sel.power_distance:.3f}) "
          f"perf_nn={sel.util_neighbor} (d={sel.util_distance:.2f}) "
          f"-> cap={cap:.2f} ({objective})")
    return cap


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--minos-cap", choices=["powercentric", "perfcentric"],
                    default=None)
    ap.add_argument("--minos-store", default="/tmp/minos_reference_store")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    shape = SHAPES[args.shape]
    actuator = SimActuator()
    if args.minos_cap:
        cap = minos_select_cap(args.arch, shape, args.minos_cap,
                               args.minos_store)
        actuator.set_cap(cap)

    if args.smoke:
        cfg = cfg.reduced()
        shape = ShapeConfig("smoke", args.seq_len, args.batch, "train")
        topo = SMOKE_TOPO
    else:
        from repro.launch.mesh import mesh_config
        topo = Topo(mesh_config())

    run_cfg = RunConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                        checkpoint_every=max(args.steps // 2, 1),
                        checkpoint_dir=args.ckpt_dir)
    trainer = Trainer(cfg, shape, run_cfg, topo)
    res = trainer.run(num_steps=args.steps)
    print(f"ran {res.steps_run} steps; loss {res.losses[0]:.4f} -> "
          f"{res.losses[-1]:.4f}; cap={actuator.get_cap():.2f}")


if __name__ == "__main__":
    main()
