import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the per-device footprint fits
  * compiled.cost_analysis()    — XLA's own (scan-body-once) numbers
  * the while-aware parsed cost — FLOPs / HBM bytes / collective bytes
  * the 3-term roofline report  (analysis/roofline.py)

Usage:
  python -m repro.launch.dryrun --list
  python -m repro.launch.dryrun --one ARCH SHAPE MESH   # single cell
  python -m repro.launch.dryrun [--mesh single|multi|both] [--arch A] ...
The orchestrating mode runs each cell in a subprocess (isolation against
compiler memory growth) and writes results/dryrun/<mesh>/<arch>__<shape>.json
incrementally, skipping cells that already have results.
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__)))))
RESULTS = os.path.join(REPO, "results", "dryrun")


def _cell_microbatches(arch: str, shape_name: str) -> int:
    """Gradient-accumulation depth for the big train cells (memory)."""
    if shape_name != "train_4k":
        return 1
    big = {"jamba-1.5-large-398b": 8, "deepseek-v2-236b": 4,
           "command-r-35b": 2}
    return big.get(arch, 1)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             microbatches: int | None = None,
             ssm_impl: str | None = None,
             period: int | None = None) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.analysis import analyze_hlo_text, build_report
    from repro.configs import ARCHS, SHAPES, shape_applicable
    from repro.configs.base import RunConfig
    from repro.launch.mesh import make_production_mesh, mesh_config
    from repro.models import build_model, input_pspecs, input_specs
    from repro.models.common import Topo
    from repro.train.step import make_train_step, state_pspecs, state_shapes

    cfg, shape = ARCHS[arch], SHAPES[shape_name]
    import dataclasses
    if ssm_impl:
        cfg = dataclasses.replace(cfg, ssm_scan_impl=ssm_impl)
    if period:
        cfg = dataclasses.replace(cfg, layers_per_period=period)
    ok, reason = shape_applicable(cfg.family, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "SKIP", "reason": reason}

    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    mcfg = mesh_config(multi_pod=multi)
    topo = Topo(mcfg)
    n_chips = mcfg.num_devices
    mb = microbatches if microbatches is not None else \
        _cell_microbatches(arch, shape_name)

    t0 = time.time()
    kind = shape.kind
    model = build_model(cfg, topo, kind=kind)
    nshard = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))

    with mesh:
        if kind == "train":
            run_cfg = RunConfig(microbatches=mb, moment_dtype=(
                "bfloat16" if cfg.param_count() > 50e9 else "float32"))
            step = make_train_step(model, run_cfg, topo)
            sshapes = state_shapes(model, run_cfg)
            sspecs = nshard(state_pspecs(model, topo))
            ispecs = input_specs(cfg, shape)
            ishard = nshard(input_pspecs(cfg, shape, topo))
            lowered = jax.jit(step, in_shardings=(sspecs, ishard),
                              out_shardings=(sspecs, None),
                              donate_argnums=(0,)).lower(sshapes, ispecs)
        elif kind == "prefill":
            pshapes = model.param_shapes()
            pspecs = nshard(model.param_specs())
            ispecs = input_specs(cfg, shape)
            ishard = nshard(input_pspecs(cfg, shape, topo))
            lowered = jax.jit(model.prefill,
                              in_shardings=(pspecs, ishard)).lower(
                pshapes, ispecs)
        else:  # decode
            pshapes = model.param_shapes()
            pspecs = nshard(model.param_specs())
            cshapes = model.cache_shape_structs(shape.global_batch, shape.seq_len)
            cspecs = nshard(model.cache_pspecs(shape.global_batch, shape.seq_len))
            tshard = NamedSharding(
                mesh, topo.pspec(("batch",), (shape.global_batch,)))
            lowered = jax.jit(model.decode_step,
                              in_shardings=(pspecs, cspecs, tshard, None),
                              donate_argnums=(1,)).lower(
                pshapes, cshapes,
                jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(f"memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
          f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB")
    ca = compiled.cost_analysis()
    print(f"cost_analysis (XLA, scan-body-once): flops={ca.get('flops', 0):.3e} "
          f"bytes={ca.get('bytes accessed', 0):.3e}")

    t0 = time.time()
    cost = analyze_hlo_text(compiled.as_text())
    t_parse = time.time() - t0
    report = build_report(cost, cfg, shape, mesh_name, n_chips)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "OK", "kind": kind, "microbatches": mb,
        "n_chips": n_chips,
        "memory": {
            "args_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "xla_cost": {"flops": ca.get("flops", 0.0),
                     "bytes": ca.get("bytes accessed", 0.0)},
        "roofline": report.to_dict(),
        "timings": {"lower_s": t_lower, "compile_s": t_compile,
                    "parse_s": t_parse},
    }
    print(f"roofline: t_comp={report.t_compute*1e3:.1f}ms "
          f"t_mem={report.t_memory*1e3:.1f}ms "
          f"t_coll={report.t_collective*1e3:.1f}ms "
          f"dominant={report.dominant} "
          f"useful_ratio={report.useful_ratio:.3f} "
          f"roofline_frac={report.roofline_fraction:.3f}")
    return rec


def all_cell_ids(mesh_sel: str) -> list[tuple[str, str, str]]:
    from repro.configs import ARCHS, ALL_SHAPES
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[mesh_sel]
    return [(a, s.name, m) for m in meshes for a in ARCHS for s in ALL_SHAPES]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--one", nargs=3, metavar=("ARCH", "SHAPE", "MESH"))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--ssm-impl", default=None, choices=["sequential", "associative"])
    ap.add_argument("--period", type=int, default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.one:
        arch, shape, mesh = args.one
        rec = run_cell(arch, shape, mesh, args.microbatches, args.ssm_impl,
                       args.period)
        out_dir = os.path.join(RESULTS, mesh)
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print("WROTE", path, rec["status"])
        return

    cells = all_cell_ids(args.mesh)
    if args.arch:
        cells = [c for c in cells if c[0] == args.arch]
    if args.shape:
        cells = [c for c in cells if c[1] == args.shape]
    if args.list:
        for c in cells:
            print(*c)
        return

    for arch, shape, mesh in cells:
        path = os.path.join(RESULTS, mesh, f"{arch}__{shape}.json")
        if os.path.exists(path) and not args.force:
            print(f"skip (done): {arch} {shape} {mesh}")
            continue
        print(f"=== {arch} {shape} {mesh} ===", flush=True)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--one", arch, shape, mesh]
        if args.microbatches is not None:
            cmd += ["--microbatches", str(args.microbatches)]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=3600)
        sys.stdout.write(r.stdout[-3000:])
        if r.returncode != 0:
            os.makedirs(os.path.join(RESULTS, mesh), exist_ok=True)
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "FAIL",
                           "error": r.stderr[-4000:]}, f, indent=1)
            sys.stdout.write("FAILED\n" + r.stderr[-1500:] + "\n")
        sys.stdout.flush()


if __name__ == "__main__":
    main()
