"""Production mesh construction (assignment MULTI-POD DRY-RUN spec).

Importing this module never touches jax device state; meshes are built
lazily inside the functions.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD
