"""Serving launcher: batched prefill + decode with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
        --prompt-len 16 --tokens 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.common import SMOKE_TOPO, Topo
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = cfg.reduced()
        topo = SMOKE_TOPO
    else:
        from repro.launch.mesh import mesh_config
        topo = Topo(mesh_config())

    engine = ServeEngine(cfg, topo, max_len=args.prompt_len + args.tokens + 4)
    params = engine.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == "audio":
        batch["frames"] = rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32) * 0.02
    out = engine.generate(params, batch, args.tokens)
    print("generated token ids:\n", out)
    print(f"prefill_tokens={engine.stats.prefill_tokens} "
          f"decode_steps={engine.stats.decode_steps}")


if __name__ == "__main__":
    main()
