"""Device inventory: the heterogeneous, variability-aware fleet model.

A fleet is a set of ``DeviceInstance``s drawn from the ``CHIP_MODELS``
registry.  Each instance carries its own ``ChipSpec`` whose
``perf_scale``/``power_scale`` fields are seeded per-device perturbations of
the nominal frequency->power/perf curves — the chip-to-chip silicon lottery
of "Not All GPUs Are Created Equal" (arXiv:2208.11035).  With variability
disabled every draw is exactly 1.0 and the instance spec is bit-identical to
the nominal model, which is what the homogeneous-fleet invariance tests pin.

Device-portable classification hangs off ``effective_tdp_w``: a power trace
captured on a device, divided by that device's *effective* TDP (nameplate x
power_scale), recovers the workload's intrinsic relative power curve.  Since
the power model is calibrated relative to TDP for every chip model, relative
curves are comparable across the whole fleet — so the single shipped
``ReferenceLibrary`` (built on the nominal v5e) serves every device.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.analysis.hardware import CHIP_MODELS, ChipSpec
from repro.core.classify import WorkloadProfile
from repro.telemetry.power_model import TPUPowerModel


@dataclass(frozen=True)
class VariabilityModel:
    """Seeded per-device multiplicative draws around the nominal curves.

    Draws are ``1 + sigma * z`` with ``z ~ N(0, 1)`` clipped to ``max_z``
    standard deviations (a chip can't be arbitrarily bad).  Defaults follow
    the ~5% frequency / ~8% power spreads reported for production fleets.
    With a sigma of 0 the draw is *exactly* 1.0 (the RNG is still consumed,
    so an inventory's device list doesn't depend on which sigmas are zero).
    """
    sigma_perf: float = 0.05
    sigma_power: float = 0.08
    max_z: float = 3.0

    @classmethod
    def none(cls) -> "VariabilityModel":
        """Variability disabled: every device is the nominal chip."""
        return cls(sigma_perf=0.0, sigma_power=0.0)

    def draw(self, rng: np.random.Generator) -> tuple[float, float]:
        z = np.clip(rng.standard_normal(2), -self.max_z, self.max_z)
        return 1.0 + self.sigma_perf * float(z[0]), \
            1.0 + self.sigma_power * float(z[1])


@dataclass(frozen=True)
class DeviceInstance:
    """One physical accelerator: a chip model plus its silicon-lottery spec."""
    device_id: str
    model: str                   # CHIP_MODELS key
    spec: ChipSpec               # per-instance (possibly perturbed) spec

    @property
    def effective_tdp_w(self) -> float:
        """The device's profile-normalization base (see module docstring)."""
        return self.spec.effective_tdp_w

    @property
    def nameplate_w(self) -> float:
        """What a TDP-provisioned scheduler must reserve for this device."""
        return self.spec.tdp_w

    def power_model(self, **kw) -> TPUPowerModel:
        """A ``TPUPowerModel`` bound to this instance's perturbed spec."""
        return TPUPowerModel(self.spec, **kw)

    def normalize_profile(self, profile: WorkloadProfile) -> WorkloadProfile:
        """Re-express a profile captured on this device in the fleet's
        device-portable frame: the trace stays in device watts but the
        normalization base becomes the device's effective TDP, so spike
        vectors and power quantiles are relative to the *intrinsic* curve.
        Identity (same object values) on an unperturbed device."""
        return dataclasses.replace(profile, tdp=self.effective_tdp_w)


# device health states (the fleet membership-churn model: production
# telemetry studies show devices fail, degrade, and come back constantly)
HEALTHY = "healthy"
DEGRADED = "degraded"       # straggling: still running, proactively drained
FAILED = "failed"           # gone: jobs must migrate, no new placements

_HEALTH_STATES = (HEALTHY, DEGRADED, FAILED)


class DeviceInventory:
    """Ordered collection of ``DeviceInstance``s with deterministic
    generation, simple lookup/grouping, and per-device health state.

    Health is inventory-level (the instances stay frozen value objects):
    ``mark_failed``/``mark_degraded``/``restore`` move a device between
    states, ``healthy``/``failed_ids``/``device_health`` are the views the
    fleet controller schedules against.  A fresh inventory is all-healthy,
    so the health layer is inert until a failure is injected — the
    byte-identity pins of the no-failure paths are untouched."""

    def __init__(self, devices=()):
        self._devices: list[DeviceInstance] = list(devices)
        ids = [d.device_id for d in self._devices]
        if len(set(ids)) != len(ids):
            raise ValueError("duplicate device_id in inventory")
        self._health: dict[str, str] = {i: HEALTHY for i in ids}

    @classmethod
    def generate(cls, counts: dict[str, int] | int,
                 variability: VariabilityModel | None = None,
                 seed: int = 0) -> "DeviceInventory":
        """Build a fleet: ``counts`` maps chip-model name -> device count (a
        bare int means that many nominal-model ``tpu-v5e`` chips).  Draws are
        taken from one seeded RNG in sorted-model order, so the same
        ``(counts, seed)`` always yields the same fleet."""
        if isinstance(counts, int):
            counts = {"tpu-v5e": counts}
        var = variability or VariabilityModel.none()
        rng = np.random.default_rng(seed)
        devices = []
        for model_name in sorted(counts):
            base = CHIP_MODELS[model_name]       # KeyError on unknown model
            for i in range(counts[model_name]):
                perf, power = var.draw(rng)
                spec = dataclasses.replace(base, perf_scale=perf,
                                           power_scale=power)
                devices.append(DeviceInstance(
                    device_id=f"{model_name}/{i:03d}", model=model_name,
                    spec=spec))
        return cls(devices)

    def __len__(self) -> int:
        return len(self._devices)

    def __iter__(self):
        return iter(self._devices)

    def __getitem__(self, i: int) -> DeviceInstance:
        return self._devices[i]

    def __contains__(self, key) -> bool:
        """O(1) membership by device_id (or a DeviceInstance's id)."""
        if isinstance(key, DeviceInstance):
            key = key.device_id
        return key in self._health

    def get(self, device_id: str) -> DeviceInstance:
        for d in self._devices:
            if d.device_id == device_id:
                return d
        raise KeyError(device_id)

    def by_model(self, model: str) -> list[DeviceInstance]:
        return [d for d in self._devices if d.model == model]

    @property
    def models(self) -> list[str]:
        """Distinct chip models present, in first-seen order."""
        seen: dict[str, None] = {}
        for d in self._devices:
            seen.setdefault(d.model, None)
        return list(seen)

    @property
    def nameplate_w(self) -> float:
        """Total nameplate TDP across the fleet (per-device, 1 chip each)."""
        return sum(d.nameplate_w for d in self._devices)

    # -- health ----------------------------------------------------------
    def _set_health(self, device_id: str, state: str) -> None:
        self.get(device_id)                  # KeyError on unknown device
        self._health[device_id] = state

    def mark_failed(self, device_id: str) -> None:
        """The device is gone: it leaves every healthy view until
        ``restore``; jobs bound to it must migrate."""
        self._set_health(device_id, FAILED)

    def mark_degraded(self, device_id: str) -> None:
        """The device is straggling: keep it out of new placements while it
        drains, but don't treat its telemetry as dead."""
        self._set_health(device_id, DEGRADED)

    def restore(self, device_id: str) -> None:
        """The device is back (replaced or recovered): it re-joins the
        healthy pool and may take new/migrated jobs again."""
        self._set_health(device_id, HEALTHY)

    def health(self, device_id: str) -> str:
        self.get(device_id)
        return self._health[device_id]

    def is_healthy(self, device_id: str) -> bool:
        return self.health(device_id) == HEALTHY

    @property
    def device_health(self) -> dict[str, str]:
        """device_id -> health state for every device, inventory order."""
        return {d.device_id: self._health[d.device_id]
                for d in self._devices}

    @property
    def healthy(self) -> list[DeviceInstance]:
        """Devices eligible for (new or migrated) placements."""
        return [d for d in self._devices
                if self._health[d.device_id] == HEALTHY]

    @property
    def failed_ids(self) -> list[str]:
        return [d.device_id for d in self._devices
                if self._health[d.device_id] == FAILED]

    @property
    def healthy_nameplate_w(self) -> float:
        """Nameplate TDP of the surviving (non-failed) devices only."""
        return sum(d.nameplate_w for d in self._devices
                   if self._health[d.device_id] != FAILED)

    @property
    def homogeneous(self) -> bool:
        """True when every device is the *identical* nominal chip: one model
        and no variability perturbations (all scales exactly 1.0)."""
        return len(self.models) <= 1 and all(
            d.spec.perf_scale == 1.0 and d.spec.power_scale == 1.0
            for d in self._devices)
