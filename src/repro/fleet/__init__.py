"""Heterogeneous fleet layer: variability-aware device models, multiplexed
telemetry, and cluster-wide online capping.

The fleet API path (the scale-out front door on top of ``repro.pipeline``):

    from repro.fleet import (DeviceInventory, VariabilityModel,
                             FleetTelemetryMux, FleetCapController)

    inv = DeviceInventory.generate({"tpu-v5e": 6, "tpu-v5p": 2},
                                   VariabilityModel(), seed=0)
    fleet = FleetCapController(lib, budget_w=0.8 * total_nameplate)
    mux = FleetTelemetryMux()
    for (stream, chips), dev in zip(jobs, inv):
        meta, chunks = stream_telemetry(stream, 1.0, dev.power_model(),
                                        device_id=dev.device_id)
        mux.add_job(fleet.admit(dev, meta, chips), meta, chunks)
    result = fleet.run(mux)        # early caps + budget-aware packing

Three layers:

  * ``inventory`` — ``DeviceInstance``/``DeviceInventory``: multiple chip
    generations (``analysis.hardware.CHIP_MODELS``) with seeded per-device
    perf/power variability draws; device-portable profile normalization.
  * ``mux`` — ``FleetTelemetryMux``: deterministically interleaves many
    jobs' ``TelemetryChunk`` streams into one system-wide feed.
  * ``controller`` — ``FleetCapController``: one ``OnlineCapController``
    per job under a shared cluster power budget, re-packing through the
    heterogeneity-aware ``PowerAwareScheduler`` on every early cap — and,
    with an ``inventory`` attached, surviving membership churn:
    ``fail_device``/``degrade_device``/``restore_device`` migrate jobs to
    healthy silicon from their cached decisions (zero re-classification;
    see ``repro.ft`` and ``benchmarks/bench_chaos.py``).
"""
from repro.fleet.controller import (FleetCapController, FleetEvent, FleetJob,
                                    FleetResult, RepackTrail)
from repro.fleet.inventory import (DEGRADED, FAILED, HEALTHY, DeviceInstance,
                                   DeviceInventory, VariabilityModel)
from repro.fleet.mux import FleetChunk, FleetTelemetryMux

__all__ = [
    "DeviceInstance", "DeviceInventory", "VariabilityModel",
    "FleetChunk", "FleetTelemetryMux",
    "FleetCapController", "FleetEvent", "FleetJob", "FleetResult",
    "RepackTrail",
    "HEALTHY", "DEGRADED", "FAILED",
]
