"""Fleet telemetry multiplexer: many concurrent job streams, one chunk feed.

On a real cluster the telemetry daemon polls every device on one wire and
hands the collector an interleaved sequence of per-device counter readings
("Characterizing Production GPU Workloads using System-wide Telemetry
Data", arXiv:2502.18680).  ``FleetTelemetryMux`` reproduces that view from
per-job ``stream_telemetry`` iterators: chunks are merged in arrival-time
order (the wall-clock time of a chunk's last sample edge), with job
admission order as the tie-break, so the interleave is fully deterministic.

Each yielded ``FleetChunk`` tags the raw ``TelemetryChunk`` with its job and
device, which is all ``FleetCapController`` needs to route it to the right
``ProfileBuilder``.  Per-job chunk order is preserved by construction, so
any single job's sub-stream is exactly what the un-muxed path would see —
the property the homogeneous-fleet byte-identity test pins.
"""
from __future__ import annotations

import heapq
from typing import NamedTuple

from repro.telemetry.simulator import TelemetryChunk, TraceMeta


class FleetChunk(NamedTuple):
    """One multiplexed poll: a raw counter chunk tagged with its origin.

    A ``NamedTuple`` rather than a frozen dataclass: the mux mints one per
    chunk per tick, and tuple construction is several times cheaper than
    ``object.__setattr__``-based frozen-dataclass init at fleet scale."""
    job_id: str
    device_id: str
    t_end: float                 # wall-clock time of the last sample edge (s)
    chunk: TelemetryChunk


class FleetTelemetryMux:
    """Merge per-job telemetry streams into one time-ordered chunk feed."""

    def __init__(self):
        self._jobs: list[tuple[str, str, float, object]] = []
        self._ids: set[str] = set()
        self._dead_jobs: set[str] = set()
        self._dead_devices: set[str] = set()

    def add_job(self, job_id: str, meta: TraceMeta, chunks,
                device_id: str | None = None, t_start: float = 0.0) -> None:
        """Register one job's chunk iterator.  ``device_id`` defaults to the
        stream's ``meta.device_id`` tag; ``t_start`` offsets the job's
        arrival on the fleet clock (0 = starts with the fleet)."""
        if job_id in self._ids:
            raise ValueError(f"duplicate job_id {job_id!r}")
        self._ids.add(job_id)
        did = meta.device_id if device_id is None else device_id
        self._jobs.append((job_id, did, float(t_start), iter(chunks)))

    def __len__(self) -> int:
        return len(self._jobs)

    # -- failure injection -----------------------------------------------
    def drop_job(self, job_id: str) -> None:
        """Stop delivering ``job_id``'s chunks (the job migrated or was
        cancelled mid-stream).  Takes effect immediately, even inside a
        live iteration: the next chunk due from that stream is discarded
        and the stream is not pulled again."""
        self._dead_jobs.add(job_id)

    def drop_device(self, device_id: str) -> None:
        """A device died: every stream tagged with its ``device_id`` goes
        silent from this poll on — the wire-level view of a failure.  Safe
        to call mid-iteration (the failure-injection path)."""
        self._dead_devices.add(device_id)

    def _is_dead(self, fchunk: FleetChunk) -> bool:
        return (fchunk.job_id in self._dead_jobs
                or fchunk.device_id in self._dead_devices)

    def _chunk_t_end(self, chunk: TelemetryChunk, t_start: float) -> float:
        n_end = chunk.start_index + len(chunk.energy_j)
        return t_start + n_end * chunk.sample_dt

    def __iter__(self):
        """Yield ``FleetChunk``s across all jobs in (t_end, admission-order)
        order — a lazy k-way heap merge, pulling each stream only as its
        chunks come due."""
        heap: list[tuple[float, int, FleetChunk]] = []
        iters: dict[int, tuple[str, str, float, object]] = {}
        for order, (job_id, did, t_start, it) in enumerate(self._jobs):
            iters[order] = (job_id, did, t_start, it)
            chunk = next(it, None)
            if chunk is not None:
                t_end = self._chunk_t_end(chunk, t_start)
                heapq.heappush(heap, (t_end, order, FleetChunk._make(
                    (job_id, did, t_end, chunk))))
        while heap:
            _, order, fchunk = heapq.heappop(heap)
            if self._is_dead(fchunk):
                continue           # stream went silent: discard, never pull
            yield fchunk
            job_id, did, t_start, it = iters[order]
            if job_id in self._dead_jobs or did in self._dead_devices:
                continue           # dropped while the chunk was being handled
            nxt = next(it, None)
            if nxt is not None:
                t_end = self._chunk_t_end(nxt, t_start)
                heapq.heappush(heap, (t_end, order, FleetChunk._make(
                    (job_id, did, t_end, nxt))))

    def ticks(self):
        """Yield *batches* of ``FleetChunk``s — all chunks sharing one
        ``t_end`` (one poll of the fleet wire) popped together, ordered by
        the same ``(t_end, admission-order)`` key as ``__iter__``.

        Concatenating the yielded batches reproduces ``__iter__``'s chunk
        sequence exactly; the batching only exposes which chunks are
        simultaneous so ``FleetCapController.ingest_tick`` can advance every
        live job in one columnar pass.  Streams are pulled lazily per tick
        (no per-chunk heap churn between equal timestamps), and
        ``drop_job``/``drop_device`` take effect at the same poll boundary
        as the per-chunk path.
        """
        heap: list[tuple[float, int, FleetChunk]] = []
        iters: dict[int, tuple[str, str, float, object]] = {}
        for order, (job_id, did, t_start, it) in enumerate(self._jobs):
            iters[order] = (job_id, did, t_start, it)
            chunk = next(it, None)
            if chunk is not None:
                t_end = self._chunk_t_end(chunk, t_start)
                heapq.heappush(heap, (t_end, order, FleetChunk._make(
                    (job_id, did, t_end, chunk))))
        while heap:
            t_now = heap[0][0]
            popped: list[tuple[int, FleetChunk]] = []
            while heap and heap[0][0] == t_now:
                _, order, fchunk = heapq.heappop(heap)
                popped.append((order, fchunk))
            batch = [fc for _, fc in popped if not self._is_dead(fc)]
            if batch:
                yield batch
            for order, fchunk in popped:
                job_id, did, t_start, it = iters[order]
                if job_id in self._dead_jobs or did in self._dead_devices:
                    continue       # dropped at (or before) this poll
                nxt = next(it, None)
                if nxt is not None:
                    t_end = self._chunk_t_end(nxt, t_start)
                    heapq.heappush(heap, (t_end, order, FleetChunk._make(
                        (job_id, did, t_end, nxt))))
