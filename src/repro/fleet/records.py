"""JSON record codecs for the fleet types the event journal references.

``CapDecision``/``JobPlan``/``FleetEvent`` round-trip through the tagged
``repro.api.results`` codec, but a journaled *admit* also has to carry the
job's device bindings and trace context — ``DeviceInstance`` (with its
possibly-perturbed per-instance ``ChipSpec``), ``TraceMeta``, and
``MeshConfig`` are not session results, so they get explicit record forms
here.  Every field is a JSON scalar/list, and floats survive the text
round-trip exactly (``json`` emits shortest-repr floats), so a device
rebuilt from its record has a bit-identical ``effective_tdp_w`` — the
normalization base crash recovery must reproduce.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.hardware import ChipSpec
from repro.configs.base import MeshConfig
from repro.fleet.inventory import DeviceInstance
from repro.telemetry.simulator import TraceMeta


def device_record(device: DeviceInstance) -> dict:
    return {"device_id": device.device_id, "model": device.model,
            "spec": dataclasses.asdict(device.spec)}


def device_from_record(rec: dict) -> DeviceInstance:
    return DeviceInstance(device_id=rec["device_id"], model=rec["model"],
                          spec=ChipSpec(**rec["spec"]))


def meta_record(meta: TraceMeta) -> dict:
    return dataclasses.asdict(meta)


def meta_from_record(rec: dict) -> TraceMeta:
    rec = dict(rec)
    # JSON turned the (duration, util_c, util_m) row tuples into lists;
    # restore the tuple shape so rebuilt metas compare equal to originals
    rec["kernel_rows"] = [tuple(row) for row in rec.get("kernel_rows", [])]
    return TraceMeta(**rec)


def mesh_record(mesh: MeshConfig | None) -> dict | None:
    if mesh is None:
        return None
    return {"shape": list(mesh.shape), "axis_names": list(mesh.axis_names)}


def mesh_from_record(rec: dict | None) -> MeshConfig | None:
    if rec is None:
        return None
    return MeshConfig(shape=tuple(rec["shape"]),
                      axis_names=tuple(rec["axis_names"]))
