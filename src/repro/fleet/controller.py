"""Cluster-wide online capping under a shared power budget.

``FleetCapController`` scales the PR 2 single-job pipeline to a
heterogeneous fleet: every admitted job gets its own ``ProfileBuilder`` and
``OnlineCapController`` (sharing one warm classifier), fed from the
``FleetTelemetryMux``'s interleaved chunk feed.  The moment any job's
confidence gate clears, its cap is actuated on its device and the whole pod
is re-packed through the heterogeneity-aware ``PowerAwareScheduler`` against
the shared cluster budget — the POLCA-style early-re-provisioning loop, now
cluster-wide.

Device portability: each job's builder normalizes by its *device's*
effective TDP (nameplate x per-chip power variability), so the partial
profiles it hands the classifier are in the same relative frame as the
single shipped (nominal-v5e) ``ReferenceLibrary``.  On a homogeneous
zero-variability fleet that base equals the nameplate TDP bit-for-bit, and
every per-job decision is byte-identical to running the single-job
``OnlineCapController.run`` path — the invariance ``tests/test_fleet.py``
pins.

Once a job has a decision its remaining telemetry is dropped (profiling
stops early on the device — the paper's cost saving).  Packing provisions
the neighbor's p99 (not p90) per-chip power by default so coincident
cross-job spikes stay inside the budget; ``benchmarks/bench_fleet.py``
validates the aggregate simulated fleet trace against it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.classify import MinosClassifier
from repro.fleet.inventory import DeviceInstance
from repro.fleet.mux import FleetChunk, FleetTelemetryMux
from repro.pipeline.builder import ProfileBuilder
from repro.pipeline.library import ReferenceLibrary
from repro.pipeline.online import CapDecision, OnlineCapController
from repro.sched.dvfs import SimActuator
from repro.sched.power_sched import JobPlan, PowerAwareScheduler, \
    ScheduleResult


@dataclass
class FleetJob:
    """One admitted job: its device binding plus the per-job pipeline."""
    job_id: str
    device: DeviceInstance
    chips: int
    builder: ProfileBuilder
    controller: OnlineCapController
    actuator: object               # FrequencyActuator | None (plugin-chosen)
    decision: CapDecision | None = None
    plan: JobPlan | None = None    # built once, when the decision lands
    profile_to_completion: bool = False   # keep building after the decision


@dataclass
class FleetResult:
    """Outcome of one fleet run: per-job decisions + the final packing."""
    decisions: dict[str, CapDecision] = field(default_factory=dict)
    schedule: ScheduleResult | None = None
    repacks: int = 0             # how many early caps triggered a re-pack
    budget_w: float = 0.0
    chunks_dropped: int = 0      # telemetry skipped after early decisions

    @property
    def early_decisions(self) -> int:
        return sum(d.early for d in self.decisions.values())


class FleetCapController:
    """Run one ``OnlineCapController`` per job under a shared power budget.

    ``references`` is a ``ReferenceLibrary`` (preferred: warm classifier) or
    a prebuilt ``MinosClassifier`` — shared by every job.  Gate thresholds
    (``min_confidence`` etc.) are forwarded verbatim to each per-job
    controller, so a one-job fleet reproduces the single-job path exactly.
    """

    def __init__(self, references, budget_w: float,
                 objective="powercentric",
                 provision_quantile="p99",
                 min_confidence: float = 0.3, min_fraction: float = 0.1,
                 min_spike_samples: int = 50,
                 actuator_factory=SimActuator.for_device):
        if isinstance(references, ReferenceLibrary):
            self.clf = references.classifier()
        elif isinstance(references, MinosClassifier):
            self.clf = references
        else:
            self.clf = MinosClassifier(list(references))
        self.budget_w = float(budget_w)
        self.objective = objective
        # per-device actuator plugin: called once per admitted job with the
        # job's DeviceInstance; None disables actuation entirely
        self.actuator_factory = actuator_factory
        self._gates = dict(min_confidence=min_confidence,
                           min_fraction=min_fraction,
                           min_spike_samples=min_spike_samples)
        # tdp_w is only the fallback for device-less queue entries; every
        # fleet job carries its own device
        self.scheduler = PowerAwareScheduler(
            self.clf, tdp_w=0.0, objective=objective,
            quantile=provision_quantile)
        self.jobs: dict[str, FleetJob] = {}
        self.repacks: list[ScheduleResult] = []
        self._dropped = 0

    # -- admission -------------------------------------------------------
    def admit(self, device: DeviceInstance, meta, chips: int = 1,
              job_id: str | None = None,
              profile_to_completion: bool = False) -> str:
        """Register a job on ``device``; returns its ``job_id`` (default
        ``"<workload>@<device>"``).  The job's builder normalizes by the
        device's effective TDP — the device-portable frame.

        ``profile_to_completion`` keeps ingesting telemetry into the job's
        builder after its cap decision lands (instead of dropping it), so a
        full-trace profile stays available — the convergence-study mode."""
        job_id = job_id or f"{meta.name}@{device.device_id}"
        if job_id in self.jobs:
            raise ValueError(f"duplicate job_id {job_id!r}")
        actuator = self.actuator_factory(device) \
            if self.actuator_factory is not None else None
        controller = OnlineCapController(
            self.clf, objective=self.objective, actuator=actuator,
            device_id=device.device_id, **self._gates)
        self.jobs[job_id] = FleetJob(
            job_id=job_id, device=device, chips=int(chips),
            builder=ProfileBuilder(meta, tdp=device.effective_tdp_w),
            controller=controller, actuator=actuator,
            profile_to_completion=profile_to_completion)
        return job_id

    # -- streaming -------------------------------------------------------
    def ingest(self, fchunk: FleetChunk) -> CapDecision | None:
        """Route one multiplexed chunk to its job.  Returns that job's
        ``CapDecision`` when this chunk tips its confidence gate (which also
        re-packs the fleet); ``None`` otherwise."""
        return self.ingest_chunk(fchunk.job_id, fchunk.chunk)

    def ingest_chunk(self, job_id: str, chunk) -> CapDecision | None:
        """Un-muxed entry point: ingest one raw ``TelemetryChunk`` for
        ``job_id`` (the ``MinosSession``/``JobHandle`` feed path)."""
        job = self.jobs[job_id]
        if job.decision is not None:
            if not job.profile_to_completion:
                self._dropped += 1
                return None        # profiling already stopped for this job
            job.builder.ingest(chunk)
            return None            # decision already made; just keep building
        job.builder.ingest(chunk)
        decision = job.controller.observe(job.builder)
        if decision is None:
            return None
        self._decide(job, decision)
        self._repack()
        return decision

    def finalize(self) -> FleetResult:
        """Decide any still-undecided jobs from their completed profiles,
        re-pack once more, and return the fleet outcome."""
        pending = [j for j in self.jobs.values() if j.decision is None]
        for job in pending:
            self._decide(job, job.controller.finalize(job.builder))
        if pending or not self.repacks:
            self._repack()
        return FleetResult(
            decisions={j.job_id: j.decision for j in self.jobs.values()},
            schedule=self.repacks[-1], repacks=len(self.repacks),
            budget_w=self.budget_w, chunks_dropped=self._dropped)

    def finalize_job(self, job_id: str) -> CapDecision:
        """Decide one still-undecided job from whatever it has ingested so
        far (the batch-equivalent decision) and re-pack; a no-op for jobs
        that already decided."""
        job = self.jobs[job_id]
        if job.decision is None:
            self._decide(job, job.controller.finalize(job.builder))
            self._repack()
        return job.decision

    def run(self, mux: FleetTelemetryMux) -> FleetResult:
        """Pump the multiplexed feed to completion: every chunk is routed,
        each early cap re-packs the fleet, stragglers decide at stream end."""
        for fchunk in mux:
            self.ingest(fchunk)
        return self.finalize()

    # -- dynamic lifecycle -----------------------------------------------
    def retire(self, job_id: str) -> FleetJob:
        """Remove a job from the fleet (it finished or was cancelled): its
        telemetry routing stops and its plan leaves the packing, releasing
        its budget share.  If the job was planned, the survivors re-pack
        into the freed budget — from their cached ``JobPlan``s, so a
        retirement never re-classifies anything."""
        job = self.jobs.pop(job_id)    # KeyError on unknown/already-retired
        if job.plan is not None:
            self._repack()
        return job

    def set_budget(self, budget_w: float) -> None:
        """Change the shared power budget; re-packs the decided jobs against
        the new ceiling (cached plans only — no re-classification)."""
        self.budget_w = float(budget_w)
        if any(j.plan is not None for j in self.jobs.values()):
            self._repack()

    # -- packing ---------------------------------------------------------
    def _decide(self, job: FleetJob, decision: CapDecision) -> None:
        """Pin a job's decision and build its ``JobPlan`` once, straight
        from the decision's Algorithm 1 selection — re-packs never
        re-classify."""
        job.decision = decision
        job.plan = self.scheduler.plan_from_selection(
            decision.selection, job.chips, job.device, job_id=job.job_id)

    def _repack(self) -> ScheduleResult:
        """Re-pack every decided job (admission order) into the budget."""
        res = self.scheduler.pack(
            (j.plan for j in self.jobs.values() if j.plan is not None),
            budget_w=self.budget_w)
        self.repacks.append(res)
        return res
