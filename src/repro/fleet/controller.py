"""Cluster-wide online capping under a shared power budget.

``FleetCapController`` scales the PR 2 single-job pipeline to a
heterogeneous fleet: every admitted job gets its own ``ProfileBuilder`` and
``OnlineCapController`` (sharing one warm classifier), fed from the
``FleetTelemetryMux``'s interleaved chunk feed.  The moment any job's
confidence gate clears, its cap is actuated on its device and the whole pod
is re-packed through the heterogeneity-aware ``PowerAwareScheduler`` against
the shared cluster budget — the POLCA-style early-re-provisioning loop, now
cluster-wide.

Device portability: each job's builder normalizes by its *device's*
effective TDP (nameplate x per-chip power variability), so the partial
profiles it hands the classifier are in the same relative frame as the
single shipped (nominal-v5e) ``ReferenceLibrary``.  On a homogeneous
zero-variability fleet that base equals the nameplate TDP bit-for-bit, and
every per-job decision is byte-identical to running the single-job
``OnlineCapController.run`` path — the invariance ``tests/test_fleet.py``
pins.

Once a job has a decision its remaining telemetry is dropped (profiling
stops early on the device — the paper's cost saving).  Packing provisions
the neighbor's p99 (not p90) per-chip power by default so coincident
cross-job spikes stay inside the budget; ``benchmarks/bench_fleet.py``
validates the aggregate simulated fleet trace against it.

Fault tolerance (connects ``repro.ft`` to the fleet): construct with an
``inventory`` and the controller survives membership churn —
``fail_device`` migrates every affected job to surviving healthy silicon by
re-costing its cached ``CapDecision`` selection against the new device's
effective TDP (``PowerAwareScheduler.migrate_plan``: **zero classifier
calls**, the same invariant as retire/set_budget), ``degrade_device``
drains a straggling device proactively, ``restore_device`` returns it to
the placement pool.  Multi-chip jobs that lose part of their device span
shrink through ``ft.plan_new_mesh``/``rescale_batch`` instead of migrating
wholesale.  A ``FleetStragglerAdapter`` wired via ``straggler_adapter``
turns the mux's per-device chunk cadence into automatic degrade-and-drain.
``benchmarks/bench_chaos.py`` drives the whole loop under seeded failure
injection.
"""
from __future__ import annotations

import warnings
from contextlib import nullcontext
from dataclasses import dataclass, field
from time import perf_counter

from repro.configs.base import MeshConfig
from repro.core.classify import MinosClassifier
from repro.fleet.inventory import FAILED, HEALTHY, DeviceInstance, \
    DeviceInventory
from repro.fleet.mux import FleetChunk, FleetTelemetryMux
from repro.fleet.records import device_record, meta_record, mesh_record
from repro.ft.elastic import plan_new_mesh, rescale_batch
from repro.ft.fleetwatch import FleetStragglerAdapter
from repro.pipeline.batch import BatchProfileEngine
from repro.pipeline.builder import ProfileBuilder
from repro.pipeline.library import ReferenceLibrary
from repro.pipeline.online import CapDecision, OnlineCapController, \
    finalize_fleet, observe_fleet
from repro.sched.dvfs import SimActuator
from repro.sched.power_sched import IncrementalPacker, JobPlan, \
    PowerAwareScheduler, RepackStats, ScheduleResult
from repro.store import kinds


class _PendingRepack:
    """A re-pack recorded but not yet materialized: holds the live packer
    plus the exact power totals at record time.  If the packer has not
    moved on, resolving yields the full ``ScheduleResult`` (byte-identical
    to ``pack()``); once superseded, only the totals survive as
    ``RepackStats`` — per-job placements of historical packs are not kept
    at fleet scale."""

    __slots__ = ("packer", "version", "planned_w", "nameplate_w", "budget_w")

    def __init__(self, packer: IncrementalPacker):
        self.packer = packer
        self.version = packer.version
        self.planned_w = packer.planned_power_w
        self.nameplate_w = packer.nameplate_power_w
        self.budget_w = packer.budget_w

    def resolve(self):
        if self.version == self.packer.version:
            return self.packer.result()
        return RepackStats(self.planned_w, self.nameplate_w, self.budget_w)


class RepackTrail(list):
    """``FleetCapController.repacks`` with lazy materialization.

    The incremental path appends an O(1) ``_PendingRepack`` marker per
    re-pack instead of an O(n) ``ScheduleResult``; reading an entry (by
    index, slice, or iteration) resolves it in place — the most recent
    entry to the full byte-identical ``ScheduleResult``, superseded ones
    to their ``RepackStats`` power totals.  Every aggregate consumer
    (budget sweeps over history, reports, ``repacks[-1]``) works
    unchanged; only per-job placements of *historical* packs are gone."""

    __slots__ = ()

    def append_lazy(self, packer: IncrementalPacker) -> None:
        list.append(self, _PendingRepack(packer))

    def _resolve(self, i: int):
        entry = list.__getitem__(self, i)
        if type(entry) is _PendingRepack:
            entry = entry.resolve()
            list.__setitem__(self, i, entry)
        return entry

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self._resolve(j) for j in range(*i.indices(len(self)))]
        if i < 0:
            i += len(self)
        return self._resolve(i)

    def __iter__(self):
        # list iteration bypasses __getitem__; resolve explicitly
        for i in range(len(self)):
            yield self._resolve(i)


@dataclass(frozen=True)
class FleetEvent:
    """One fleet-membership/lifecycle event (JSON-round-trippable via
    ``repro.api.results``): a failure, a proactive degrade, a restore, or a
    per-job consequence (migrate / shrink / strand)."""
    kind: str                    # fail|degrade|restore|migrate|shrink|strand
    device_id: str               # the device the event is about (source)
    job_id: str = ""             # affected job ("" = device-level event)
    to_device_id: str = ""       # migration target ("" = none)
    detail: str = ""             # human-readable specifics


@dataclass
class FleetJob:
    """One admitted job: its device binding plus the per-job pipeline."""
    job_id: str
    device: DeviceInstance         # primary device (profiling frame)
    chips: int
    builder: object                # ProfileBuilder | pipeline.batch.SlotBuilder
    controller: OnlineCapController
    actuator: object               # FrequencyActuator | None (plugin-chosen)
    decision: CapDecision | None = None
    plan: JobPlan | None = None    # built once, when the decision lands
    profile_to_completion: bool = False   # keep building after the decision
    devices: tuple = ()            # full multi-chip span (defaults (device,))
    mesh: MeshConfig | None = None        # multi-chip topology (optional)
    global_batch: int | None = None       # rescaled on elastic shrink
    needs_reprofile: bool = False  # mid-profile migrant awaiting its re-run


@dataclass
class FleetResult:
    """Outcome of one fleet run: per-job decisions + the final packing."""
    decisions: dict[str, CapDecision] = field(default_factory=dict)
    schedule: ScheduleResult | None = None
    repacks: int = 0             # how many early caps triggered a re-pack
    budget_w: float = 0.0
    chunks_dropped: int = 0      # telemetry skipped after early decisions
    events: list = field(default_factory=list)   # FleetEvents, in order

    @property
    def early_decisions(self) -> int:
        return sum(d.early for d in self.decisions.values())

    @property
    def migrations(self) -> int:
        return sum(e.kind in ("migrate", "shrink") for e in self.events)


class FleetCapController:
    """Run one ``OnlineCapController`` per job under a shared power budget.

    ``references`` is a ``ReferenceLibrary`` (preferred: warm classifier) or
    a prebuilt ``MinosClassifier`` — shared by every job.  Gate thresholds
    (``min_confidence`` etc.) are forwarded verbatim to each per-job
    controller, so a one-job fleet reproduces the single-job path exactly.

    ``inventory`` (optional) enables the fault-tolerance surface: failed /
    degraded devices are tracked there and migrations target its healthy
    view.  ``straggler_adapter`` (optional ``FleetStragglerAdapter``) makes
    degrade-and-drain automatic from the mux feed's chunk cadence.  Both
    default off, in which case every code path is byte-identical to the
    pre-FT controller.
    """

    def __init__(self, references, budget_w: float,
                 objective="powercentric",
                 provision_quantile="p99",
                 min_confidence: float = 0.3, min_fraction: float = 0.1,
                 min_spike_samples: int = 50,
                 actuator_factory=SimActuator.for_device,
                 inventory: DeviceInventory | None = None,
                 straggler_adapter: FleetStragglerAdapter | None = None,
                 journal=None, engine: str = "batched",
                 repack: str = "decision", packer: str = "incremental"):
        """``engine`` selects the builder state layout: ``"batched"``
        (default) backs every job by one slot of a shared columnar
        ``BatchProfileEngine`` — bit-identical to ``"perjob"`` (one
        ``ProfileBuilder`` per job, the reference path), but advanced in one
        stacked pass per ``ingest_tick``.  ``repack`` sets the re-packing
        cadence: ``"decision"`` (default) re-packs on every landed decision
        exactly like the per-chunk path; ``"tick"`` coalesces to one re-pack
        per mux tick — same final packing, O(ticks) instead of O(decisions)
        scheduler calls, the fleet-scale mode.  ``packer`` selects how each
        re-pack is computed: ``"incremental"`` (default) maintains the
        decided plans in an ``IncrementalPacker`` so every plan mutation
        updates only the affected tail of the first-fit pass —
        byte-identical results to ``"full"`` (one ``PowerAwareScheduler.
        pack`` sweep per re-pack, the hypothesis-pinned reference) at
        O(block + n/block) per event instead of O(n log n)."""
        if isinstance(references, ReferenceLibrary):
            self.clf = references.classifier()
        elif isinstance(references, MinosClassifier):
            self.clf = references
        else:
            self.clf = MinosClassifier(list(references))
        self.budget_w = float(budget_w)
        self.objective = objective
        # per-device actuator plugin: called once per admitted job with the
        # job's DeviceInstance; None disables actuation entirely
        self.actuator_factory = actuator_factory
        self._gates = dict(min_confidence=min_confidence,
                           min_fraction=min_fraction,
                           min_spike_samples=min_spike_samples)
        # tdp_w is only the fallback for device-less queue entries; every
        # fleet job carries its own device
        self.scheduler = PowerAwareScheduler(
            self.clf, tdp_w=0.0, objective=objective,
            quantile=provision_quantile)
        if engine not in ("batched", "perjob"):
            raise ValueError(f"engine must be 'batched' or 'perjob', "
                             f"got {engine!r}")
        if repack not in ("decision", "tick"):
            raise ValueError(f"repack must be 'decision' or 'tick', "
                             f"got {repack!r}")
        if packer not in ("incremental", "full"):
            raise ValueError(f"packer must be 'incremental' or 'full', "
                             f"got {packer!r}")
        self.engine = BatchProfileEngine() if engine == "batched" else None
        self.repack_mode = repack
        self.packer_mode = packer
        self._packer = self.scheduler.packer(self.budget_w) \
            if packer == "incremental" else None
        self.repack_s = 0.0          # wall-clock spent maintaining packings
        self.inventory = inventory
        self.straggler_adapter = straggler_adapter
        # write-ahead session store (repro.store.SessionStore), attached by
        # MinosSession when configured with a store path; None = no
        # durability, every code path byte-identical to the store-less
        # controller
        self.journal = journal
        # online class discovery (repro.discovery.DiscoveryController),
        # attached by MinosSession when configured with a discovery key;
        # None = inert, every code path byte-identical to the pre-discovery
        # controller
        self.discovery = None
        self.jobs: dict[str, FleetJob] = {}
        self.repacks = RepackTrail()
        self.events: list[FleetEvent] = []
        self._dropped = 0
        self._failed_devices: set[str] = set()

    # -- durability ------------------------------------------------------
    def _journal(self, kind: str, **data) -> None:
        """Write-ahead: durably record a mutation *before* applying it.
        No-op without an attached session store."""
        if self.journal is not None:
            self.journal.record(kind, **data)

    def _emit(self, events) -> None:
        """Append lifecycle events, journaling each as an informational
        record.  Consequence events (migrate/shrink/strand) are reproduced
        by re-running the deterministic controller logic during recovery,
        so replay skips these records — they exist for reports."""
        for ev in events:
            self._journal(kinds.EVENT, event=ev)
        self.events.extend(events)

    def _sync_store(self) -> None:
        """Let the store write its cadence snapshot now that the mutation
        the latest records describe has fully applied (a snapshot taken
        mid-mutation would lose the in-flight record on replay)."""
        if self.journal is not None:
            self.journal.flush_snapshot()

    # -- online class discovery -------------------------------------------
    def set_discovery(self, discovery) -> None:
        """Attach a ``DiscoveryController``: every per-job controller's
        confidence gate gets tapped so finalized low-margin profiles flow
        into the quarantine pool (journaled write-ahead when a store is
        attached).  Pass ``None`` to detach."""
        self.discovery = discovery
        tap = self._quarantine_tap if discovery is not None else None
        for job in self.jobs.values():
            job.controller.quarantine_tap = tap

    def _quarantine_tap(self, profile, decision) -> None:
        """Gate-tap callback (fires inside ``OnlineCapController._record``):
        low-margin decisions quarantine their decided profile.  The entry
        record is journaled *before* the pool admits it, so a crash between
        the two replays to the identical pool state."""
        d = self.discovery
        if d is None or not d.wants(decision):
            return
        rec = d.entry_record(profile, decision)
        self._journal(kinds.QUARANTINE, entry=rec)
        d.admit_record(rec)

    def adopt_classifier(self, references) -> MinosClassifier:
        """Atomically repoint the whole fleet at a new reference classifier
        (a discovery promotion or rollback published a new library version):
        the shared classifier object, the scheduler's name-resolution memos,
        and every per-job controller swap together, so the batched
        observation paths (which group by classifier identity) keep seeing
        ONE shared object.  Call only between ticks — decisions already
        made keep their cached selections and are never re-derived.

        Zero classifier calls: building a warm classifier from a library is
        pure matrix adoption, and nothing here queries it."""
        if isinstance(references, ReferenceLibrary):
            clf = references.classifier()
        elif isinstance(references, MinosClassifier):
            clf = references
        else:
            clf = MinosClassifier(list(references))
        self.clf = clf
        self.scheduler.adopt_classifier(clf)
        for job in self.jobs.values():
            job.controller.clf = clf
        return clf

    # -- builder lifecycle -----------------------------------------------
    def _make_builder(self, meta, tdp: float):
        """One profiling-state handle in the configured engine: a slot view
        of the shared columnar engine, or a standalone ``ProfileBuilder``."""
        if self.engine is not None:
            return self.engine.builder(meta, tdp)
        return ProfileBuilder(meta, tdp=tdp)

    @staticmethod
    def _drop_builder(builder) -> None:
        """Release a builder's engine slot for reuse (no-op for the
        standalone ``ProfileBuilder``)."""
        release = getattr(builder, "release", None)
        if release is not None:
            release()

    def _replace_builder(self, job: FleetJob, meta=None,
                         tdp: float | None = None):
        """Swap a job's profiling state for a fresh run (migration /
        reprofile), freeing the old engine slot."""
        meta = meta if meta is not None else job.builder.meta
        tdp = job.device.effective_tdp_w if tdp is None else tdp
        self._drop_builder(job.builder)
        job.builder = self._make_builder(meta, tdp)
        return job.builder

    # -- admission -------------------------------------------------------
    def admit(self, device: DeviceInstance, meta, chips: int = 1,
              job_id: str | None = None,
              profile_to_completion: bool = False,
              devices=None, mesh: MeshConfig | None = None,
              global_batch: int | None = None) -> str:
        """Register a job on ``device``; returns its ``job_id`` (default
        ``"<workload>@<device>"``).  The job's builder normalizes by the
        device's effective TDP — the device-portable frame.

        ``profile_to_completion`` keeps ingesting telemetry into the job's
        builder after its cap decision lands (instead of dropping it), so a
        full-trace profile stays available — the convergence-study mode.

        Multi-chip jobs may span several devices: pass the full span as
        ``devices`` (must include ``device``, which stays the profiling
        frame) with ``chips`` divided evenly across it, plus an optional
        ``mesh``/``global_batch`` so a partial device loss can re-mesh
        through ``ft.plan_new_mesh``/``rescale_batch``."""
        spec = self._admit_validate(
            device, meta, chips=chips, job_id=job_id,
            profile_to_completion=profile_to_completion, devices=devices,
            mesh=mesh, global_batch=global_batch)
        self._journal_admit(spec)
        self._admit_apply(spec)
        self._sync_store()
        return spec["job_id"]

    def admit_many(self, admissions) -> list[str]:
        """Bulk admission: validate a whole batch up front (atomically — a
        bad entry rejects the batch before anything is journaled or
        applied), then journal every admit record in one coalesced store
        flush and apply them in order.  ``admissions`` is an iterable of
        dicts with :meth:`admit`'s keyword arguments (``device`` and
        ``meta`` required).  Returns the ``job_id``s in batch order.

        Journal bytes, job state, and placement are identical to calling
        ``admit`` once per entry; only the store-flush count changes."""
        taken: set[str] = set()
        specs = [self._admit_validate(taken=taken, **kw)
                 for kw in admissions]
        ctx = self.journal.batch() if self.journal is not None \
            else nullcontext()
        with ctx:
            for spec in specs:
                self._journal_admit(spec)
            for spec in specs:
                self._admit_apply(spec)
        self._sync_store()
        return [spec["job_id"] for spec in specs]

    def _admit_validate(self, device: DeviceInstance, meta, chips: int = 1,
                        job_id: str | None = None,
                        profile_to_completion: bool = False,
                        devices=None, mesh: MeshConfig | None = None,
                        global_batch: int | None = None,
                        taken: set | None = None) -> dict:
        """Shared admission checks; ``taken`` carries job_ids earlier in the
        same batch so bulk admission sees in-flight duplicates."""
        job_id = job_id or f"{meta.name}@{device.device_id}"
        if job_id in self.jobs or (taken is not None and job_id in taken):
            raise ValueError(f"duplicate job_id {job_id!r}")
        span = tuple(devices) if devices else (device,)
        if device not in span:
            raise ValueError("the primary device must be part of the span")
        if len({d.device_id for d in span}) != len(span):
            raise ValueError("duplicate device in job span")
        if chips % len(span):
            raise ValueError(f"chips={chips} does not divide evenly across "
                             f"{len(span)} devices")
        if self.inventory is not None:
            for d in span:
                did = d.device_id
                if did in self.inventory \
                        and not self.inventory.is_healthy(did):
                    raise ValueError(f"cannot admit on {did!r}: device is "
                                     f"{self.inventory.health(did)}")
        if taken is not None:
            taken.add(job_id)
        return dict(job_id=job_id, device=device, meta=meta,
                    chips=int(chips), span=span,
                    profile_to_completion=bool(profile_to_completion),
                    mesh=mesh, global_batch=global_batch)

    def _journal_admit(self, spec: dict) -> None:
        if self.journal is not None:
            # the record payload (dataclasses.asdict over meta/devices) is
            # the expensive part — only build it when a store is attached
            self._journal(
                kinds.ADMIT, job_id=spec["job_id"],
                device=device_record(spec["device"]), chips=spec["chips"],
                meta=meta_record(spec["meta"]),
                profile_to_completion=spec["profile_to_completion"],
                devices=[device_record(d) for d in spec["span"]],
                mesh=mesh_record(spec["mesh"]),
                global_batch=spec["global_batch"])

    def _admit_apply(self, spec: dict) -> None:
        device = spec["device"]
        actuator = self.actuator_factory(device) \
            if self.actuator_factory is not None else None
        controller = OnlineCapController(
            self.clf, objective=self.objective, actuator=actuator,
            device_id=device.device_id, **self._gates)
        if self.discovery is not None:
            controller.quarantine_tap = self._quarantine_tap
        self.jobs[spec["job_id"]] = FleetJob(
            job_id=spec["job_id"], device=device, chips=spec["chips"],
            builder=self._make_builder(spec["meta"],
                                       device.effective_tdp_w),
            controller=controller, actuator=actuator,
            profile_to_completion=spec["profile_to_completion"],
            devices=spec["span"], mesh=spec["mesh"],
            global_batch=spec["global_batch"])

    # -- streaming -------------------------------------------------------
    def ingest(self, fchunk: FleetChunk) -> CapDecision | None:
        """Route one multiplexed chunk to its job.  Returns that job's
        ``CapDecision`` when this chunk tips its confidence gate (which also
        re-packs the fleet); ``None`` otherwise.

        Telemetry from a failed device (in flight when the failure landed)
        is discarded, as is telemetry for a job that has left the fleet —
        the wire keeps no promises under churn.  With a straggler adapter
        attached, every chunk also feeds the per-device cadence monitor and
        flagged devices are degraded-and-drained automatically."""
        if self.straggler_adapter is not None:
            self.straggler_adapter.observe(fchunk)
            if self.straggler_adapter.should_check():
                self._auto_degrade()
        if fchunk.device_id in self._failed_devices:
            self._dropped += 1
            return None
        job = self.jobs.get(fchunk.job_id)
        if job is None:                    # retired/stranded mid-stream
            self._dropped += 1
            return None
        return self.ingest_chunk(fchunk.job_id, fchunk.chunk)

    def ingest_chunk(self, job_id: str, chunk,
                     _defer_repack: bool = False) -> CapDecision | None:
        """Un-muxed entry point: ingest one raw ``TelemetryChunk`` for
        ``job_id`` (the ``MinosSession``/``JobHandle`` feed path)."""
        job = self.jobs[job_id]
        if job.decision is not None:
            if not job.profile_to_completion:
                self._dropped += 1
                return None        # profiling already stopped for this job
            job.builder.ingest(chunk)
            return None            # decision already made; just keep building
        if job.needs_reprofile:
            # the partial trace died with the job's old device; without a
            # device tag on this path we cannot tell the stale stream from
            # the re-run, so demand an explicit restart
            raise ValueError(
                f"job {job_id!r} migrated mid-profile; restart its run via "
                f"restart_profile()/JobHandle.reprofile() before feeding")
        job.builder.ingest(chunk)
        decision = job.controller.observe(job.builder)
        if decision is None:
            return None
        self._decide(job, decision)
        if not _defer_repack:
            self._repack()
            self._sync_store()
        return decision

    def ingest_tick(self, batch) -> list[CapDecision]:
        """Advance the fleet by one mux tick — a batch of simultaneous
        ``FleetChunk``s from ``FleetTelemetryMux.ticks()`` — in one columnar
        engine pass instead of a per-job Python loop.  Returns the decisions
        that landed this tick, in chunk order.

        Outcome-equivalent to calling ``ingest`` per chunk in batch order:
        undecided jobs' chunks advance through ``BatchProfileEngine.
        ingest_batch`` (bit-identical builder state), then confidence gates
        are observed in the same chunk order, so decisions, journal records,
        and (with ``repack="decision"``) re-packs land in the identical
        sequence.  With ``repack="tick"`` all of a tick's decisions share
        one closing re-pack.  Falls back to the sequential path per chunk
        when the chunk can't batch (per-job engine, duplicate job in one
        batch, straggler cadence monitoring — which is order-sensitive)."""
        if self.straggler_adapter is not None:
            # cadence monitoring consumes chunks one at a time in wire
            # order; keep that path byte-identical
            return [d for d in (self.ingest(fc) for fc in batch)
                    if d is not None]
        defer = self.repack_mode == "tick"
        store_ctx = self.journal.batch() if self.journal is not None \
            else nullcontext()
        decisions: list[CapDecision] = []
        with store_ctx:
            # route: engine-eligible chunks batch; the rest go sequential
            rows = []               # (fchunk, job | None, batched, observe)
            seen: set[str] = set()
            slots, chunks = [], []
            jobs_get = self.jobs.get          # hoisted: this loop runs once
            failed = self._failed_devices     # per chunk at fleet scale
            eng = self.engine
            for fc in batch:
                if fc.device_id in failed:
                    self._dropped += 1
                    continue
                job = jobs_get(fc.job_id)
                if job is None:            # retired/stranded mid-stream
                    self._dropped += 1
                    continue
                eligible = (eng is not None
                            and fc.job_id not in seen
                            and getattr(job.builder, "engine", None) is eng
                            and not job.needs_reprofile
                            and (job.decision is None
                                 or job.profile_to_completion))
                seen.add(fc.job_id)
                if eligible:
                    slots.append(job.builder.slot)
                    chunks.append(fc.chunk)
                    rows.append((fc, job, True, job.decision is None))
                else:
                    rows.append((fc, job, False, False))
            if slots:
                self.engine.ingest_batch(slots, chunks)
            # one classification sweep for every gate-passing undecided job
            # this tick (engine rows only mutate through ingest_batch above,
            # so the batched observations see exactly the state the per-row
            # observe calls would)
            obs = [pos for pos, (_, job, batched, observe) in enumerate(rows)
                   if batched and observe]
            tick_ds = dict(zip(obs, observe_fleet(
                [(rows[pos][1].controller, rows[pos][1].builder)
                 for pos in obs]))) if obs else {}
            for pos, (fc, job, batched, observe) in enumerate(rows):
                if not batched:
                    d = self.ingest_chunk(fc.job_id, fc.chunk,
                                          _defer_repack=defer)
                elif observe:
                    d = tick_ds.get(pos)
                    if d is not None:
                        self._decide(job, d)
                        if not defer:
                            self._repack()
                            self._sync_store()
                else:
                    d = None       # decided profile-to-completion job
                if d is not None:
                    decisions.append(d)
            if defer and decisions:
                self._repack()
                self._sync_store()
        return decisions

    def finalize(self) -> FleetResult:
        """Decide any still-undecided jobs from their completed profiles,
        re-pack once more, and return the fleet outcome.  Jobs with nothing
        ingested (e.g. mid-profile migrants whose re-run never arrived —
        see ``restart_profile``) stay undecided and are left out of the
        decision map rather than classified from an empty trace."""
        pending = [j for j in self.jobs.values()
                   if j.decision is None and j.builder.n_ingested > 0]
        batched = [j for j in pending
                   if self.engine is not None
                   and getattr(j.builder, "engine", None) is self.engine]
        # engine-backed stragglers classify in one batched sweep; decisions
        # still adopt in admission order so journal replay stays verbatim
        pre = dict(zip(
            (j.job_id for j in batched),
            finalize_fleet([(j.controller, j.builder) for j in batched]))) \
            if batched else {}
        for job in pending:
            decision = pre.get(job.job_id)
            if decision is None:
                decision = job.controller.finalize(job.builder)
            self._decide(job, decision)
        if pending or not self.repacks:
            self._repack()
        self._sync_store()
        return FleetResult(
            decisions={j.job_id: j.decision for j in self.jobs.values()
                       if j.decision is not None},
            schedule=self.repacks[-1], repacks=len(self.repacks),
            budget_w=self.budget_w, chunks_dropped=self._dropped,
            events=list(self.events))

    def finalize_job(self, job_id: str) -> CapDecision:
        """Decide one still-undecided job from whatever it has ingested so
        far (the batch-equivalent decision) and re-pack; a no-op for jobs
        that already decided."""
        job = self.jobs[job_id]
        if job.decision is None:
            self._decide(job, job.controller.finalize(job.builder))
            self._repack()
            self._sync_store()
        return job.decision

    def restart_profile(self, job_id: str, meta=None) -> None:
        """Reset an undecided job's profiling run — the recovery step after
        a mid-profile migration, whose partial trace died with its device.
        The fresh builder normalizes by the job's *current* device frame;
        pass the re-run's ``TraceMeta`` (its sample count differs on the
        new silicon) or inherit the old one."""
        job = self.jobs[job_id]
        if job.decision is not None:
            raise ValueError(f"job {job_id!r} already decided; nothing to "
                             f"re-profile")
        meta = meta if meta is not None else job.builder.meta
        self._journal(kinds.REPROFILE, job_id=job_id, meta=meta_record(meta))
        self._replace_builder(job, meta)
        job.needs_reprofile = False
        self._sync_store()

    def run(self, mux: FleetTelemetryMux) -> FleetResult:
        """Pump the multiplexed feed to completion: every mux tick advances
        all simultaneous jobs in one columnar pass, each early cap re-packs
        the fleet (per the ``repack`` cadence), stragglers decide at stream
        end.  Outcomes are byte-identical to the per-chunk drain."""
        for batch in mux.ticks():
            self.ingest_tick(batch)
        return self.finalize()

    # -- dynamic lifecycle -----------------------------------------------
    def retire(self, job_id: str) -> FleetJob:
        """Remove a job from the fleet (it finished or was cancelled): its
        telemetry routing stops and its plan leaves the packing, releasing
        its budget share.  If the job was planned, the survivors re-pack
        into the freed budget — from their cached ``JobPlan``s, so a
        retirement never re-classifies anything."""
        if job_id not in self.jobs:    # KeyError on unknown/already-retired
            raise KeyError(job_id)
        self._journal(kinds.RETIRE, job_id=job_id)
        job = self.jobs.pop(job_id)
        self._drop_builder(job.builder)
        if job.plan is not None:
            self._unpack(job.plan)
            self._repack()
        self._sync_store()
        return job

    def set_budget(self, budget_w: float) -> None:
        """Change the shared power budget; re-packs the decided jobs against
        the new ceiling (cached plans only — no re-classification)."""
        self._journal(kinds.BUDGET, budget_w=float(budget_w))
        self.budget_w = float(budget_w)
        if self._has_plans():
            self._repack()
        self._sync_store()

    # -- fault tolerance -------------------------------------------------
    def fail_device(self, device_id: str) -> list[FleetEvent]:
        """A device died: mark it failed, stop trusting its telemetry, and
        migrate every affected job to surviving healthy devices.

        Decided jobs carry their cached ``CapDecision`` selection, so the
        migration is ``PowerAwareScheduler.migrate_plan`` — a re-costing
        against the new device's effective TDP with **zero classifier
        calls** (device-portable classification makes cross-model migration
        free).  Undecided jobs restart profiling on the target device (the
        failed device's partial trace is unfinishable).  Multi-chip jobs
        that only lost part of their span shrink via ``ft.plan_new_mesh``/
        ``rescale_batch`` instead.  Jobs with nowhere to go are stranded:
        they leave the packing (drawing no budget) until capacity returns.
        Ends with a single re-pack of the survivors.

        Returns this failure's events (also appended to ``self.events``)."""
        inv = self._require_inventory("fail_device")
        inv.get(device_id)                   # KeyError on unknown device
        self._journal(kinds.FAIL, device=device_id)
        inv.mark_failed(device_id)
        self._failed_devices.add(device_id)
        events = self._drain_device(device_id, FleetEvent("fail", device_id))
        self._sync_store()
        return events

    def degrade_device(self, device_id: str) -> list[FleetEvent]:
        """A device is straggling: mark it degraded and proactively migrate
        its *decided* jobs to healthy devices (zero classifier calls, as in
        ``fail_device``).  Undecided jobs keep profiling — the power frame
        of a slow-but-alive chip is still valid — and migrate the moment
        they decide.  No-op if the device is already non-healthy."""
        inv = self._require_inventory("degrade_device")
        if inv.health(device_id) != HEALTHY:
            return []
        self._journal(kinds.DEGRADE, device=device_id)
        inv.mark_degraded(device_id)
        events = self._drain_device(device_id,
                                    FleetEvent("degrade", device_id),
                                    decided_only=True)
        self._sync_store()
        return events

    def restore_device(self, device_id: str) -> list[FleetEvent]:
        """The device is back: return it to the healthy placement pool and
        re-place any stranded jobs — capacity returned, so jobs that had
        nowhere to go re-plan from their cached decisions (zero classifier
        calls) and mid-profile strandees re-bind for their re-run.  Healthy
        placements stay where they are (migration is one-way)."""
        inv = self._require_inventory("restore_device")
        prior = inv.health(device_id)
        self._journal(kinds.RESTORE, device=device_id)
        inv.restore(device_id)
        self._failed_devices.discard(device_id)
        events = [FleetEvent("restore", device_id, detail=f"was {prior}")]
        replaced = False
        for job in self.jobs.values():
            health = inv.health(job.device.device_id)
            if job.decision is not None and job.plan is None:
                # stranded (by a fail, or a degrade drain that found no
                # target): capacity is back, put it somewhere
                if health == HEALTHY:
                    # its own device is back
                    self._set_plan(job, self._plan_for(job))
                    if job.actuator is not None:
                        job.actuator.set_cap(job.decision.cap)
                    events.append(FleetEvent(
                        "migrate", job.device.device_id, job_id=job.job_id,
                        to_device_id=job.device.device_id,
                        detail="re-placed after restore"))
                else:
                    events.append(self._migrate_job(job,
                                                    job.device.device_id))
                replaced = True
            elif job.decision is None and health == FAILED:
                # mid-profile resident of a dead device: re-bind it so its
                # re-run lands on live silicon
                events.append(self._migrate_job(job, job.device.device_id))
        self._emit(events)
        if replaced:
            self._repack()
        self._sync_store()
        return events

    def device_health(self) -> dict[str, str]:
        """device_id -> health for the attached inventory ({} if none)."""
        return {} if self.inventory is None \
            else dict(self.inventory.device_health)

    def _require_inventory(self, op: str) -> DeviceInventory:
        if self.inventory is None:
            raise ValueError(f"{op} needs an inventory of candidate devices;"
                             f" construct FleetCapController(..., "
                             f"inventory=...)")
        return self.inventory

    def _auto_degrade(self) -> None:
        """Degrade-and-drain devices the straggler adapter flags (only
        meaningful with an inventory; flagged devices without one are left
        to the caller via ``straggler_adapter.degraded()``)."""
        if self.inventory is None:
            return
        for device_id in self.straggler_adapter.degraded():
            if device_id in self.inventory \
                    and self.inventory.health(device_id) == HEALTHY:
                self.degrade_device(device_id)

    def _drain_device(self, device_id: str, cause: FleetEvent,
                      decided_only: bool = False) -> list[FleetEvent]:
        events = [cause]
        affected = [j for j in self.jobs.values()
                    if device_id in {d.device_id for d in j.devices}
                    and (j.decision is not None or not decided_only)]
        for job in affected:
            if len(job.devices) > 1:
                events.append(self._shrink_job(job, device_id))
            else:
                events.append(self._migrate_job(job, device_id))
        self._emit(events)
        if self._has_plans() or self.repacks:
            self._repack()
        return events

    def _placement_load_w(self) -> dict[str, float]:
        """Planned watts currently bound to each device (for the
        deterministic least-loaded migration target choice)."""
        load: dict[str, float] = {}
        for j in self.jobs.values():
            if j.plan is not None:
                load[j.device.device_id] = load.get(j.device.device_id, 0.0) \
                    + j.plan.predicted_p90_w * j.plan.chips
        return load

    def _pick_target(self, exclude: set[str]) -> DeviceInstance | None:
        """Least-loaded healthy device (ties broken by device_id) outside
        ``exclude`` — deterministic, so a replayed failure schedule yields
        a byte-identical recovery."""
        candidates = [d for d in (self.inventory.healthy
                                  if self.inventory is not None else [])
                      if d.device_id not in exclude]
        if not candidates:
            return None
        load = self._placement_load_w()
        return min(candidates,
                   key=lambda d: (load.get(d.device_id, 0.0), d.device_id))

    def _rebind(self, job: FleetJob, device: DeviceInstance) -> None:
        """Point a job's actuation + decision tagging at a new device and
        re-assert its cap there (decided jobs only)."""
        job.device = device
        job.controller.device_id = device.device_id
        job.actuator = self.actuator_factory(device) \
            if self.actuator_factory is not None else None
        job.controller.actuator = job.actuator
        if job.decision is not None and job.actuator is not None:
            job.actuator.set_cap(job.decision.cap)

    def _migrate_job(self, job: FleetJob, from_device_id: str) -> FleetEvent:
        target = self._pick_target(exclude={from_device_id})
        if target is None:
            # nowhere to go: the job leaves the packing (draws no budget)
            # but keeps its cached decision for when capacity returns
            # (restore_device re-places strandees)
            stranded_plan = job.plan
            self._set_plan(job, None)
            if job.decision is None:
                # the partial trace died with the device: drop it so a
                # later finalize cannot classify from the dead frame
                self._replace_builder(job)
                job.needs_reprofile = True
            return FleetEvent(
                "strand", from_device_id, job_id=job.job_id,
                detail="no healthy device available" if stranded_plan
                else "no healthy device available; profiling aborted")
        detail = ""
        if job.decision is not None:
            # the free path: re-cost the cached selection on the new device
            self._set_plan(job, self.scheduler.migrate_plan(
                job.plan or self._plan_for(job), target))
        else:
            # mid-profile: the partial trace died with the device — restart
            # the profiling run in the new device's normalization frame
            self._replace_builder(job, tdp=target.effective_tdp_w)
            job.needs_reprofile = True
            detail = "reprofile"
        self._rebind(job, target)
        job.devices = (target,)
        return FleetEvent("migrate", from_device_id, job_id=job.job_id,
                          to_device_id=target.device_id, detail=detail)

    def _shrink_job(self, job: FleetJob, lost_device_id: str) -> FleetEvent:
        """Partial span loss for a multi-chip job: keep the survivors and
        re-mesh down through ``ft.plan_new_mesh`` (model extent preserved,
        data extent the largest power of two that fits), rescaling the
        global batch to hold the per-device batch constant."""
        surviving = tuple(d for d in job.devices
                          if d.device_id != lost_device_id)
        chips_per_dev = job.chips // len(job.devices)
        surviving_chips = chips_per_dev * len(surviving)
        mesh = job.mesh or MeshConfig(shape=(job.chips, 1),
                                      axis_names=("data", "model"))
        try:
            eplan = plan_new_mesh(mesh, surviving_chips)
        except RuntimeError:
            # survivors can't hold the model extent: whole-job migration
            return self._migrate_job(job, lost_device_id)
        old_chips = job.chips
        job.mesh = eplan.new
        job.chips = eplan.new.num_devices
        job.devices = surviving
        if job.global_batch is not None:
            job.global_batch = rescale_batch(job.global_batch, eplan)
        if job.device.device_id == lost_device_id:
            self._rebind(job, surviving[0])
            if job.decision is None:
                # the profiling frame was the lost primary: its partial
                # trace is unfinishable — restart on the new primary
                self._replace_builder(job)
                job.needs_reprofile = True
        if job.decision is not None:
            self._set_plan(job, self.scheduler.migrate_plan(
                job.plan or self._plan_for(job), job.device,
                chips=job.chips))
        return FleetEvent(
            "shrink", lost_device_id, job_id=job.job_id,
            to_device_id=job.device.device_id,
            detail=f"chips {old_chips}->{job.chips} "
                   f"(lost={eplan.lost_devices} idle={eplan.idle_devices})")

    # -- packing ---------------------------------------------------------
    def _plan_for(self, job: FleetJob, selection=None) -> JobPlan:
        """(Re)build a job's plan from its cached decision selection —
        never a classification.  ``selection`` overrides for the moment a
        decision lands (the job field is not assigned yet)."""
        return self.scheduler.plan_from_selection(
            job.decision.selection if selection is None else selection,
            job.chips, job.device, job_id=job.job_id)

    def _decide(self, job: FleetJob, decision: CapDecision,
                plan: JobPlan | None = None) -> None:
        """Pin a job's decision and build its ``JobPlan`` once, straight
        from the decision's Algorithm 1 selection — re-packs never
        re-classify.  A job that decides while part of its span sits on a
        non-healthy device (degraded mid-profile) drains immediately:
        single-device jobs migrate, multi-chip jobs shrink the bad member
        away — the deferred half of ``degrade_device``'s contract.

        The decision record is journaled *with* its plan before either is
        adopted, so crash recovery re-adopts both verbatim (``plan`` is the
        replay path's verbatim hand-back)."""
        if plan is None:
            plan = self._plan_for(job, selection=decision.selection)
        self._journal(kinds.DECISION, job_id=job.job_id, decision=decision,
                      plan=plan)
        job.decision = decision
        self._set_plan(job, plan)
        if self.inventory is None:
            return
        for dev in list(job.devices):
            did = dev.device_id
            if dev not in job.devices:         # shrunk away by a prior turn
                continue
            if did in self.inventory \
                    and self.inventory.health(did) != HEALTHY:
                if len(job.devices) > 1:
                    self._emit([self._shrink_job(job, did)])
                else:
                    self._emit([self._migrate_job(job, did)])

    def _set_plan(self, job: FleetJob, plan: JobPlan | None) -> None:
        """The one way a job's plan changes: assign it and keep the
        incremental packer's population in lockstep.  Any plan the packer
        cannot hold exactly (non-finite power, colliding identity) degrades
        the controller to full packs — correctness over speed."""
        old, job.plan = job.plan, plan
        pk = self._packer
        if pk is None or old is plan:
            return
        t0 = perf_counter()
        try:
            if old is not None:
                pk.remove(old)
            if plan is not None:
                pk.insert(plan)
        except (KeyError, ValueError) as exc:
            self._packer = None
            warnings.warn(f"incremental packing disabled, falling back to "
                          f"full re-packs: {exc}", RuntimeWarning,
                          stacklevel=2)
        self.repack_s += perf_counter() - t0

    def _unpack(self, plan: JobPlan) -> None:
        """A plan leaves the fleet with its job (retire): evict it from the
        packer without touching the departed job."""
        pk = self._packer
        if pk is None:
            return
        t0 = perf_counter()
        try:
            pk.remove(plan)
        except KeyError as exc:
            self._packer = None
            warnings.warn(f"incremental packing disabled, falling back to "
                          f"full re-packs: {exc}", RuntimeWarning,
                          stacklevel=2)
        self.repack_s += perf_counter() - t0

    def _has_plans(self) -> bool:
        if self._packer is not None:
            return len(self._packer) > 0
        return any(j.plan is not None for j in self.jobs.values())

    def _repack(self) -> None:
        """Record the packing of every decided job into the budget.

        Incremental mode appends an O(1) lazy marker — the packer already
        tracks every plan mutation, so the ``ScheduleResult`` (byte-
        identical to a full ``pack()``) materializes only when the entry is
        actually read.  Full mode runs the reference O(n log n) sweep."""
        t0 = perf_counter()
        pk = self._packer
        if pk is not None:
            pk.set_budget(self.budget_w)     # O(1) when unchanged
            self.repacks.append_lazy(pk)
        else:
            self.repacks.append(self.scheduler.pack(
                (j.plan for j in self.jobs.values() if j.plan is not None),
                budget_w=self.budget_w))
        self.repack_s += perf_counter() - t0
