"""Training loop: checkpoint/restart, preemption, stragglers, telemetry.

The loop is deliberately host-driven and step-indexed: the data pipeline is
addressed by step number (no hidden iterator state), so crash/preempt restart
resumes bit-exact from the last committed checkpoint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.data.synthetic import SyntheticTokens
from repro.ft.heartbeat import PreemptionHandler, StragglerMonitor
from repro.models.common import Topo
from repro.models.model_zoo import build_model
from repro.train.step import init_state, make_train_step


@dataclass
class TrainResult:
    steps_run: int
    final_step: int
    losses: list = field(default_factory=list)
    preempted: bool = False
    restored_from: int | None = None
    step_durations: list = field(default_factory=list)


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, run_cfg: RunConfig,
                 topo: Topo, data=None,
                 telemetry_hook: Callable[[int, float, dict], None] | None = None,
                 preemption: PreemptionHandler | None = None):
        self.cfg, self.shape, self.run_cfg, self.topo = cfg, shape, run_cfg, topo
        self.model = build_model(cfg, topo, kind="train")
        self.step_fn = jax.jit(make_train_step(self.model, run_cfg, topo),
                               donate_argnums=(0,))
        self.data = data or SyntheticTokens(cfg, shape, seed=run_cfg.seed)
        self.telemetry_hook = telemetry_hook
        self.preemption = preemption or PreemptionHandler(install=False)
        self.straggler_monitor = StragglerMonitor()

    # ------------------------------------------------------------------
    def init_or_restore(self, key: jax.Array) -> tuple[dict, int, int | None]:
        directory = self.run_cfg.checkpoint_dir
        last = ckpt.latest_step(directory)
        if last is not None:
            state, step = ckpt.restore(directory, last)
            return state, step, last
        return init_state(self.model, self.run_cfg, key), 0, None

    def run(self, num_steps: int | None = None, key: jax.Array | None = None
            ) -> TrainResult:
        key = key if key is not None else jax.random.key(self.run_cfg.seed)
        state, start_step, restored = self.init_or_restore(key)
        total = num_steps if num_steps is not None else self.run_cfg.total_steps
        result = TrainResult(steps_run=0, final_step=start_step,
                             restored_from=restored)
        step = start_step
        while step < total:
            batch = jax.tree.map(
                lambda a: jax.numpy.asarray(a), self.data.batch_at(step))
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            step += 1
            result.steps_run += 1
            result.losses.append(loss)
            result.step_durations.append(dt)
            self.straggler_monitor.record(0, step, dt)
            if self.telemetry_hook:
                self.telemetry_hook(step, dt, {k: float(v) for k, v in metrics.items()})
            if self.preemption.preempted:
                ckpt.save(state, self.run_cfg.checkpoint_dir, step)
                result.preempted = True
                break
            if step % self.run_cfg.checkpoint_every == 0:
                ckpt.save(state, self.run_cfg.checkpoint_dir, step)
                ckpt.garbage_collect(self.run_cfg.checkpoint_dir)
        else:
            ckpt.save(state, self.run_cfg.checkpoint_dir, step)
        result.final_step = step
        self._state = state
        return result
