from repro.train.loop import Trainer, TrainResult
from repro.train.step import init_state, make_train_step, state_pspecs, state_shapes
