"""pjit train-step factory: microbatched grad accumulation + AdamW.

``make_train_step`` returns (step_fn, state_shapes, state_pspecs) so callers
(trainer, dry-run) can jit with exact in/out shardings and donate the state.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import RunConfig
from repro.models.common import Topo
from repro.optim import adamw_update, clip_by_global_norm, init_opt_state, \
    opt_state_shapes, warmup_cosine


def state_shapes(model, run_cfg: RunConfig) -> dict:
    ps = model.param_shapes()
    return {"params": ps, "opt": opt_state_shapes(ps, run_cfg.moment_dtype)}


def state_pspecs(model, topo: Topo) -> dict:
    ps = model.param_specs()
    return {
        "params": ps,
        "opt": {
            "m": jax.tree.map(lambda x: x, ps, is_leaf=lambda x: isinstance(x, P)),
            "v": jax.tree.map(lambda x: x, ps, is_leaf=lambda x: isinstance(x, P)),
            "step": P(),
        },
    }


def init_state(model, run_cfg: RunConfig, key: jax.Array) -> dict:
    params = model.init_params(key)
    return {"params": params, "opt": init_opt_state(params, run_cfg.moment_dtype)}


def _split_microbatches(batch: dict, n: int) -> dict:
    def sp(x):
        b = x.shape[0]
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(model, run_cfg: RunConfig, topo: Topo) -> Callable:
    """Returns step(state, batch) -> (state, metrics)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        return loss, metrics, grads

    def step(state: dict, batch: dict):
        params = state["params"]
        n_mb = run_cfg.microbatches
        if n_mb > 1:
            mbs = _split_microbatches(batch, n_mb)

            def acc_body(carry, mb):
                gsum, lsum = carry
                loss, metrics, grads = grads_of(params, mb)
                gsum = jax.tree.map(lambda a, g: a + g.astype(a.dtype), gsum, grads)
                return (gsum, lsum + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            (grads, loss), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            loss = loss / n_mb
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            loss, metrics, grads = grads_of(params, batch)

        grads, gnorm = clip_by_global_norm(grads, run_cfg.grad_clip)
        lr = warmup_cosine(run_cfg, state["opt"]["step"])
        new_params, new_opt = adamw_update(params, grads, state["opt"], run_cfg, lr)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        return {"params": new_params, "opt": new_opt}, metrics

    return step
