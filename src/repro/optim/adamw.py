"""AdamW with global-norm clipping and optional low-precision moments.

Moments inherit the parameters' (ZeRO-)sharding, so optimizer state is fully
sharded across the mesh.  ``moment_dtype="bfloat16"`` halves optimizer memory
(needed for the 398B config at 16 GB/chip; see DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


def init_opt_state(params: Any, moment_dtype: str = "float32") -> dict:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_shapes(param_shapes: Any, moment_dtype: str = "float32") -> dict:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, param_shapes),
        "v": jax.tree.map(zeros, param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params: Any, grads: Any, opt: dict, cfg: RunConfig,
                 lr: jax.Array) -> tuple[Any, dict]:
    step = opt["step"] + 1
    b1, b2, eps = cfg.beta1, cfg.beta2, cfg.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        delta = mh / (jnp.sqrt(vh) + eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}
