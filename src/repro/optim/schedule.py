"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import RunConfig


def warmup_cosine(cfg: RunConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.learning_rate * jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decayed = cfg.learning_rate * (0.1 + 0.9 * cos)
    return jnp.where(step < cfg.warmup_steps, warm, decayed)
