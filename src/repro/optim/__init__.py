from repro.optim.adamw import (adamw_update, clip_by_global_norm, global_norm,
                               init_opt_state, opt_state_shapes)
from repro.optim.schedule import warmup_cosine
