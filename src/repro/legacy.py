"""Seed (pre-vectorization) implementations of the profiling hot path.

Frozen verbatim snapshots of the scalar/Python-loop code that PR 1 replaced
with the vectorized event-stream engine:

  * ``simulate_dense``       — O(events x samples) dense-broadcast energy and
                               busy integration (``telemetry/simulator.py``)
  * ``ema_filter_loop``      — per-sample Python EMA (``core/spikes.py``)
  * ``power_neighbor_loop``/``util_neighbor_loop``/``choose_bin_size_loop``
                             — per-call spike-vector recomputation
                               (``core/classify.py`` / ``core/algorithm1.py``)
  * ``linkage_loop``         — per-point Lance-Williams update
  * ``silhouette_loop``      — per-point silhouette
  * ``kmeanspp_init_loop``   — O(k^2 n) kmeans++ seeding

They exist for exactly two consumers and nothing else:

  1. golden-equivalence tests (``tests/test_profiling_engine.py``) pinning
     the vectorized engine to the seed semantics at 1e-9, and
  2. ``benchmarks/bench_profiling_throughput.py`` measuring the before/after
     speedup.

Do not "fix" or optimize this module — it is the baseline.
"""
from __future__ import annotations

import numpy as np

from repro.core.classify import WorkloadProfile
from repro.telemetry.kernel_stream import KernelStream
from repro.telemetry.power_model import OVERSHOOT_TAU, TPUPowerModel


# ---------------------------------------------------------------------------
# telemetry: dense O(E x S) integration (seed simulate)
# ---------------------------------------------------------------------------
def _chunks(t0, t1, pw, size: int = 512):
    for i in range(0, len(t0), size):
        yield t0[i:i + size], t1[i:i + size], pw[i:i + size]


def integrate_events_dense(t0: np.ndarray, t1: np.ndarray, pw: np.ndarray,
                           edges: np.ndarray) -> np.ndarray:
    """Seed cumulative integral: per-edge dense clip-broadcast over events."""
    out = np.zeros(len(edges))
    for a, b, watts in _chunks(t0, t1, pw):
        contrib = np.clip(edges[None, :] - a[:, None], 0.0,
                          (b - a)[:, None]) * watts[:, None]
        out += contrib.sum(axis=0)
    return out


def simulate_dense(stream: KernelStream, freq: float, model: TPUPowerModel,
                   sample_dt: float = 1e-3, target_duration: float = 4.0,
                   max_iterations: int = 2000, noise: float = 0.03,
                   seed: int = 0):
    """The seed ``simulate`` (dense integration + loop EMA), verbatim.

    Returns the same ``SimTrace`` as ``repro.telemetry.simulate``.
    """
    from repro.telemetry.simulator import SimTrace

    execs = [model.exec_kernel(k, freq) for k in stream.kernels]
    gaps = np.array([k.gap_s for k in stream.kernels])
    durs = np.array([e.duration for e in execs])
    pows = np.array([e.power for e in execs])
    step_time = float(np.sum(gaps) + np.sum(durs))
    iters = int(np.clip(np.ceil(target_duration / max(step_time, 1e-9)),
                        1, max_iterations))

    nk = len(execs)
    idle = model.idle_w
    seg_d = np.empty(2 * nk)
    seg_p = np.empty(2 * nk)
    seg_busy = np.empty(2 * nk)
    seg_d[0::2] = gaps
    seg_d[1::2] = durs
    seg_p[0::2] = idle
    seg_p[1::2] = pows
    seg_busy[0::2] = 0.0
    seg_busy[1::2] = 1.0
    pad = max(10 * sample_dt, 0.01)
    d = np.concatenate([[pad], np.tile(seg_d, iters), [pad]])
    p = np.concatenate([[idle], np.tile(seg_p, iters), [idle]])
    busy_flag = np.concatenate([[0.0], np.tile(seg_busy, iters), [0.0]])
    keep = d > 0
    d, p, busy_flag = d[keep], p[keep], busy_flag[keep]

    t_edges = np.concatenate([[0.0], np.cumsum(d)])
    starts, ends = t_edges[:-1], t_edges[1:]
    ev_t0, ev_t1, ev_p = [starts], [ends], [p]
    prev_p = np.concatenate([[idle], p[:-1]])
    for i in np.nonzero(p - prev_p >= 30.0)[0]:
        amp = model.overshoot(prev_p[i], p[i])
        if amp is None:
            continue
        tau = min(OVERSHOOT_TAU, d[i])
        ev_t0.append(np.array([starts[i]]))
        ev_t1.append(np.array([starts[i] + tau]))
        ev_p.append(np.array([amp - p[i]]))
    t0 = np.concatenate(ev_t0)
    t1 = np.concatenate(ev_t1)
    pw = np.concatenate(ev_p)

    total_t = t_edges[-1]
    n_samples = int(total_t / sample_dt)
    edges = np.arange(n_samples + 1) * sample_dt

    energy = np.zeros(n_samples + 1)
    for a, b, watts in _chunks(t0, t1, pw):
        contrib = np.clip(edges[None, :] - a[:, None], 0.0,
                          (b - a)[:, None]) * watts[:, None]
        energy += contrib.sum(axis=0)

    rng = np.random.default_rng(seed)
    de = np.diff(energy)
    de = de * (1.0 + noise * rng.standard_normal(n_samples))
    out_mask = rng.random(n_samples) < 0.01
    de = np.where(out_mask, de * (1.0 + 0.5 * rng.random(n_samples)), de)
    p_raw = de / sample_dt

    busy_t0, busy_t1 = starts[busy_flag > 0], ends[busy_flag > 0]
    busy = np.zeros(n_samples)
    for a, b, _ in _chunks(busy_t0, busy_t1, np.ones_like(busy_t0)):
        contrib = np.clip(edges[None, :] - a[:, None], 0.0, (b - a)[:, None])
        busy += np.diff(contrib.sum(axis=0))
    busy = (busy > 0).astype(np.float64)

    filt = ema_filter_loop(p_raw, alpha=0.5)
    nz = np.nonzero(busy > 0)[0]
    filt = filt[nz[0]:nz[-1] + 1] if len(nz) else filt[:0]

    tot_d = durs.sum()
    app_sm = float((durs * [e.util_c for e in execs]).sum() / max(tot_d, 1e-12))
    app_dr = float((durs * [e.util_m for e in execs]).sum() / max(tot_d, 1e-12))
    rows = [(e.duration, e.util_c, e.util_m) for e in execs]
    return SimTrace(power_filtered=filt, power_raw=p_raw, busy=busy,
                    sample_dt=sample_dt, exec_time=step_time,
                    app_sm_util=app_sm, app_dram_util=app_dr,
                    kernel_rows=rows)


# ---------------------------------------------------------------------------
# spikes: per-sample Python EMA
# ---------------------------------------------------------------------------
def ema_filter_loop(power: np.ndarray, alpha: float = 0.5) -> np.ndarray:
    out = np.empty_like(power, dtype=np.float64)
    if len(power) == 0:
        return out
    acc = power[0]
    for i, p in enumerate(power):
        acc = alpha * p + (1 - alpha) * acc
        out[i] = acc
    return out


# ---------------------------------------------------------------------------
# classify: per-call spike-vector recomputation
# ---------------------------------------------------------------------------
def _cosine_distance(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = np.linalg.norm(a), np.linalg.norm(b)
    if na == 0 or nb == 0:
        return 1.0
    return float(1.0 - np.clip(np.dot(a, b) / (na * nb), -1.0, 1.0))


def power_neighbor_loop(references: list[WorkloadProfile],
                        target: WorkloadProfile, bin_size: float = 0.1,
                        exclude: str | None = None):
    v = target.spike_vec(bin_size)
    best, best_d = None, np.inf
    for r in references:
        if r.name == target.name or r.name == exclude:
            continue
        d = _cosine_distance(v, r.spike_vec(bin_size))
        if d < best_d:
            best, best_d = r, d
    return best, float(best_d)


def util_neighbor_loop(references: list[WorkloadProfile],
                       target: WorkloadProfile, exclude: str | None = None):
    v = target.util_point
    best, best_d = None, np.inf
    for r in references:
        if r.name == target.name or r.name == exclude:
            continue
        d = float(np.linalg.norm(v - r.util_point))
        if d < best_d:
            best, best_d = r, d
    return best, best_d


def choose_bin_size_loop(target: WorkloadProfile,
                         references: list[WorkloadProfile],
                         candidates=(0.05, 0.1, 0.15, 0.2, 0.25, 0.5),
                         quantile: float = 90.0) -> float:
    best_c, best_err = candidates[0], np.inf
    p_t = target.p_quantile(quantile)
    for c in candidates:
        nn, _ = power_neighbor_loop(references, target, bin_size=c)
        err = abs(p_t - nn.p_quantile(quantile))
        if err < best_err:
            best_c, best_err = c, err
    return best_c


# ---------------------------------------------------------------------------
# clustering: per-point loops
# ---------------------------------------------------------------------------
_LW = {
    "average": lambda ni, nj, nk: (ni / (ni + nj), nj / (ni + nj), 0.0, 0.0),
    "complete": lambda ni, nj, nk: (0.5, 0.5, 0.0, 0.5),
    "single": lambda ni, nj, nk: (0.5, 0.5, 0.0, -0.5),
}


def linkage_loop(dist: np.ndarray, method: str = "ward") -> np.ndarray:
    n = dist.shape[0]
    D = dist.astype(np.float64).copy()
    if method == "ward":
        D = D * D
    np.fill_diagonal(D, np.inf)
    sizes = {i: 1 for i in range(n)}
    ids = {i: i for i in range(n)}
    active = list(range(n))
    Z = np.zeros((n - 1, 4))
    big = np.full(D.shape, np.inf)
    big[:D.shape[0], :D.shape[1]] = D
    D = big
    next_id = n
    for step in range(n - 1):
        sub = D[np.ix_(active, active)]
        flat = np.argmin(sub)
        a, b = divmod(flat, len(active))
        if a == b:
            raise RuntimeError("degenerate linkage state")
        i, j = active[a], active[b]
        if i > j:
            i, j = j, i
        dij = D[i, j]
        d_rep = np.sqrt(dij) if method == "ward" else dij
        Z[step] = [ids[i], ids[j], d_rep, sizes[i] + sizes[j]]
        ni, nj = sizes[i], sizes[j]
        for k in active:
            if k in (i, j):
                continue
            nk = sizes[k]
            dik, djk = D[i, k], D[j, k]
            if method == "ward":
                tot = ni + nj + nk
                new = ((ni + nk) * dik + (nj + nk) * djk - nk * dij) / tot
            else:
                ai, aj, bb, g = _LW[method](ni, nj, nk)
                new = ai * dik + aj * djk + bb * dij + g * abs(dik - djk)
            D[i, k] = D[k, i] = new
        sizes[i] = ni + nj
        ids[i] = next_id
        next_id += 1
        active.remove(j)
        D[j, :] = np.inf
        D[:, j] = np.inf
    return Z


def silhouette_loop(X: np.ndarray, labels: np.ndarray) -> float:
    from repro.core.clustering import euclidean_distance_matrix

    X = np.asarray(X, np.float64)
    labels = np.asarray(labels)
    n = len(X)
    uniq = np.unique(labels)
    if len(uniq) < 2 or n < 3:
        return 0.0
    D = euclidean_distance_matrix(X)
    s = np.zeros(n)
    for i in range(n):
        same = labels == labels[i]
        n_same = same.sum()
        if n_same <= 1:
            s[i] = 0.0
            continue
        a = D[i, same].sum() / (n_same - 1)
        b = np.inf
        for c in uniq:
            if c == labels[i]:
                continue
            mask = labels == c
            b = min(b, D[i, mask].mean())
        s[i] = (b - a) / max(a, b) if max(a, b) > 0 else 0.0
    return float(np.mean(s))


def kmeanspp_init_loop(X: np.ndarray, k: int,
                       rng: np.random.Generator) -> np.ndarray:
    """Seed kmeans++ seeding: recomputes distances to ALL centers each step."""
    X = np.asarray(X, np.float64)
    centers = [X[rng.integers(len(X))]]
    while len(centers) < k:
        d2 = np.min(
            [np.sum((X - c) ** 2, axis=1) for c in centers], axis=0)
        tot = d2.sum()
        if tot <= 0:
            centers.append(X[rng.integers(len(X))])
            continue
        centers.append(X[rng.choice(len(X), p=d2 / tot)])
    return np.stack(centers)
