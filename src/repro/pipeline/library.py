"""Versioned reference library: the mutable, persistent home of the
reference-profile set.

Replaces the ad-hoc ``list[WorkloadProfile]`` + ``reference_store.save/load``
pair with one object that owns:

  * **incremental membership** — ``add``/``remove`` bump a version counter
    and update the per-bin-size spike matrices row-wise instead of
    re-histogramming the whole set;
  * **warm-start persistence** — ``save`` writes the profiles (float64
    traces) *plus* the spike matrices keyed by a content fingerprint;
    ``load`` verifies the fingerprint and seeds ``MinosClassifier`` with the
    cached matrices, so a process cold-start skips the 28-trace
    re-histogramming entirely while producing byte-identical neighbor
    decisions (pinned by ``tests/test_pipeline.py``);
  * **cluster-based dedup** — near-identical spike behavior collapses via
    single-linkage clustering on the cosine distance matrix
    (``core/clustering.py``), keeping the first profile of each cluster.

``reference_store.save_profiles``/``load_profiles`` remain as a deprecation
shim over this class.
"""
from __future__ import annotations

import hashlib
import json
import os
import warnings
import zipfile

import numpy as np

from repro.core.classify import FreqPoint, MinosClassifier, WorkloadProfile
from repro.core.clustering import cosine_distance_matrix, cut, linkage
from repro.pipeline.builder import DEFAULT_BIN_SIZES

_LIBRARY_META = "library.json"
_SPIKE_CACHE = "spike_cache.npz"
_PROFILES = "profiles.json"
_TRACES = "traces.npz"


def _profile_digest(p: WorkloadProfile) -> str:
    h = hashlib.sha256()
    h.update(p.name.encode())
    h.update(np.float64(p.tdp).tobytes())
    h.update(np.ascontiguousarray(p.power_trace, np.float64).tobytes())
    return h.hexdigest()


class ReferenceLibrary:
    """Ordered, versioned collection of reference ``WorkloadProfile``s."""

    def __init__(self, profiles=(), bin_sizes=DEFAULT_BIN_SIZES,
                 built_on: str = ""):
        self.bin_sizes = tuple(float(c) for c in bin_sizes)
        # provenance: the chip model the reference traces were captured on.
        # Profiles are stored relative to that device's TDP, so one library
        # serves a heterogeneous fleet through device-frame normalization
        # (see repro.fleet.inventory).
        self.built_on = built_on
        self._profiles: list[WorkloadProfile] = []
        self._spike: dict[float, np.ndarray] = {}
        self.version = 0
        for p in profiles:
            self.add(p)

    # -- membership -----------------------------------------------------
    def __len__(self) -> int:
        return len(self._profiles)

    def __iter__(self):
        return iter(self._profiles)

    def __contains__(self, name: str) -> bool:
        return any(p.name == name for p in self._profiles)

    @property
    def profiles(self) -> list[WorkloadProfile]:
        return list(self._profiles)

    @property
    def names(self) -> list[str]:
        return [p.name for p in self._profiles]

    def get(self, name: str) -> WorkloadProfile:
        for p in self._profiles:
            if p.name == name:
                return p
        raise KeyError(name)

    def add(self, profile: WorkloadProfile) -> None:
        """Append a reference; spike matrices grow by one row (no rebuild)."""
        if profile.name in self:
            raise ValueError(f"duplicate reference name {profile.name!r} "
                             f"(remove it first to replace)")
        self._profiles.append(profile)
        for c in list(self._spike):
            row = profile.spike_vec(c)[None, :]
            self._spike[c] = np.concatenate([self._spike[c], row])
        self.version += 1

    def remove(self, name: str) -> WorkloadProfile:
        """Drop a reference by name; spike matrices lose its row."""
        for i, p in enumerate(self._profiles):
            if p.name == name:
                del self._profiles[i]
                for c in list(self._spike):
                    self._spike[c] = np.delete(self._spike[c], i, axis=0)
                self.version += 1
                return p
        raise KeyError(name)

    def subset(self, keep) -> "ReferenceLibrary":
        """New library with the profiles for which ``keep(profile)`` holds;
        cached spike-matrix rows are carried over (no re-histogramming)."""
        mask = np.array([bool(keep(p)) for p in self._profiles])
        out = ReferenceLibrary(bin_sizes=self.bin_sizes,
                               built_on=self.built_on)
        out._profiles = [p for p, m in zip(self._profiles, mask) if m]
        out._spike = {c: M[mask] for c, M in self._spike.items()}
        out.version = 1
        return out

    # -- features & classification --------------------------------------
    def spike_matrix(self, bin_size: float) -> np.ndarray:
        """(n_refs, n_bins) spike matrix, maintained incrementally."""
        c = float(bin_size)
        M = self._spike.get(c)
        if M is None:
            M = np.stack([p.spike_vec(c) for p in self._profiles])
            self._spike[c] = M
        return M

    def warm_spike_cache(self) -> dict[float, np.ndarray]:
        """All tracked matrices (computing any missing) — the classifier's
        warm-start seed."""
        return {c: self.spike_matrix(c) for c in self.bin_sizes}

    def classifier(self, bin_size: float = 0.1) -> MinosClassifier:
        """A ``MinosClassifier`` over the current membership, warm-started
        from the library's spike matrices."""
        if not self._profiles:
            raise ValueError("empty reference library")
        return MinosClassifier(self._profiles, bin_size=bin_size,
                               spike_cache=self.warm_spike_cache())

    def fingerprint(self) -> str:
        """Order-sensitive content hash of the membership (names + tdp +
        float64 trace bytes) — the spike-cache validity key."""
        h = hashlib.sha256()
        for p in self._profiles:
            h.update(_profile_digest(p).encode())
        return h.hexdigest()

    # -- dedup ----------------------------------------------------------
    def dedup(self, max_distance: float = 1e-9,
              bin_size: float = 0.1) -> list[str]:
        """Collapse references whose spike vectors cluster within
        ``max_distance`` cosine distance (single linkage), keeping the first
        profile of each cluster.  Returns the removed names."""
        if len(self._profiles) < 2:
            return []
        D = cosine_distance_matrix(self.spike_matrix(bin_size))
        labels = cut(linkage(D, method="single"), max_distance)
        keep_idx = {}
        removed = []
        for i, lab in enumerate(labels):
            if lab in keep_idx:
                removed.append(self._profiles[i].name)
            else:
                keep_idx[lab] = i
        for name in removed:
            self.remove(name)
        return removed

    # -- persistence ----------------------------------------------------
    def save(self, directory: str) -> None:
        """Write profiles + scaling data + the fingerprinted spike-matrix
        cache.  Traces are stored float64 so a reload is bit-exact (the
        warm-start byte-identity guarantee depends on it)."""
        os.makedirs(directory, exist_ok=True)
        meta, arrays = {}, {}
        for i, p in enumerate(self._profiles):
            key = f"trace_{i}"
            arrays[key] = np.asarray(p.power_trace, np.float64)
            meta[p.name] = {
                "trace_key": key,
                "tdp": p.tdp,
                "sm_util": p.sm_util,
                "dram_util": p.dram_util,
                "exec_time": p.exec_time,
                "domain": p.domain,
                "scaling": {
                    repr(float(f)): {
                        "freq": fp.freq, "p90": fp.p90, "p95": fp.p95,
                        "p99": fp.p99, "mean_power": fp.mean_power,
                        "exec_time": fp.exec_time,
                    }
                    for f, fp in p.scaling.items()
                },
            }
        np.savez_compressed(os.path.join(directory, _TRACES), **arrays)
        with open(os.path.join(directory, _PROFILES), "w") as f:
            json.dump(meta, f, indent=1)
        cache = {f"c_{c!r}": M for c, M in self.warm_spike_cache().items()}
        np.savez_compressed(os.path.join(directory, _SPIKE_CACHE), **cache)
        with open(os.path.join(directory, _LIBRARY_META), "w") as f:
            json.dump({"version": self.version,
                       "fingerprint": self.fingerprint(),
                       "bin_sizes": list(self.bin_sizes),
                       "built_on": self.built_on}, f, indent=1)

    @classmethod
    def load(cls, directory: str) -> "ReferenceLibrary":
        """Load a saved library; when the on-disk spike cache's fingerprint
        matches the loaded membership, the matrices are adopted verbatim
        (warm start) instead of recomputed."""
        with open(os.path.join(directory, _PROFILES)) as f:
            meta = json.load(f)
        data = np.load(os.path.join(directory, _TRACES))
        lib = cls(bin_sizes=())
        for name, m in meta.items():
            scaling = {float(f): FreqPoint(**fp)
                       for f, fp in m["scaling"].items()}
            lib._profiles.append(WorkloadProfile(
                name=name,
                tdp=m["tdp"],
                power_trace=np.asarray(data[m["trace_key"]], np.float64),
                sm_util=m["sm_util"],
                dram_util=m["dram_util"],
                exec_time=m["exec_time"],
                scaling=scaling,
                domain=m.get("domain", ""),
            ))
        lib.version = 1
        lib.bin_sizes = tuple(DEFAULT_BIN_SIZES)
        lib_meta_path = os.path.join(directory, _LIBRARY_META)
        cache_path = os.path.join(directory, _SPIKE_CACHE)
        if os.path.exists(lib_meta_path) and os.path.exists(cache_path):
            # the warm-start cache is an optimization, never a dependency: a
            # corrupt/truncated library.json or spike_cache.npz degrades to
            # the cold matrix rebuild (bit-identical results, just slower)
            # instead of failing the load
            try:
                with open(lib_meta_path) as f:
                    lm = json.load(f)
                lib.version = int(lm.get("version", 1))
                lib.bin_sizes = tuple(float(c) for c in lm.get(
                    "bin_sizes", DEFAULT_BIN_SIZES))
                lib.built_on = lm.get("built_on", "")
                if lm.get("fingerprint") == lib.fingerprint():
                    with np.load(cache_path) as cache:
                        spike = {float(k[2:]): np.asarray(cache[k],
                                                          np.float64)
                                 for k in cache.files}
                    lib._spike = spike
            except (OSError, EOFError, ValueError, KeyError,
                    zipfile.BadZipFile) as e:
                warnings.warn(
                    f"spike cache under {directory!r} is corrupt or "
                    f"truncated ({type(e).__name__}: {e}); falling back to "
                    f"a cold spike-matrix rebuild", RuntimeWarning)
        return lib

    @classmethod
    def load_or_build(cls, directory: str, build) -> "ReferenceLibrary":
        """Load from ``directory`` if present, else call ``build()`` for the
        profile list, save, and return the library."""
        if os.path.exists(os.path.join(directory, _PROFILES)):
            return cls.load(directory)
        lib = cls(build())
        lib.save(directory)
        return lib


def build_reference_library(model=None, freqs=None, seed: int = 0,
                            target_duration: float = 4.0,
                            chunk_samples: int = 256) -> ReferenceLibrary:
    """Build the shipped reference zoo through the streaming pipeline (one
    ``ProfileBuilder`` per workload x frequency) into a ``ReferenceLibrary``."""
    from repro.analysis.hardware import FREQ_SWEEP
    from repro.pipeline.builder import stream_profile_workload
    from repro.telemetry.power_model import TPUPowerModel
    from repro.telemetry.workloads import reference_streams

    model = model or TPUPowerModel()
    freqs = FREQ_SWEEP if freqs is None else freqs
    tdp = model.spec.tdp_w
    return ReferenceLibrary(
        (stream_profile_workload(s, model, freqs, tdp, seed=seed + i,
                                 target_duration=target_duration,
                                 chunk_samples=chunk_samples)
         for i, s in enumerate(reference_streams())),
        built_on=model.spec.name)
