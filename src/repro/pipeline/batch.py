"""Batched multi-job profiling: one columnar pass over the fleet's telemetry.

``BatchProfileEngine`` holds the state of *many* concurrent ``ProfileBuilder``
runs as slot-indexed columnar arrays — energy/busy prefix counters, blocked-EMA
carry state, per-bin-size spike histograms stacked ``(capacity, n_bins)``, and
idle-trim flags — so one stacked NumPy pass (diff → EMA prefix-doubling →
trim fold → ``np.add.at`` histogram scatter) advances every live job per mux
tick instead of looping Python per job.  Slots are allocated on admit and
freed on retire, so dynamic arrival/departure keeps working; freed slots are
recycled.

Bit-for-bit identity with the per-job ``ProfileBuilder`` (the reference
implementation) is a hard contract, pinned by a hypothesis property in
``tests/test_fleet.py``:

  * every elementwise stage (counter diff, ``p_raw = de/dt``, the blocked-EMA
    prefix-doubling, idle-trim slicing) evaluates the *same float expression
    per element* as the 1D path — NumPy elementwise ops on stacked rows are
    bitwise equal to the per-row ops;
  * rows are grouped per tick by ``(chunk_len, n_pending, has_ema_state)`` so
    stacked EMA blocks line up at identical absolute positions;
  * histogram counts are sums of 1.0s — exact integers in float64 — so the
    batched ``np.add.at`` scatter accumulates to bit-identical values
    regardless of ordering.

``SlotBuilder`` is the per-job view over one slot: it quacks exactly like a
``ProfileBuilder`` (``ingest``/``snapshot``/``finalize``/``spike_count``/
``fraction``/...), so ``OnlineCapController`` and the fleet controller drive
it unchanged.  On TPU backends the commit-time histogram scatter runs through
the batched Pallas kernel (``kernels.spike_hist.spike_hist_batch_pallas``);
elsewhere (and by default in tests/CI) it is pure NumPy.

Error semantics: the engine validates every chunk of a tick *before* mutating
any slot, so a poisoned chunk leaves the whole tick's builders untouched
(strictly stronger than the per-chunk path, which mutates earlier jobs in the
tick before raising) — the raised message is byte-identical to the per-job
``ProfileBuilder`` message for the first offending chunk in batch order.
"""
from __future__ import annotations

import numpy as np

from repro.core import spikes
from repro.pipeline.builder import (DEFAULT_BIN_SIZES, EMA_BLOCK,
                                    PartialProfile, _ema_filter_block,
                                    _fold_trim, _validate_readings)
from repro.telemetry.simulator import TelemetryChunk, TraceMeta

__all__ = ["BatchProfileEngine", "SlotBuilder"]


class SlotBuilder:
    """Per-job view over one ``BatchProfileEngine`` slot.

    Duck-types the ``ProfileBuilder`` surface (``meta``/``tdp``/``ingest``/
    ``snapshot``/``finalize``/``spike_vector``/``spike_count``/``fraction``/
    ``n_ingested``/``n_committed``/``bin_sizes``) so every consumer of a
    per-job builder — ``OnlineCapController.observe`` above all — works
    unchanged.  Created via ``BatchProfileEngine.builder``; ``release()``
    frees the slot for reuse (after which the view rejects every call).
    """

    __slots__ = ("engine", "slot", "meta", "_released")

    def __init__(self, engine: "BatchProfileEngine", slot: int,
                 meta: TraceMeta):
        self.engine = engine
        self.slot = slot
        self.meta = meta
        self._released = False

    def _check(self) -> int:
        if self._released:
            raise ValueError(
                f"slot builder for job {self.meta.name!r} was released")
        return self.slot

    @property
    def tdp(self) -> float:
        return float(self.engine._tdp[self._check()])

    @property
    def bin_sizes(self):
        return self.engine.bin_sizes

    @property
    def n_ingested(self) -> int:
        return int(self.engine._next_index[self._check()])

    @property
    def n_committed(self) -> int:
        return int(self.engine._n_committed[self._check()])

    @property
    def fraction(self) -> float:
        return self.n_ingested / max(self.meta.n_samples, 1)

    def ingest(self, chunk: TelemetryChunk) -> None:
        self.engine.ingest_batch((self._check(),), (chunk,))

    def spike_vector(self, bin_size: float) -> np.ndarray:
        return self.engine.spike_vector(self._check(), bin_size)

    def spike_count(self, bin_size: float | None = None) -> int:
        return self.engine.spike_count(self._check(), bin_size)

    def snapshot(self) -> PartialProfile:
        return self.engine.snapshot(self._check())

    def finalize(self) -> PartialProfile:
        return self.engine.finalize(self._check())

    def release(self) -> None:
        """Free the underlying slot for reuse (idempotent)."""
        if not self._released:
            self.engine.free(self.slot)
            self._released = True


class BatchProfileEngine:
    """Slot-indexed columnar state for many concurrent profiling runs."""

    def __init__(self, bin_sizes=DEFAULT_BIN_SIZES, alpha: float = 0.5,
                 ema_block: int = EMA_BLOCK, capacity: int = 64,
                 backend: str | None = None):
        """``backend`` selects the commit-time histogram scatter: ``"numpy"``
        (``np.add.at``), ``"pallas"`` (the batched TPU kernel), or ``None``
        to autodetect — compiled Pallas on TPU, NumPy elsewhere (the same
        convention as ``spikes.ema_filter``/``kernels.spike_hist``)."""
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.bin_sizes = tuple(float(c) for c in bin_sizes)
        if any(c <= 0 for c in self.bin_sizes):
            raise ValueError(f"bin sizes must be positive: {self.bin_sizes}")
        if backend not in (None, "numpy", "pallas"):
            raise ValueError(f"backend must be 'numpy', 'pallas', or None "
                             f"(autodetect), got {backend!r}")
        self.alpha = float(alpha)
        self.w = 1.0 - self.alpha
        self.block = int(ema_block)
        self._backend = backend
        cap = max(int(capacity), 1)
        # columnar scalar state (one row per slot)
        self._tdp = np.zeros(cap, np.float64)
        self._energy = np.zeros(cap, np.float64)
        self._busy = np.zeros(cap, np.float64)
        self._next_index = np.zeros(cap, np.int64)
        self._n_pending = np.zeros(cap, np.int64)
        self._ema_state = np.zeros(cap, np.float64)
        self._ema_has = np.zeros(cap, bool)
        self._seen_busy = np.zeros(cap, bool)
        self._n_committed = np.zeros(cap, np.int64)
        self._final = np.zeros(cap, bool)
        self._live = np.zeros(cap, bool)
        # stacked per-bin-size spike histograms: (capacity, n_bins)
        self._hist = {c: np.zeros((cap, spikes.num_bins(c)), np.float64)
                      for c in self.bin_sizes}
        # ragged per-slot state (sample runs of varying length)
        self._meta: list[TraceMeta | None] = [None] * cap
        self._pending: list[list[np.ndarray]] = [[] for _ in range(cap)]
        self._busyq: list[list[np.ndarray]] = [[] for _ in range(cap)]
        self._tail: list[list[np.ndarray]] = [[] for _ in range(cap)]
        self._committed: list[list[np.ndarray]] = [[] for _ in range(cap)]
        self._free: list[int] = list(range(cap - 1, -1, -1))

    # -- capacity --------------------------------------------------------
    @property
    def capacity(self) -> int:
        return len(self._live)

    @property
    def n_live(self) -> int:
        return int(self._live.sum())

    def _grow(self) -> None:
        # quadruple: growth is a stop-the-world copy of every column, and a
        # slot row is tiny (~576 B of histogram), so fewer bigger steps beat
        # doubling on the fleet admission path
        old = self.capacity
        new = old * 4
        add = new - old
        for name in ("_tdp", "_energy", "_busy", "_ema_state"):
            setattr(self, name, np.concatenate(
                [getattr(self, name), np.zeros(add, np.float64)]))
        for name in ("_next_index", "_n_pending", "_n_committed"):
            setattr(self, name, np.concatenate(
                [getattr(self, name), np.zeros(add, np.int64)]))
        for name in ("_ema_has", "_seen_busy", "_final", "_live"):
            setattr(self, name, np.concatenate(
                [getattr(self, name), np.zeros(add, bool)]))
        for c, h in self._hist.items():
            self._hist[c] = np.vstack(
                [h, np.zeros((add, h.shape[1]), np.float64)])
        self._meta.extend([None] * add)
        for lst in (self._pending, self._busyq, self._tail, self._committed):
            lst.extend([] for _ in range(add))
        self._free.extend(range(new - 1, old - 1, -1))

    # -- slot lifecycle --------------------------------------------------
    def alloc(self, meta: TraceMeta, tdp: float) -> int:
        """Claim a slot for one profiling run; returns its index."""
        if not self._free:
            self._grow()
        s = self._free.pop()
        self._tdp[s] = float(tdp)
        self._energy[s] = 0.0
        self._busy[s] = 0.0
        self._next_index[s] = 0
        self._n_pending[s] = 0
        self._ema_state[s] = 0.0
        self._ema_has[s] = False
        self._seen_busy[s] = False
        self._n_committed[s] = 0
        self._final[s] = False
        self._live[s] = True
        # histogram rows are already zero: ``_grow`` allocates zeros and
        # ``free`` scrubs a slot's rows on release, keeping the (hot) admit
        # path free of the six per-bin-size clears
        self._meta[s] = meta
        self._pending[s] = []
        self._busyq[s] = []
        self._tail[s] = []
        self._committed[s] = []
        return s

    def builder(self, meta: TraceMeta, tdp: float) -> SlotBuilder:
        """Allocate a slot and return its ``ProfileBuilder``-shaped view."""
        return SlotBuilder(self, self.alloc(meta, tdp), meta)

    def free(self, slot: int) -> None:
        """Release a slot (idempotent); its state is recycled on next alloc."""
        if self._live[slot]:
            self._live[slot] = False
            self._meta[slot] = None
            # scrub the histogram rows now so alloc() can skip the clears
            # (free-list invariant: every parked slot's rows are zero)
            for c in self.bin_sizes:
                self._hist[c][slot, :] = 0.0
            # drop the ragged trace state now — the slot may idle on the
            # free list for a while
            self._pending[slot] = []
            self._busyq[slot] = []
            self._tail[slot] = []
            self._committed[slot] = []
            self._free.append(slot)

    def _check_live(self, slot: int) -> None:
        if not self._live[slot]:
            raise ValueError(f"slot {slot} is not allocated")

    # -- ingestion -------------------------------------------------------
    def ingest_batch(self, slots, chunks) -> None:
        """Advance many slots by one chunk each — the per-tick columnar pass.

        ``slots``/``chunks`` are parallel sequences; each slot may appear at
        most once (the mux emits at most one chunk per job per tick).  The
        whole batch is validated before any slot mutates, and the raised
        error for bad telemetry matches the per-job ``ProfileBuilder``
        message for the first offending chunk in batch order.
        """
        slots = list(slots)
        chunks = list(chunks)
        if len(slots) != len(chunks):
            raise ValueError("slots and chunks differ in length")
        if len(set(slots)) != len(slots):
            raise ValueError("duplicate slot in one ingest_batch tick")
        # phase 1: per-row scalar checks (finalized / contiguity / shape),
        # mirroring ProfileBuilder.ingest's check order and messages
        rows = []            # (batch_pos, slot, chunk, er, br)
        for pos, (s, chunk) in enumerate(zip(slots, chunks)):
            self._check_live(s)
            if self._final[s]:
                raise ValueError("ProfileBuilder already finalized")
            if chunk.start_index != self._next_index[s]:
                raise ValueError(
                    f"chunk starts at sample {chunk.start_index}, expected "
                    f"{self._next_index[s]} (chunks must be contiguous and "
                    f"ordered)")
            er = np.asarray(chunk.energy_j, np.float64)
            br = np.asarray(chunk.busy_s, np.float64)
            if er.shape != br.shape:
                raise ValueError("energy_j and busy_s readings differ in "
                                 "length")
            if len(er) == 0:
                continue                    # empty chunk: a no-op
            rows.append((pos, s, chunk, er, br))
        if not rows:
            return
        # phase 2: group rows so stacked 2D passes line up — equal chunk
        # length for the counter diff, equal pending count + state presence
        # for fixed-position EMA blocks
        groups: dict[tuple, list] = {}
        for row in rows:
            _, s, chunk, er, _ = row
            key = (len(er), int(self._n_pending[s]), bool(self._ema_has[s]))
            groups.setdefault(key, []).append(row)
        # phase 3: validate every group before any state mutates (the
        # all-or-nothing tick contract)
        bad_pos = None
        for (length, _, _), grp in groups.items():
            idx = np.fromiter((r[1] for r in grp), np.int64, len(grp))
            er2 = np.stack([r[3] for r in grp])
            br2 = np.stack([r[4] for r in grp])
            dt = np.fromiter((r[2].sample_dt for r in grp), np.float64,
                             len(grp))
            d_e = np.diff(er2, axis=1)
            d_b = np.diff(br2, axis=1)
            ok = (np.isfinite(dt) & (dt > 0)
                  & np.isfinite(er2).all(axis=1) & np.isfinite(br2).all(axis=1)
                  & (er2[:, 0] >= self._energy[idx])
                  & (d_e >= 0).all(axis=1)
                  & (br2[:, 0] >= self._busy[idx])
                  & (d_b >= 0).all(axis=1))
            for j in np.nonzero(~ok)[0]:
                pos = grp[j][0]
                if bad_pos is None or pos < bad_pos[0]:
                    bad_pos = (pos, grp[j])
            grp.append((idx, er2, br2, dt, d_e, d_b))  # stash stacked arrays
        if bad_pos is not None:
            _, (_, s, chunk, er, br) = bad_pos
            _validate_readings(self._meta[s], float(self._energy[s]),
                               float(self._busy[s]), chunk.start_index,
                               chunk.sample_dt, er, br)
            raise AssertionError("vectorized validation flagged a chunk the "
                                 "reference validator accepts")  # unreachable
        # phase 4: mutate, one stacked pass per group
        for (length, pend, has_state), grp in groups.items():
            idx, er2, br2, dt, d_e, d_b = grp.pop()
            self._advance_group(idx, er2, br2, dt, d_e, d_b, length, pend,
                                has_state)

    def _advance_group(self, idx: np.ndarray, er2: np.ndarray,
                       br2: np.ndarray, dt: np.ndarray, d_e: np.ndarray,
                       d_b: np.ndarray, length: int,
                       pend: int, has_state: bool) -> None:
        """One stacked columnar advance for rows sharing (chunk length,
        pending count, EMA-state presence).  ``d_e``/``d_b`` are the
        validator's intra-chunk counter diffs, reused here: prepending the
        prefix-state column gives the identical elementwise subtractions as
        ``np.diff(concat([[prev], er]))``."""
        k = len(idx)
        de = np.concatenate([er2[:, :1] - self._energy[idx, None], d_e],
                            axis=1)
        db = np.concatenate([br2[:, :1] - self._busy[idx, None], d_b],
                            axis=1)
        self._energy[idx] = er2[:, -1]
        self._busy[idx] = br2[:, -1]
        self._next_index[idx] += length
        p_raw = de / dt[:, None]
        busy = (db > 0).astype(np.float64)

        total = pend + length
        nblocks = total // self.block
        if nblocks == 0:
            # nothing commits this tick: everything stays pending
            for j, s in enumerate(idx.tolist()):
                self._pending[s].append(p_raw[j].copy())
                self._busyq[s].append(busy[j].copy())
            self._n_pending[idx] = total
            return
        # stack the pending buffers (equal length across the group) and the
        # new samples into (k, total); commit whole fixed-position blocks
        if pend:
            prev_p = np.stack([np.concatenate(self._pending[s])
                               if len(self._pending[s]) != 1
                               else self._pending[s][0] for s in idx])
            prev_b = np.stack([np.concatenate(self._busyq[s])
                               if len(self._busyq[s]) != 1
                               else self._busyq[s][0] for s in idx])
            buf = np.concatenate([prev_p, p_raw], axis=1)
            busy_buf = np.concatenate([prev_b, busy], axis=1)
        else:
            buf, busy_buf = p_raw, busy
        take = nblocks * self.block
        filt = np.empty((k, take), np.float64)
        state = self._ema_state[idx]
        for b in range(nblocks):
            blk = buf[:, b * self.block:(b + 1) * self.block]
            out = self.alpha * blk
            if has_state or b > 0:
                out[:, 0] += self.w * state
            else:
                out[:, 0] = blk[:, 0]       # batch seeding: out_0 = p_0
            shift, decay = 1, self.w
            while shift < out.shape[1] and decay != 0.0:
                out[:, shift:] += decay * out[:, :-shift]
                shift *= 2
                decay *= decay
            state = out[:, -1]
            filt[:, b * self.block:(b + 1) * self.block] = out
        self._ema_state[idx] = state
        self._ema_has[idx] = True
        rest_p = buf[:, take:]
        rest_b = busy_buf[:, take:]
        for j, s in enumerate(idx.tolist()):
            self._pending[s] = [rest_p[j].copy()] if rest_p.shape[1] else []
            self._busyq[s] = [rest_b[j].copy()] if rest_b.shape[1] else []
        self._n_pending[idx] = total - take
        self._fold_commit(idx, filt, busy_buf[:, :take])

    def _fold_commit(self, idx: np.ndarray, filt: np.ndarray,
                     busy: np.ndarray) -> None:
        """Columnar idle-trim fold + histogram commit over (k, F) filtered
        samples — the batched twin of ``_fold_trim`` + ``_commit``."""
        k, F = filt.shape
        busy_pos = busy > 0
        has_busy = busy_pos.any(axis=1)
        first = np.where(has_busy, np.argmax(busy_pos, axis=1), F)
        last = np.where(has_busy,
                        F - 1 - np.argmax(busy_pos[:, ::-1], axis=1), -1)
        seen = self._seen_busy[idx]
        start = np.where(seen, 0, first)
        commit_end = np.where(has_busy, last + 1, start)
        # pass 1: histogram contribution of the newly-committed spans
        cols = np.arange(F)
        commit_mask = (cols >= start[:, None]) & (cols < commit_end[:, None])
        r = filt / self._tdp[idx][:, None]
        self._scatter_hist(idx, r, commit_mask)
        # pass 2: old-tail pieces promoted by a fresh busy sample, plus the
        # ragged per-row trace bookkeeping (plain Python ints — NumPy scalar
        # indexing in this loop costs more than the work it guards)
        tail_vals: list[np.ndarray] = []
        tail_rows: list[np.ndarray] = []
        n_add = [0] * k
        hb_l, seen_l = has_busy.tolist(), seen.tolist()
        start_l, end_l = start.tolist(), commit_end.tolist()
        for j, s in enumerate(idx.tolist()):
            if hb_l[j]:
                if self._tail[s]:
                    for piece in self._tail[s]:
                        n_add[j] += len(piece)
                        tail_vals.append(piece / self._tdp[s])
                        tail_rows.append(np.full(len(piece), s, np.int64))
                    self._committed[s].extend(self._tail[s])
                    self._tail[s] = []
                span = filt[j, start_l[j]:end_l[j]]
                self._committed[s].append(span)
                n_add[j] += len(span)
                if end_l[j] < F:
                    self._tail[s] = [filt[j, end_l[j]:]]
            elif seen_l[j]:
                self._tail[s].append(filt[j])
            # rows with no busy yet: leading idle, dropped entirely
        if tail_vals:
            rr = np.concatenate(tail_vals)
            rows = np.concatenate(tail_rows)
            keep = rr >= spikes.SPIKE_LO
            rr, rows = rr[keep], rows[keep]
            if len(rr):
                for c in self.bin_sizes:
                    h = self._hist[c]
                    n = h.shape[1]
                    bidx = np.minimum(((rr - spikes.SPIKE_LO) / c)
                                      .astype(np.int64), n - 1)
                    np.add.at(h, (rows, bidx), 1.0)
        self._n_committed[idx] += n_add
        self._seen_busy[idx] = seen | has_busy

    def _scatter_hist(self, idx: np.ndarray, r: np.ndarray,
                      mask: np.ndarray) -> None:
        """Accumulate the masked (k, F) relative-power block into every
        tracked histogram.  Counts are exact float64 integers, so the
        scatter is bit-identical to per-piece ``np.bincount`` adds."""
        if self._resolve_backend() == "pallas":
            from repro.kernels.spike_hist import spike_hist_batch_pallas
            masked = np.where(mask, r, -np.inf)
            for c in self.bin_sizes:
                h = self._hist[c]
                counts = np.asarray(spike_hist_batch_pallas(
                    masked, h.shape[1], lo=spikes.SPIKE_LO, bin_width=c))
                h[idx] += counts.astype(np.float64)
            return
        spike = r >= spikes.SPIKE_LO
        np.logical_and(spike, mask, out=spike)
        ri, ci = np.nonzero(spike)
        if not len(ri):
            return
        vals = r[ri, ci]
        shifted = vals - spikes.SPIKE_LO     # shared first step of every bin
        k = len(idx)
        # scratch buffers shared across bin sizes: the per-bin pass is pure
        # elementwise work, so reusing the output arrays saves six rounds of
        # large allocations per tick without changing a single bit
        q = np.empty_like(shifted)
        bidx = np.empty(len(shifted), np.int64)
        flat = np.empty(len(shifted), np.int64)
        for c in self.bin_sizes:
            h = self._hist[c]
            n = h.shape[1]
            np.divide(shifted, c, out=q)
            np.copyto(bidx, q, casting="unsafe")  # C truncation == astype
            np.minimum(bidx, n - 1, out=bidx)     # quotients are >= 0
            np.multiply(ri, n, out=flat)
            flat += bidx
            # one flat bincount + dense row add: the same exact integer
            # counts as np.add.at, without its scattered read-modify-write
            counts = np.bincount(flat, minlength=k * n)
            h[idx] += counts.reshape(k, n)

    def _resolve_backend(self) -> str:
        if self._backend is None:
            try:
                import jax
                self._backend = "pallas" \
                    if jax.default_backend() == "tpu" else "numpy"
            except Exception:        # jax unavailable: stay pure NumPy
                self._backend = "numpy"
        return self._backend

    # -- incremental queries ---------------------------------------------
    def spike_vector(self, slot: int, bin_size: float) -> np.ndarray:
        self._check_live(slot)
        c = float(bin_size)
        if c not in self._hist:
            raise ValueError(f"bin size {bin_size} not tracked; "
                             f"tracked: {self.bin_sizes}")
        h = self._hist[c][slot]
        tot = h.sum()
        if tot == 0:
            return np.zeros(len(h))
        return h / tot

    def spike_count(self, slot: int, bin_size: float | None = None) -> int:
        self._check_live(slot)
        c = self.bin_sizes[0] if bin_size is None else float(bin_size)
        if c not in self._hist:
            raise ValueError(f"bin size {bin_size} not tracked; "
                             f"tracked: {self.bin_sizes}")
        return int(self._hist[c][slot].sum())

    def spike_count_batch(self, slots) -> np.ndarray:
        """Vector ``spike_count`` over many slots: one stacked row-sum.
        Histogram counts are exact float64 integers, so each row's sum
        equals the scalar call regardless of reduction order."""
        idx = np.asarray(list(slots), np.int64)
        if len(idx) and not self._live[idx].all():
            bad = int(idx[np.nonzero(~self._live[idx])[0][0]])
            raise ValueError(f"slot {bad} is not allocated")
        return self._hist[self.bin_sizes[0]][idx].sum(axis=1).astype(np.int64)

    # -- profile emission ------------------------------------------------
    def _profile(self, slot: int, trace: np.ndarray,
                 complete: bool) -> PartialProfile:
        m = self._meta[slot]
        n_ing = int(self._next_index[slot])
        return PartialProfile(
            name=m.name, tdp=float(self._tdp[slot]), power_trace=trace,
            sm_util=m.app_sm_util, dram_util=m.app_dram_util,
            exec_time=m.exec_time, scaling={}, domain=m.domain,
            fraction=n_ing / max(m.n_samples, 1), n_samples=n_ing,
            complete=complete)

    def _pending_view(self, slot: int) -> np.ndarray:
        if not self._n_pending[slot]:
            return np.empty(0, np.float64)
        state = float(self._ema_state[slot]) if self._ema_has[slot] else None
        return _ema_filter_block(np.concatenate(self._pending[slot]), state,
                                 self.alpha, self.w)

    def snapshot(self, slot: int) -> PartialProfile:
        """A valid partial profile over everything this slot ingested so
        far; pure — mirrors ``ProfileBuilder.snapshot`` bit-for-bit."""
        self._check_live(slot)
        filt = self._pending_view(slot)
        pieces = list(self._committed[slot])
        extras: list[np.ndarray] = []
        if len(filt):
            busy = np.concatenate(self._busyq[slot])[:len(filt)] \
                if self._busyq[slot] else np.zeros(len(filt))
            extras, _, _ = _fold_trim(filt, busy, bool(self._seen_busy[slot]),
                                      list(self._tail[slot]))
            pieces += extras
        trace = np.concatenate(pieces) if pieces else np.empty(0, np.float64)
        prof = self._profile(slot, trace, complete=False)
        self._prefill_spike_memo(prof, slot, extras)
        return prof

    def _memo_mats(self, idx: np.ndarray, rr: np.ndarray | None,
                   rows: np.ndarray | None) -> dict[float, np.ndarray]:
        """Stacked spike-memo prefill for the slots in ``idx``: per bin size
        one (k, n_bins) histogram slice, one flat bincount folding the rows'
        uncommitted extras (``rr``: relative spike samples, ``rows``: the
        local row each belongs to), one row-wise normalization.  Counts are
        exact float64 integers and the divide is elementwise, so every row
        matches the scalar ``_prefill_spike_memo`` bit-for-bit."""
        k = len(idx)
        mats: dict[float, np.ndarray] = {}
        shifted = None if rr is None else rr - spikes.SPIKE_LO
        for c in self.bin_sizes:
            H = self._hist[c][idx]               # fancy index: a fresh copy
            n = H.shape[1]
            if shifted is not None:
                bidx = np.minimum((shifted / c).astype(np.int64), n - 1)
                H += np.bincount(rows * n + bidx,
                                 minlength=k * n).reshape(k, n)
            tot = H.sum(axis=1)
            M = H / np.where(tot > 0.0, tot, 1.0)[:, None]
            M[tot == 0.0] = 0.0          # empty rows pin to exact zeros
            mats[c] = M
        return mats

    def snapshot_batch(self, slots) -> list[PartialProfile]:
        """``snapshot`` over many slots in one columnar pass.

        The ragged per-row work (the EMA view of mid-block pending samples,
        the idle-trim fold, the trace concat) stays per slot, but the memo
        prefill — the expensive part of ``snapshot`` — runs stacked through
        ``_memo_mats``.  Every returned profile is bit-identical to
        ``snapshot(slot)``; each also carries the shared memo matrix so the
        classifier's sweep can gather target rows without a Python stack."""
        idx = np.asarray(list(slots), np.int64)
        k = len(idx)
        if not k:
            return []
        live = self._live[idx]
        if not live.all():
            bad = int(idx[np.nonzero(~live)[0][0]])
            raise ValueError(f"slot {bad} is not allocated")
        npend = self._n_pending[idx].tolist()
        traces: list[np.ndarray] = []
        rr_parts: list[np.ndarray] = []
        row_parts: list[np.ndarray] = []
        empty = np.empty(0, np.float64)
        for j, s in enumerate(idx.tolist()):
            pieces = self._committed[s]
            if npend[j]:
                filt = self._pending_view(s)
                if len(filt):
                    busy = np.concatenate(self._busyq[s])[:len(filt)] \
                        if self._busyq[s] else np.zeros(len(filt))
                    extras, _, _ = _fold_trim(
                        filt, busy, bool(self._seen_busy[s]),
                        list(self._tail[s]))
                    if extras:
                        pieces = pieces + extras
                        r = np.concatenate(extras) if len(extras) > 1 \
                            else extras[0]
                        r = r / self._tdp[s]
                        r = r[r >= spikes.SPIKE_LO]
                        if len(r):
                            rr_parts.append(r)
                            row_parts.append(np.full(len(r), j, np.int64))
            if not pieces:
                traces.append(empty)
            elif len(pieces) == 1:
                traces.append(pieces[0])  # committed pieces are immutable
            else:
                traces.append(np.concatenate(pieces))
        rr = np.concatenate(rr_parts) if rr_parts else None
        rows = np.concatenate(row_parts) if rr_parts else None
        mats = self._memo_mats(idx, rr, rows)
        bins = self.bin_sizes
        out = []
        for j, s in enumerate(idx.tolist()):
            prof = self._profile(s, traces[j], complete=False)
            prof.__dict__["_spike_memo"] = {c: mats[c][j] for c in bins}
            prof.__dict__["_spike_mat"] = (mats, j)
            out.append(prof)
        return out

    def _prefill_spike_memo(self, prof: PartialProfile, slot: int,
                            extras: list[np.ndarray]) -> None:
        """Seed the profile's per-bin-size spike-vector memo from the slot's
        incremental histograms, so the classifier's bin-size sweep never
        re-histograms the trace.  Histogram counts are exact float64
        integers, so ``committed counts + extras counts`` equals the
        one-pass ``spikes.spike_vector`` bincount bit-for-bit, and the
        shared normalization divide produces the identical vector."""
        extra_r = None
        if extras:
            r = np.concatenate(extras) / self._tdp[slot]
            r = r[r >= spikes.SPIKE_LO]
            extra_r = r if len(r) else None
        memo: dict[float, np.ndarray] = {}
        for c in self.bin_sizes:
            h = self._hist[c][slot]
            n = len(h)
            if extra_r is not None:
                bidx = np.minimum(((extra_r - spikes.SPIKE_LO) / c)
                                  .astype(np.int64), n - 1)
                h = h + np.bincount(bidx, minlength=n).astype(np.float64)
            tot = h.sum()
            # h / tot allocates, so the memo never aliases the live columns
            memo[c] = np.zeros(n) if tot == 0 else h / tot
        prof.__dict__["_spike_memo"] = memo

    def _commit_row(self, slot: int, arr: np.ndarray) -> None:
        """Per-slot twin of ``ProfileBuilder._commit`` (finalize path)."""
        if not len(arr):
            return
        self._committed[slot].append(arr)
        self._n_committed[slot] += len(arr)
        r = arr / self._tdp[slot]
        r = r[r >= spikes.SPIKE_LO]
        if len(r):
            for c in self.bin_sizes:
                h = self._hist[c]
                n = h.shape[1]
                bidx = np.minimum(((r - spikes.SPIKE_LO) / c)
                                  .astype(np.int64), n - 1)
                h[slot] += np.bincount(bidx, minlength=n).astype(np.float64)

    def _flush(self, slot: int) -> None:
        """Commit the slot's pending EMA tail and seal it (idempotent)."""
        if self._final[slot]:
            return
        filt = self._pending_view(slot)
        if len(filt):
            self._ema_state[slot] = float(filt[-1])
            self._ema_has[slot] = True
            busy = np.concatenate(self._busyq[slot])[:len(filt)]
            commits, seen, tail = _fold_trim(
                filt, busy, bool(self._seen_busy[slot]),
                list(self._tail[slot]))
            self._seen_busy[slot] = seen
            self._tail[slot] = tail
            for arr in commits:
                self._commit_row(slot, arr)
        self._pending[slot] = []
        self._n_pending[slot] = 0
        self._busyq[slot] = []
        self._final[slot] = True

    def finalize(self, slot: int) -> PartialProfile:
        """Flush the slot's EMA tail and emit its completed profile."""
        self._check_live(slot)
        self._flush(slot)
        trace = np.concatenate(self._committed[slot]) \
            if self._committed[slot] else np.empty(0, np.float64)
        prof = self._profile(slot, trace, complete=True)
        # after the flush the histograms cover the whole committed trace
        self._prefill_spike_memo(prof, slot, [])
        return prof

    def finalize_batch(self, slots) -> list[PartialProfile]:
        """``finalize`` over many slots: the ragged EMA-tail flush stays per
        slot, the memo prefill and profile assembly batch like
        ``snapshot_batch``.  Bit-identical to per-slot ``finalize``."""
        idx = np.asarray(list(slots), np.int64)
        k = len(idx)
        if not k:
            return []
        if len(set(idx.tolist())) != k:
            # a repeated slot would collide in the fancy-index scatter
            # below; the scalar path is idempotent, so take it verbatim
            return [self.finalize(s) for s in idx.tolist()]
        traces: list[np.ndarray] = []
        rr_parts: list[np.ndarray] = []
        row_parts: list[np.ndarray] = []
        empty = np.empty(0, np.float64)
        for j, s in enumerate(idx.tolist()):
            self._check_live(s)
            if not self._final[s]:
                # inline ``_flush``, deferring the histogram commit: the
                # per-piece bincounts it would do sum to the one flat
                # scatter below (counts are exact float64 integers)
                filt = self._pending_view(s)
                if len(filt):
                    self._ema_state[s] = float(filt[-1])
                    self._ema_has[s] = True
                    busy = np.concatenate(self._busyq[s])[:len(filt)]
                    commits, seen, tail = _fold_trim(
                        filt, busy, bool(self._seen_busy[s]),
                        list(self._tail[s]))
                    self._seen_busy[s] = seen
                    self._tail[s] = tail
                    commits = [a for a in commits if len(a)]
                    if commits:
                        self._committed[s].extend(commits)
                        self._n_committed[s] += sum(len(a) for a in commits)
                        r = np.concatenate(commits) if len(commits) > 1 \
                            else commits[0]
                        r = r / self._tdp[s]
                        r = r[r >= spikes.SPIKE_LO]
                        if len(r):
                            rr_parts.append(r)
                            row_parts.append(np.full(len(r), j, np.int64))
                self._pending[s] = []
                self._n_pending[s] = 0
                self._busyq[s] = []
                self._final[s] = True
            pieces = self._committed[s]
            if not pieces:
                traces.append(empty)
            elif len(pieces) == 1:
                traces.append(pieces[0])  # committed pieces are immutable
            else:
                traces.append(np.concatenate(pieces))
        if rr_parts:
            rr = np.concatenate(rr_parts)
            rows = np.concatenate(row_parts)
            shifted = rr - spikes.SPIKE_LO
            for c in self.bin_sizes:
                h = self._hist[c]
                n = h.shape[1]
                bidx = np.minimum((shifted / c).astype(np.int64), n - 1)
                h[idx] += np.bincount(rows * n + bidx,
                                      minlength=k * n).reshape(k, n)
        # post-flush the histograms cover each whole committed trace
        mats = self._memo_mats(idx, None, None)
        bins = self.bin_sizes
        out = []
        for j, s in enumerate(idx.tolist()):
            prof = self._profile(s, traces[j], complete=True)
            prof.__dict__["_spike_memo"] = {c: mats[c][j] for c in bins}
            prof.__dict__["_spike_mat"] = (mats, j)
            out.append(prof)
        return out
