"""Incremental profile construction from streamed telemetry chunks.

``ProfileBuilder`` is the streaming half of the Minos profiling pipeline: it
ingests ``TelemetryChunk``s (cumulative energy/busy counter readings, the
exact thing a telemetry daemon polls) and maintains, incrementally,

  * the running energy/busy **prefix state** — the last counter readings,
    differentiated against each new chunk to recover per-sample power and
    busy flags;
  * the **EMA filter tail** — filtered samples are produced through
    fixed-position blocks (prefix-doubling within a block, carried filter
    state between blocks), so the output is *bit-for-bit independent of how
    the stream was chunked*;
  * the **idle-trim frontier** — samples before the first busy reading are
    dropped, samples after the last busy reading so far are held in a
    pending tail and only committed when a later busy sample arrives
    (matching the batch ``trim_idle`` head/tail semantics on every prefix);
  * **per-bin-size spike histograms** over the committed samples, so partial
    spike vectors are O(bins) queries instead of trace rescans.

``snapshot()`` emits a valid partial ``WorkloadProfile`` at any point;
``finalize()`` flushes everything and emits the completed profile.  A
full-trace build matches the batch ``profile_workload``/``simulate`` path at
1e-9 (golden tests in ``tests/test_pipeline.py``), and any chunking of the
same stream produces bit-identical spike vectors (hypothesis property test).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import spikes
from repro.core.classify import FreqPoint, WorkloadProfile
from repro.telemetry.simulator import TelemetryChunk, TraceMeta

DEFAULT_BIN_SIZES = (0.05, 0.1, 0.15, 0.2, 0.25, 0.5)
EMA_BLOCK = 256


@dataclass
class PartialProfile(WorkloadProfile):
    """A ``WorkloadProfile`` emitted mid-stream, annotated with progress."""
    fraction: float = 1.0        # fraction of the expected trace ingested
    n_samples: int = 0           # raw samples ingested so far
    complete: bool = False       # True only for finalize() output

    def spike_vec(self, bin_size: float) -> np.ndarray:
        # the online path hits the same snapshot at the same bin size several
        # times (choose_bin_size sweep -> final neighbor -> margin query);
        # the trace is immutable once emitted, so memoize per bin size
        cache = self.__dict__.setdefault("_spike_memo", {})
        c = float(bin_size)
        if c not in cache:
            cache[c] = super().spike_vec(c)
        return cache[c]


def _ema_filter_block(p: np.ndarray, state: float | None, alpha: float,
                      w: float) -> np.ndarray:
    """One fixed-position EMA block via prefix doubling; ``state`` is the
    carried filter value from the previous block (``None`` = trace start).
    Shared by ``_BlockedEMA`` and the columnar ``BatchProfileEngine`` so the
    two paths evaluate the exact same float expressions."""
    out = alpha * np.asarray(p, np.float64)
    if state is None:
        out[0] = p[0]                      # batch seeding: out_0 = p_0
    else:
        out[0] += w * state
    shift, decay = 1, w
    while shift < len(out) and decay != 0.0:
        out[shift:] += decay * out[:-shift]
        shift *= 2
        decay *= decay
    return out


def _validate_readings(meta: TraceMeta, prev_e: float, prev_b: float,
                       start_index: int, sample_dt: float,
                       er: np.ndarray, br: np.ndarray) -> None:
    """Reject poisoned telemetry (NaN/non-finite/regressing counters,
    non-positive sample_dt) with the job/device context.  Shared by the
    per-job ``ProfileBuilder`` and the batched engine so both raise the
    byte-identical message for the same chunk."""
    where = f"job {meta.name!r}"
    if meta.device_id:
        where += f" on device {meta.device_id!r}"
    if not np.isfinite(sample_dt) or sample_dt <= 0:
        raise ValueError(
            f"{where}: chunk at sample {start_index} has "
            f"non-positive/non-finite sample_dt {sample_dt!r} (sample "
            f"timestamps must advance monotonically)")
    for label, readings, prev in (("energy_j", er, prev_e),
                                  ("busy_s", br, prev_b)):
        if not np.all(np.isfinite(readings)):
            raise ValueError(
                f"{where}: chunk at sample {start_index} has "
                f"NaN/non-finite {label} counter readings")
        if readings[0] < prev or np.any(np.diff(readings) < 0):
            raise ValueError(
                f"{where}: {label} counter goes backwards in the chunk "
                f"at sample {start_index} (cumulative counters "
                f"must be non-negative and non-decreasing)")


class _BlockedEMA:
    """EMA filter whose output does not depend on ingest chunk boundaries.

    The recurrence out_i = alpha*p_i + (1-alpha)*out_{i-1} is evaluated with
    the same prefix-doubling trick as ``spikes.ema_filter``, but over blocks
    at *fixed absolute positions* (multiples of ``block`` from trace start),
    seeding each block with the carried filter state: c_0 absorbs
    w * out_{-1}.  Because block boundaries are a function of the sample
    index alone, any chunking of the same sample sequence produces
    bit-identical filtered values.
    """

    def __init__(self, alpha: float = 0.5, block: int = EMA_BLOCK):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self.w = 1.0 - alpha
        self.block = int(block)
        self._pending: list[np.ndarray] = []
        self._n_pending = 0
        self._state: float | None = None   # None until the first sample

    def _filter_block(self, p: np.ndarray, state: float | None) -> np.ndarray:
        return _ema_filter_block(p, state, self.alpha, self.w)

    def ingest(self, p: np.ndarray) -> np.ndarray:
        """Absorb raw samples; return the newly *committed* filtered samples
        (complete blocks only — the partial tail stays pending)."""
        p = np.asarray(p, np.float64)
        if len(p):
            self._pending.append(p)
            self._n_pending += len(p)
        if self._n_pending < self.block:
            return np.empty(0, np.float64)
        # one concatenation, then fixed-position block slices (linear in the
        # buffered samples no matter how large the incoming chunk is)
        buf = np.concatenate(self._pending)
        done: list[np.ndarray] = []
        i = 0
        while len(buf) - i >= self.block:
            filt = self._filter_block(buf[i:i + self.block], self._state)
            self._state = float(filt[-1])
            done.append(filt)
            i += self.block
        rest = buf[i:]
        self._pending = [rest] if len(rest) else []
        self._n_pending = len(rest)
        return np.concatenate(done)

    def pending_view(self) -> np.ndarray:
        """Filtered values for the pending partial block, without committing
        filter state (safe to call repeatedly)."""
        if not self._n_pending:
            return np.empty(0, np.float64)
        return self._filter_block(np.concatenate(self._pending), self._state)

    def flush(self) -> np.ndarray:
        """Commit the pending partial block (end of stream)."""
        out = self.pending_view()
        if len(out):
            self._state = float(out[-1])
        self._pending, self._n_pending = [], 0
        return out


def _fold_trim(filt: np.ndarray, busy: np.ndarray, seen_busy: bool,
               tail: list[np.ndarray]):
    """Advance the idle-trim frontier over one span of filtered samples.

    Returns ``(commits, seen_busy, tail)``: arrays whose membership in the
    trimmed trace is now decided, the updated head flag, and the new pending
    tail (samples after the last busy reading so far).  Mirrors the batch
    ``trim_idle`` — keep [first-busy, last-busy] — on every stream prefix.
    """
    commits: list[np.ndarray] = []
    nz = np.nonzero(busy > 0)[0]
    if not seen_busy:
        if len(nz) == 0:
            return commits, False, tail            # still leading idle: drop
        filt = filt[nz[0]:]
        nz = nz - nz[0]
        seen_busy = True
    if len(nz) == 0:
        if len(filt):
            tail = tail + [filt]
        return commits, seen_busy, tail
    last = int(nz[-1])
    commits = tail + [filt[:last + 1]]
    tail = [filt[last + 1:]] if last + 1 < len(filt) else []
    return commits, seen_busy, tail


class ProfileBuilder:
    """Incrementally build a ``WorkloadProfile`` from telemetry chunks."""

    def __init__(self, meta: TraceMeta, tdp: float,
                 bin_sizes=DEFAULT_BIN_SIZES, alpha: float = 0.5,
                 ema_block: int = EMA_BLOCK):
        self.meta = meta
        self.tdp = float(tdp)
        self.bin_sizes = tuple(float(c) for c in bin_sizes)
        if any(c <= 0 for c in self.bin_sizes):
            raise ValueError(f"bin sizes must be positive: {self.bin_sizes}")
        self._ema = _BlockedEMA(alpha=alpha, block=ema_block)
        # running prefix state: last counter readings + expected next index
        self._energy_j = 0.0
        self._busy_s = 0.0
        self._next_index = 0
        # busy flags for samples still pending inside the EMA
        self._busy_queue: list[np.ndarray] = []
        # idle-trim state + committed stats
        self._seen_busy = False
        self._tail: list[np.ndarray] = []
        self._committed: list[np.ndarray] = []
        self._n_committed = 0
        self._hist = {c: np.zeros(spikes.num_bins(c), np.float64)
                      for c in self.bin_sizes}
        self._finalized = False

    # -- ingestion ------------------------------------------------------
    def ingest(self, chunk: TelemetryChunk) -> None:
        """Absorb one chunk of counter readings (must arrive in order)."""
        if self._finalized:
            raise ValueError("ProfileBuilder already finalized")
        if chunk.start_index != self._next_index:
            raise ValueError(
                f"chunk starts at sample {chunk.start_index}, expected "
                f"{self._next_index} (chunks must be contiguous and ordered)")
        er = np.asarray(chunk.energy_j, np.float64)
        br = np.asarray(chunk.busy_s, np.float64)
        if er.shape != br.shape:
            raise ValueError("energy_j and busy_s readings differ in length")
        if len(er) == 0:
            return
        self._validate_chunk(chunk, er, br)
        # differentiate the counters against the running prefix state
        de = np.diff(np.concatenate([[self._energy_j], er]))
        db = np.diff(np.concatenate([[self._busy_s], br]))
        self._energy_j = float(er[-1])
        self._busy_s = float(br[-1])
        self._next_index += len(er)
        p_raw = de / chunk.sample_dt
        busy = (db > 0).astype(np.float64)

        self._busy_queue.append(busy)
        filt = self._ema.ingest(p_raw)
        if len(filt):
            self._absorb(filt, self._take_busy(len(filt)))

    def _validate_chunk(self, chunk: TelemetryChunk, er: np.ndarray,
                        br: np.ndarray) -> None:
        """Reject poisoned telemetry before any state mutates: NaN/negative
        counters and regressing readings raise here, with the job/device
        context, and the builder — hence every later snapshot and spike
        histogram — is left exactly as it was."""
        _validate_readings(self.meta, self._energy_j, self._busy_s,
                           chunk.start_index, chunk.sample_dt, er, br)

    def _take_busy(self, n: int) -> np.ndarray:
        buf = np.concatenate(self._busy_queue)
        taken, rest = buf[:n], buf[n:]
        self._busy_queue = [rest] if len(rest) else []
        return taken

    def _absorb(self, filt: np.ndarray, busy: np.ndarray) -> None:
        commits, self._seen_busy, self._tail = _fold_trim(
            filt, busy, self._seen_busy, self._tail)
        for arr in commits:
            self._commit(arr)

    def _commit(self, arr: np.ndarray) -> None:
        if not len(arr):
            return
        self._committed.append(arr)
        self._n_committed += len(arr)
        r = arr / self.tdp
        r = r[r >= spikes.SPIKE_LO]
        if len(r):
            for c, h in self._hist.items():
                n = len(h)
                idx = np.clip(((r - spikes.SPIKE_LO) / c).astype(np.int64),
                              0, n - 1)
                h += np.bincount(idx, minlength=n).astype(np.float64)

    # -- incremental queries --------------------------------------------
    @property
    def n_ingested(self) -> int:
        """Raw samples absorbed so far."""
        return self._next_index

    @property
    def n_committed(self) -> int:
        """Samples already inside the trimmed trace (excludes the EMA tail
        and the trailing-idle pending tail)."""
        return self._n_committed

    @property
    def fraction(self) -> float:
        """Fraction of the expected trace ingested (from ``meta``)."""
        return self.n_ingested / max(self.meta.n_samples, 1)

    def spike_vector(self, bin_size: float) -> np.ndarray:
        """Normalized spike vector over the *committed* samples — an O(bins)
        read of the incremental histogram, bit-identical to
        ``spikes.spike_vector`` on the committed trace."""
        c = float(bin_size)
        if c not in self._hist:
            raise ValueError(f"bin size {bin_size} not tracked; "
                             f"tracked: {self.bin_sizes}")
        h = self._hist[c]
        tot = h.sum()
        if tot == 0:
            return np.zeros(len(h))
        return h / tot

    def spike_count(self, bin_size: float | None = None) -> int:
        """Committed samples at or above the spike threshold.  The count is
        the same for every tracked histogram, so ``None`` (the default) reads
        the first one; an explicitly untracked bin size raises."""
        c = self.bin_sizes[0] if bin_size is None else float(bin_size)
        if c not in self._hist:
            raise ValueError(f"bin size {bin_size} not tracked; "
                             f"tracked: {self.bin_sizes}")
        return int(self._hist[c].sum())

    # -- profile emission -----------------------------------------------
    def _profile(self, trace: np.ndarray, complete: bool) -> PartialProfile:
        m = self.meta
        return PartialProfile(
            name=m.name, tdp=self.tdp, power_trace=trace,
            sm_util=m.app_sm_util, dram_util=m.app_dram_util,
            exec_time=m.exec_time, scaling={}, domain=m.domain,
            fraction=self.fraction, n_samples=self.n_ingested,
            complete=complete)

    def snapshot(self) -> PartialProfile:
        """A valid partial profile over everything ingested so far.  Does not
        mutate builder state — ingestion can continue afterwards."""
        filt = self._ema.pending_view()
        pieces = list(self._committed)
        if len(filt):
            busy = np.concatenate(self._busy_queue)[:len(filt)] \
                if self._busy_queue else np.zeros(len(filt))
            commits, _, _ = _fold_trim(filt, busy, self._seen_busy,
                                       list(self._tail))
            pieces += commits
        trace = np.concatenate(pieces) if pieces else np.empty(0, np.float64)
        return self._profile(trace, complete=False)

    def finalize(self) -> PartialProfile:
        """Flush the EMA tail and emit the completed profile.  A full-trace
        build equals the batch ``simulate`` + ``trim_idle`` path at 1e-9."""
        if not self._finalized:
            filt = self._ema.flush()
            if len(filt):
                self._absorb(filt, self._take_busy(len(filt)))
            self._busy_queue = []
            self._finalized = True
        trace = np.concatenate(self._committed) if self._committed \
            else np.empty(0, np.float64)
        return self._profile(trace, complete=True)


# ---------------------------------------------------------------------------
# streaming equivalents of the batch profiling entry points
# ---------------------------------------------------------------------------
def stream_profile_once(stream, model, tdp: float, freq: float = 1.0,
                        seed: int = 0, sample_dt: float = 1e-3,
                        target_duration: float = 4.0,
                        chunk_samples: int = 256) -> PartialProfile:
    """Streaming twin of ``telemetry.profile_once``: one low-cost profile,
    built by pumping the chunk stream through a ``ProfileBuilder``."""
    from repro.telemetry.simulator import stream_telemetry
    meta, chunks = stream_telemetry(stream, freq, model, seed=seed,
                                    sample_dt=sample_dt,
                                    target_duration=target_duration,
                                    chunk_samples=chunk_samples)
    builder = ProfileBuilder(meta, tdp)
    for chunk in chunks:
        builder.ingest(chunk)
    return builder.finalize()


def stream_profile_workload(stream, model, freqs, tdp: float, seed: int = 0,
                            sample_dt: float = 1e-3,
                            target_duration: float = 4.0,
                            chunk_samples: int = 256) -> WorkloadProfile:
    """Streaming twin of ``telemetry.profile_workload``: the full reference
    sweep, one builder per frequency (same per-frequency seeds), assembled
    into the identical ``WorkloadProfile`` (golden-tested at 1e-9)."""
    scaling = {}
    top = max(freqs)
    top_profile = None
    for i, f in enumerate(sorted(freqs)):
        prof = stream_profile_once(stream, model, tdp, freq=f,
                                   seed=seed * 1009 + i, sample_dt=sample_dt,
                                   target_duration=target_duration,
                                   chunk_samples=chunk_samples)
        tr = prof.power_trace
        scaling[f] = FreqPoint(
            freq=f,
            p90=spikes.p_quantile(tr, tdp, 90),
            p95=spikes.p_quantile(tr, tdp, 95),
            p99=spikes.p_quantile(tr, tdp, 99),
            mean_power=spikes.mean_power_rel(tr, tdp),
            exec_time=prof.exec_time,
            spike_vec=spikes.spike_vector(tr, tdp),
        )
        if f == top:
            top_profile = prof
    return WorkloadProfile(
        name=top_profile.name, tdp=tdp, power_trace=top_profile.power_trace,
        sm_util=top_profile.sm_util, dram_util=top_profile.dram_util,
        exec_time=top_profile.exec_time, scaling=scaling,
        domain=top_profile.domain,
    )
