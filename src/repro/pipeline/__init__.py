"""Streaming profiling pipeline — the front door of the Minos repro.

Three layers, replacing the copy-pasted profile->classify->cap glue:

  * ``ProfileBuilder`` (``builder``) — incremental ingestion of
    ``TelemetryChunk``s; partial ``WorkloadProfile`` at any point, batch
    equivalence at the end.
  * ``ReferenceLibrary`` (``library``) — versioned reference set with
    incremental add/remove, fingerprinted on-disk spike-matrix cache
    (classifier warm start), and cluster-based dedup.
  * ``OnlineCapController`` (``online``) — classify partial profiles
    mid-run with a distance-margin confidence and actuate frequency caps
    early, re-packing the pod through ``PowerAwareScheduler``.
  * ``BatchProfileEngine`` (``batch``) — slot-indexed columnar twin of
    ``ProfileBuilder``: one stacked NumPy pass advances every live fleet
    job per mux tick, bit-identical to the per-job path.
"""
from repro.pipeline.batch import BatchProfileEngine, SlotBuilder
from repro.pipeline.builder import (DEFAULT_BIN_SIZES, PartialProfile,
                                    ProfileBuilder, stream_profile_once,
                                    stream_profile_workload)
from repro.pipeline.library import ReferenceLibrary, build_reference_library
from repro.pipeline.online import (CapDecision, OnlineCapController,
                                   classify_with_margin,
                                   classify_with_margin_batch,
                                   finalize_fleet, observe_fleet)
