"""Online frequency capping from partial profiles (the pipeline's service
mode).

The paper's batch workflow profiles a new workload to completion before
Algorithm 1 runs once.  ``OnlineCapController`` instead watches a
``ProfileBuilder`` mid-run: after each ingested chunk it classifies the
partial profile, turns the nearest/runner-up cosine distances into a
margin-based confidence score, and — once confident — issues the frequency
cap **early** through the DVFS actuator and (optionally) re-packs the pod
through ``PowerAwareScheduler``.  ``benchmarks/bench_online_cap.py`` measures
how early the online decision converges to the full-profile cap.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.algorithm1 import (DEFAULT_BIN_CANDIDATES, FreqSelection,
                                   resolve_objective, select_optimal_freq)
from repro.core.classify import MinosClassifier, WorkloadProfile
from repro.pipeline.builder import ProfileBuilder
from repro.pipeline.library import ReferenceLibrary


@dataclass
class CapDecision:
    target: str
    cap: float
    objective: str
    selection: FreqSelection
    confidence: float            # 1 - d_best/d_second at the chosen bin size
    fraction: float              # trace fraction ingested when decided
    n_samples: int
    early: bool                  # decided before the stream finished
    device_id: str = ""          # fleet device the job runs on ("" = n/a)


def classify_with_margin(profile: WorkloadProfile, clf: MinosClassifier,
                         bin_candidates=DEFAULT_BIN_CANDIDATES
                         ) -> tuple[FreqSelection, float]:
    """Algorithm 1 plus a distance-margin confidence: how decisively the
    nearest power neighbor beats the runner-up at the selected bin size.
    Confidence is ``1 - d1/d2`` in [0, 1]: ~0 when the two closest references
    are equidistant (an unstable decision), ->1 when the winner is clear."""
    sel = select_optimal_freq(profile, clf, bin_candidates)
    (_, d1, d2), = clf.power_top2([profile], bin_size=sel.bin_size)
    if d2 == 0.0:
        confidence = 0.0         # two exact ties: nothing separates them
    elif d2 == float("inf"):
        confidence = 1.0         # single eligible reference
    else:
        confidence = max(0.0, 1.0 - d1 / d2)
    return sel, confidence


class OnlineCapController:
    """Watch a builder's stream and issue the cap as soon as it is safe.

    ``references`` may be a ``ReferenceLibrary`` (warm-started classifier) or
    a prebuilt ``MinosClassifier``.  A decision fires when the partial
    profile has at least ``min_spike_samples`` committed spike samples, at
    least ``min_fraction`` of the expected trace, and margin confidence at or
    above ``min_confidence`` — or unconditionally at ``finalize``.

    Cost note: every ``observe`` runs full Algorithm 1 on the snapshot —
    O(trace-so-far), since ``choose_bin_size`` needs trace quantiles, not
    just the builder's incremental histograms (the snapshot memoizes its
    spike vectors so the bin-size sweep, neighbor, and margin queries share
    one histogram pass per bin size).  At the shipped 1 kHz sampling that is
    microseconds per chunk; raise ``min_spike_samples``/``min_fraction`` or
    observe every k-th chunk if sampling orders of magnitude faster.
    """

    def __init__(self, references, objective="powercentric",
                 actuator=None, min_confidence: float = 0.3,
                 min_fraction: float = 0.1, min_spike_samples: int = 50,
                 bin_candidates=DEFAULT_BIN_CANDIDATES,
                 device_id: str = ""):
        if isinstance(references, ReferenceLibrary):
            self.clf = references.classifier()
        elif isinstance(references, MinosClassifier):
            self.clf = references
        else:
            self.clf = MinosClassifier(list(references))
        # a builtin name ("powercentric"/"perfcentric") or any
        # ObjectivePolicy-like plugin (see repro.api.register_objective)
        self.objective_policy = resolve_objective(objective)
        self.objective = self.objective_policy.name
        self.actuator = actuator
        self.min_confidence = float(min_confidence)
        self.min_fraction = float(min_fraction)
        self.min_spike_samples = int(min_spike_samples)
        self.bin_candidates = tuple(bin_candidates)
        self.device_id = device_id
        self.decisions: list[CapDecision] = []

    def _record(self, profile, builder: ProfileBuilder, sel: FreqSelection,
                confidence: float, early: bool) -> CapDecision:
        decision = CapDecision(
            target=profile.name, cap=self.objective_policy.cap(sel),
            objective=self.objective, selection=sel, confidence=confidence,
            fraction=builder.fraction, n_samples=builder.n_ingested,
            early=early, device_id=self.device_id)
        self.decisions.append(decision)
        if self.actuator is not None:
            self.actuator.set_cap(decision.cap)
        return decision

    def observe(self, builder: ProfileBuilder) -> CapDecision | None:
        """Called after a chunk lands: returns an early ``CapDecision`` once
        the gates pass, ``None`` while the evidence is still too thin."""
        if builder.spike_count() < self.min_spike_samples:
            return None
        if builder.fraction < self.min_fraction:
            return None
        profile = builder.snapshot()
        if len(profile.power_trace) == 0:
            return None
        sel, conf = classify_with_margin(profile, self.clf,
                                         self.bin_candidates)
        if conf < self.min_confidence:
            return None
        return self._record(profile, builder, sel, conf, early=True)

    def finalize(self, builder: ProfileBuilder) -> CapDecision:
        """End of stream without a confident early call: decide from the
        completed profile (the batch-equivalent decision)."""
        profile = builder.finalize()
        sel, conf = classify_with_margin(profile, self.clf,
                                         self.bin_candidates)
        return self._record(profile, builder, sel, conf, early=False)

    def run(self, meta, chunks, tdp: float, **builder_kw) -> CapDecision:
        """Pump a ``stream_telemetry`` stream to the first confident decision
        (early-stopping the profile run — the paper's cost saving, extended
        online); falls back to the finalize decision at stream end."""
        builder = ProfileBuilder(meta, tdp, **builder_kw)
        for chunk in chunks:
            builder.ingest(chunk)
            decision = self.observe(builder)
            if decision is not None:
                return decision
        return self.finalize(builder)

    # -- pod integration -------------------------------------------------
    def repack(self, scheduler, jobs, budget_w: float):
        """Re-pack the pod after cap decisions change the power picture:
        delegates to ``PowerAwareScheduler.schedule`` over the live job
        queue (deterministic first-fit-decreasing)."""
        return scheduler.schedule(jobs, budget_w=budget_w)
