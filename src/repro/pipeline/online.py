"""Online frequency capping from partial profiles (the pipeline's service
mode).

The paper's batch workflow profiles a new workload to completion before
Algorithm 1 runs once.  ``OnlineCapController`` instead watches a
``ProfileBuilder`` mid-run: after each ingested chunk it classifies the
partial profile, turns the nearest/runner-up cosine distances into a
margin-based confidence score, and — once confident — issues the frequency
cap **early** through the DVFS actuator and (optionally) re-packs the pod
through ``PowerAwareScheduler``.  ``benchmarks/bench_online_cap.py`` measures
how early the online decision converges to the full-profile cap.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.algorithm1 import (DEFAULT_BIN_CANDIDATES, FreqSelection,
                                   cap_perf_centric, cap_power_centric,
                                   resolve_objective, select_optimal_freq)
from repro.core.classify import MinosClassifier, WorkloadProfile
from repro.pipeline.builder import ProfileBuilder
from repro.pipeline.library import ReferenceLibrary


@dataclass
class CapDecision:
    target: str
    cap: float
    objective: str
    selection: FreqSelection
    confidence: float            # 1 - d_best/d_second at the chosen bin size
    fraction: float              # trace fraction ingested when decided
    n_samples: int
    early: bool                  # decided before the stream finished
    device_id: str = ""          # fleet device the job runs on ("" = n/a)


def classify_with_margin(profile: WorkloadProfile, clf: MinosClassifier,
                         bin_candidates=DEFAULT_BIN_CANDIDATES
                         ) -> tuple[FreqSelection, float]:
    """Algorithm 1 plus a distance-margin confidence: how decisively the
    nearest power neighbor beats the runner-up at the selected bin size.
    Confidence is ``1 - d1/d2`` in [0, 1]: ~0 when the two closest references
    are equidistant (an unstable decision), ->1 when the winner is clear."""
    sel = select_optimal_freq(profile, clf, bin_candidates)
    (_, d1, d2), = clf.power_top2([profile], bin_size=sel.bin_size)
    if d2 == 0.0:
        confidence = 0.0         # two exact ties: nothing separates them
    elif d2 == float("inf"):
        confidence = 1.0         # single eligible reference
    else:
        confidence = max(0.0, 1.0 - d1 / d2)
    return sel, confidence


def _batch_quantiles(profiles, q: float) -> None:
    """Prefill each profile's ``p_quantile`` memo with row-wise percentiles
    over equal-length trace stacks.  ``np.percentile(..., axis=1)`` computes
    each row independently of the others, so every prefetched value is
    bit-identical to the per-trace call the memo would otherwise make."""
    q = float(q)
    by_len: dict[int, list] = {}
    for p in profiles:
        cache = p.__dict__.setdefault("_pq_memo", {})
        if q in cache or len(p.power_trace) == 0:
            continue
        by_len.setdefault(len(p.power_trace), []).append(p)
    for group in by_len.values():
        if len(group) == 1:
            group[0].p_quantile(q)           # plain single-trace path
            continue
        vals = np.percentile(np.stack([p.power_trace for p in group]), q,
                             axis=1)
        for p, v in zip(group, vals):
            p.__dict__["_pq_memo"][q] = float(v / p.tdp)


def classify_with_margin_batch(profiles, clf: MinosClassifier,
                               bin_candidates=DEFAULT_BIN_CANDIDATES
                               ) -> list[tuple[FreqSelection, float]]:
    """``classify_with_margin`` over a whole batch of profiles in a handful
    of classifier queries: one ``power_neighbors_idx`` sweep per candidate
    bin size for every profile at once, one batched utilization query, and
    one margin query per *distinct chosen* bin size — instead of ~9 queries
    per profile.  Per-profile results are bit-identical to the one-at-a-time
    path: every reduction in the distance pipeline (einsum dot products,
    row-wise norms/argmin/partition/percentile) computes row i independently
    of the batch around it."""
    if not profiles:
        return []
    q = 90.0                                 # choose_bin_size default
    _batch_quantiles(profiles, q)
    p_t = np.array([p.p_quantile(q) for p in profiles])
    ref_pq = np.array([r.p_quantile(q) for r in clf.references])
    n = len(profiles)
    # one fused sweep: nearest + runner-up distances for every candidate bin
    # size, from one distance matrix per candidate
    sweep = clf.power_sweep(profiles, bin_candidates, second=False)
    nn_idx = np.stack([s[0] for s in sweep], axis=1)
    nn_dist = np.stack([s[1] for s in sweep], axis=1)
    # ChooseBinSize: argmin of |p90(T) - p90(NN_c(T))|, first minimum wins
    # (exactly the strict-less update order of the sequential sweep)
    errs = np.abs(p_t[:, None] - ref_pq[nn_idx])
    best_j = np.argmin(errs, axis=1)
    rows = np.arange(n)
    pwr_idx = nn_idx[rows, best_j]
    util_idx, util_dist = clf.util_neighbors_idx(profiles)
    # the margin distances at the chosen bin size come straight out of the
    # sweep — the one-at-a-time path recomputes the same matrix in power_top2.
    # The runner-up partition runs only on the rows that chose each bin size
    # (a row of the distance matrix partitions the same alone as in bulk).
    d1 = nn_dist[rows, best_j]
    d2 = np.empty(n, np.float64)
    for j, s in enumerate(sweep):
        sel_rows = np.nonzero(best_j == j)[0]
        if not len(sel_rows):
            continue
        D = s[2]
        if D.shape[1] > 1:
            d2[sel_rows] = np.partition(D[sel_rows], 1, axis=1)[:, 1]
        else:
            d2[sel_rows] = np.inf
    # frequency caps are pure functions of the neighbor: compute once per
    # distinct neighbor, not once per profile
    f_pwr_memo: dict[int, float] = {}
    f_perf_memo: dict[int, float] = {}
    pwr_i = pwr_idx.tolist()
    util_i = util_idx.tolist()
    pwr_d = d1.tolist()                      # .tolist() preserves the bits
    util_d = util_dist.tolist()
    d1_l, d2_l = d1.tolist(), d2.tolist()
    best_c = [bin_candidates[j] for j in best_j.tolist()]
    out = []
    for i, p in enumerate(profiles):
        pi, ui = pwr_i[i], util_i[i]
        f_pwr = f_pwr_memo.get(pi)
        if f_pwr is None:
            f_pwr = f_pwr_memo[pi] = cap_power_centric(clf.references[pi])
        f_perf = f_perf_memo.get(ui)
        if f_perf is None:
            f_perf = f_perf_memo[ui] = cap_perf_centric(clf.references[ui])
        sel = FreqSelection(
            target=p.name, bin_size=best_c[i],
            power_neighbor=clf.references[pi].name,
            power_distance=pwr_d[i],
            util_neighbor=clf.references[ui].name,
            util_distance=util_d[i],
            f_pwr=f_pwr, f_perf=f_perf)
        if d2_l[i] == 0.0:
            confidence = 0.0
        elif d2_l[i] == float("inf"):
            confidence = 1.0
        else:
            confidence = max(0.0, 1.0 - d1_l[i] / d2_l[i])
        out.append((sel, confidence))
    return out


class OnlineCapController:
    """Watch a builder's stream and issue the cap as soon as it is safe.

    ``references`` may be a ``ReferenceLibrary`` (warm-started classifier) or
    a prebuilt ``MinosClassifier``.  A decision fires when the partial
    profile has at least ``min_spike_samples`` committed spike samples, at
    least ``min_fraction`` of the expected trace, and margin confidence at or
    above ``min_confidence`` — or unconditionally at ``finalize``.

    Cost note: every ``observe`` runs full Algorithm 1 on the snapshot —
    O(trace-so-far), since ``choose_bin_size`` needs trace quantiles, not
    just the builder's incremental histograms (the snapshot memoizes its
    spike vectors so the bin-size sweep, neighbor, and margin queries share
    one histogram pass per bin size).  At the shipped 1 kHz sampling that is
    microseconds per chunk; raise ``min_spike_samples``/``min_fraction`` or
    observe every k-th chunk if sampling orders of magnitude faster.
    """

    def __init__(self, references, objective="powercentric",
                 actuator=None, min_confidence: float = 0.3,
                 min_fraction: float = 0.1, min_spike_samples: int = 50,
                 bin_candidates=DEFAULT_BIN_CANDIDATES,
                 device_id: str = ""):
        if isinstance(references, ReferenceLibrary):
            self.clf = references.classifier()
        elif isinstance(references, MinosClassifier):
            self.clf = references
        else:
            self.clf = MinosClassifier(list(references))
        # a builtin name ("powercentric"/"perfcentric") or any
        # ObjectivePolicy-like plugin (see repro.api.register_objective)
        self.objective_policy = resolve_objective(objective)
        self.objective = self.objective_policy.name
        self.actuator = actuator
        self.min_confidence = float(min_confidence)
        self.min_fraction = float(min_fraction)
        self.min_spike_samples = int(min_spike_samples)
        self.bin_candidates = tuple(bin_candidates)
        self.device_id = device_id
        self.decisions: list[CapDecision] = []

    # discovery gate tap (class default, so a tap-less controller is
    # byte-identical to the pre-discovery one): when set — by
    # FleetCapController.set_discovery — every recorded decision is offered,
    # with its decided profile, to the quarantine intake.  Replay never
    # calls _record (decisions are re-adopted verbatim from the journal), so
    # a resumed session cannot double-quarantine.
    quarantine_tap = None

    def _record(self, profile, builder: ProfileBuilder, sel: FreqSelection,
                confidence: float, early: bool) -> CapDecision:
        decision = CapDecision(
            target=profile.name, cap=self.objective_policy.cap(sel),
            objective=self.objective, selection=sel, confidence=confidence,
            fraction=builder.fraction, n_samples=builder.n_ingested,
            early=early, device_id=self.device_id)
        self.decisions.append(decision)
        if self.actuator is not None:
            self.actuator.set_cap(decision.cap)
        if self.quarantine_tap is not None:
            self.quarantine_tap(profile, decision)
        return decision

    def observe(self, builder: ProfileBuilder) -> CapDecision | None:
        """Called after a chunk lands: returns an early ``CapDecision`` once
        the gates pass, ``None`` while the evidence is still too thin."""
        if builder.spike_count() < self.min_spike_samples:
            return None
        if builder.fraction < self.min_fraction:
            return None
        profile = builder.snapshot()
        if len(profile.power_trace) == 0:
            return None
        sel, conf = classify_with_margin(profile, self.clf,
                                         self.bin_candidates)
        if conf < self.min_confidence:
            return None
        return self._record(profile, builder, sel, conf, early=True)

    def finalize(self, builder: ProfileBuilder) -> CapDecision:
        """End of stream without a confident early call: decide from the
        completed profile (the batch-equivalent decision)."""
        profile = builder.finalize()
        sel, conf = classify_with_margin(profile, self.clf,
                                         self.bin_candidates)
        return self._record(profile, builder, sel, conf, early=False)

    def run(self, meta, chunks, tdp: float, **builder_kw) -> CapDecision:
        """Pump a ``stream_telemetry`` stream to the first confident decision
        (early-stopping the profile run — the paper's cost saving, extended
        online); falls back to the finalize decision at stream end."""
        builder = ProfileBuilder(meta, tdp, **builder_kw)
        for chunk in chunks:
            builder.ingest(chunk)
            decision = self.observe(builder)
            if decision is not None:
                return decision
        return self.finalize(builder)

    # -- pod integration -------------------------------------------------
    def repack(self, scheduler, jobs, budget_w: float):
        """Re-pack the pod after cap decisions change the power picture:
        delegates to ``PowerAwareScheduler.schedule`` over the live job
        queue (deterministic first-fit-decreasing)."""
        return scheduler.schedule(jobs, budget_w=budget_w)


# ---------------------------------------------------------------------------
# fleet-scale batched observation (one classification sweep per mux tick)
# ---------------------------------------------------------------------------
def _grouped(entries):
    """Group ``(i, controller, builder, profile)`` entries by the (shared)
    classifier + bin-candidate tuple, preserving order within each group."""
    groups: dict[tuple, list] = {}
    for entry in entries:
        ctl = entry[1]
        # id() keys group by *object identity* within one call only —
        # never ordered, compared, or serialized (dict insertion order is
        # first-appearance, which is deterministic given the input order)
        groups.setdefault((id(ctl.clf), ctl.bin_candidates),  # minoslint: disable=W304
                          []).append(entry)
    return groups.values()


def _replica_key(ctl, builder):
    """Replica-group key for engine-backed fleet jobs.  Slot rows that
    ingested the same telemetry stream (identified by the *shared*
    ``TraceMeta`` object — the fleet pattern where one pre-generated stream
    feeds many jobs) at the same TDP to the same depth hold bit-identical
    state: the engine is deterministic in (chunk values, tdp), so one
    representative's snapshot and classification serve the whole group.
    Jobs with per-job metas never share a key and see no behavior change."""
    return (id(builder.meta), builder.tdp, builder.n_ingested,
            id(ctl.clf), ctl.bin_candidates)


def observe_fleet(pairs) -> list:
    """Batched ``OnlineCapController.observe`` across many ``(controller,
    builder)`` pairs (one per fleet job, sharing a classifier): the cheap
    per-job gates run in pair order, then every gate-passing snapshot goes
    through ONE ``classify_with_margin_batch`` sweep — with one
    representative per replica group (see ``_replica_key``) standing in for
    all its identical siblings.  Returns the per-pair ``CapDecision |
    None`` list; each decision is bit-identical to what that pair's
    ``observe`` call would have produced."""
    out = [None] * len(pairs)
    # engine-backed slot builders gate and snapshot columnar: one stacked
    # spike-count row-sum and one snapshot_batch per engine, instead of a
    # histogram sum + memo prefill per job
    snap: dict[int, object] = {}
    gated: set[int] = set()
    replicas: dict[int, list[int]] = {}
    by_engine: dict[int, list[int]] = {}
    engines: dict[int, object] = {}
    for i, (ctl, builder) in enumerate(pairs):
        eng = getattr(builder, "engine", None)
        if eng is not None and not getattr(builder, "_released", True):
            # identity grouping within this call only: iteration is in
            # first-appearance order and keys are never serialized
            by_engine.setdefault(id(eng), []).append(i)  # minoslint: disable=W304
            engines[id(eng)] = eng  # minoslint: disable=W304
    for key, ids in by_engine.items():
        eng = engines[key]
        counts = eng.spike_count_batch([pairs[i][1].slot for i in ids])
        passing_ids = [
            i for i, cnt in zip(ids, counts.tolist())
            if cnt >= pairs[i][0].min_spike_samples
            and pairs[i][1].fraction >= pairs[i][0].min_fraction]
        gated.update(ids)
        reps: list[int] = []
        first: dict[tuple, int] = {}
        for i in passing_ids:
            r = first.setdefault(_replica_key(*pairs[i]), i)
            if r == i:
                reps.append(i)
            else:
                replicas.setdefault(r, []).append(i)
        snap.update(zip(reps, eng.snapshot_batch(
            [pairs[i][1].slot for i in reps])))
    passing = []                 # (i, controller, builder, profile)
    for i, (ctl, builder) in enumerate(pairs):
        if i in snap:
            profile = snap[i]
        elif i in gated:
            continue             # batched gates said the evidence is thin
            # (replica siblings ride on their representative instead)
        else:
            if builder.spike_count() < ctl.min_spike_samples:
                continue
            if builder.fraction < ctl.min_fraction:
                continue
            profile = builder.snapshot()
        if len(profile.power_trace) == 0:
            continue
        passing.append((i, ctl, builder, profile))
    for group in _grouped(passing):
        results = classify_with_margin_batch(
            [p for _, _, _, p in group], group[0][1].clf,
            group[0][1].bin_candidates)
        for (i, ctl, builder, profile), (sel, conf) in zip(group, results):
            if conf >= ctl.min_confidence:
                out[i] = ctl._record(profile, builder, sel, conf, early=True)
            for j in replicas.get(i, ()):
                ctl_j, b_j = pairs[j]
                if conf >= ctl_j.min_confidence:
                    out[j] = ctl_j._record(profile, b_j, sel, conf,
                                           early=True)
    return out


def finalize_fleet(pairs) -> list:
    """Batched ``OnlineCapController.finalize``: flush every builder, then
    classify all completed profiles in one sweep per shared classifier.
    Returns the per-pair ``CapDecision`` list, in pair order."""
    # engine-backed slot builders flush through finalize_batch (stacked memo
    # prefill); plain builders finalize one at a time
    profs: dict[int, object] = {}
    by_engine: dict[int, list[int]] = {}
    engines: dict[int, object] = {}
    for i, (ctl, builder) in enumerate(pairs):
        eng = getattr(builder, "engine", None)
        if eng is not None and not getattr(builder, "_released", True):
            # identity grouping within this call only: iteration is in
            # first-appearance order and keys are never serialized
            by_engine.setdefault(id(eng), []).append(i)  # minoslint: disable=W304
            engines[id(eng)] = eng  # minoslint: disable=W304
    for key, ids in by_engine.items():
        profs.update(zip(ids, engines[key].finalize_batch(
            [pairs[i][1].slot for i in ids])))
    entries = [(i, ctl, builder,
                profs[i] if i in profs else builder.finalize())
               for i, (ctl, builder) in enumerate(pairs)]
    out = [None] * len(pairs)
    # replica dedup (see _replica_key): every engine slot still flushed
    # above — only the classification is shared.  Each sibling's decision
    # is built from its OWN (bit-identical) profile and builder.
    replicas: dict[int, list] = {}
    first: dict[tuple, int] = {}
    lead = []
    for e in entries:
        i, ctl, builder, _ = e
        if i in profs:
            r = first.setdefault(_replica_key(ctl, builder), i)
            if r != i:
                replicas.setdefault(r, []).append(e)
                continue
        lead.append(e)
    for group in _grouped(lead):
        results = classify_with_margin_batch(
            [p for _, _, _, p in group], group[0][1].clf,
            group[0][1].bin_candidates)
        for (i, ctl, builder, profile), (sel, conf) in zip(group, results):
            out[i] = ctl._record(profile, builder, sel, conf, early=False)
            for j, ctl_j, b_j, prof_j in replicas.get(i, ()):
                out[j] = ctl_j._record(prof_j, b_j, sel, conf, early=False)
    return out
