"""Analytic per-kernel (FLOPs, bytes) streams for every workload.

Each workload's one training/serving step is described as an ordered list of
``Kernel``s whose FLOPs/bytes are derived from the same ModelConfig math the
dry-run compiles.  The DVFS simulator executes these streams to produce power
traces and utilization counters — Minos itself only ever sees the sampled
telemetry, never this ground truth (DESIGN.md §2).

``gap_s`` models host-side time before a kernel (CPU sections, collective
stalls): the LSMS-like idle-burst pattern of the paper comes from streams
with large gaps.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass(frozen=True)
class Kernel:
    name: str
    flops: float
    bytes: float
    gap_s: float = 0.0          # host gap before this kernel


@dataclass(frozen=True)
class KernelStream:
    name: str
    kernels: tuple[Kernel, ...]
    domain: str = ""

    def totals(self) -> tuple[float, float]:
        return (sum(k.flops for k in self.kernels),
                sum(k.bytes for k in self.kernels))


def _mm(name: str, m: float, k: float, n: float, gap: float = 0.0,
        dtype_bytes: int = 2) -> Kernel:
    flops = 2.0 * m * k * n
    byts = (m * k + k * n + m * n) * dtype_bytes
    return Kernel(name, flops, byts, gap)


def _ew(name: str, elems: float, flops_per: float = 4.0,
        bytes_per: float = 6.0) -> Kernel:
    return Kernel(name, elems * flops_per, elems * bytes_per)


def lm_train_stream(cfg: ModelConfig, shape: ShapeConfig,
                    n_chips: int = 256) -> KernelStream:
    """One training step, per-chip share, fwd+bwd (bwd ~= 2x fwd)."""
    T = shape.tokens / n_chips          # tokens per chip
    d = cfg.d_model
    ks: list[Kernel] = []
    ks.append(_ew("embed", T * d))
    layers = _layer_kernels(cfg, shape, T)
    for i in range(cfg.num_layers):
        for k in layers(i):
            ks.append(k)
    ks.append(_mm("logits", T, d, cfg.padded_vocab / 16))
    ks.append(_ew("ce_loss", T * cfg.padded_vocab / 16, 2.0, 4.0))
    # backward ~= 2x forward compute on the same operands
    bwd = [Kernel("bwd_" + k.name, 2 * k.flops, 2 * k.bytes, k.gap_s)
           for k in ks]
    # optimizer: read p,m,v + grads, write p,m,v (AdamW)
    params = cfg.param_count() / n_chips
    opt = Kernel("adamw", 12 * params, 22 * params)
    grad_comm = Kernel("grad_reduce", 0.0, 2 * params, gap_s=0.0)
    return KernelStream(f"{cfg.name}:{shape.name}",
                        tuple(ks + bwd + [grad_comm, opt]), domain="train")


def _layer_kernels(cfg: ModelConfig, shape: ShapeConfig, T: float):
    d = cfg.d_model
    s = shape.seq_len

    def layer(i: int) -> list[Kernel]:
        ks: list[Kernel] = []
        ks.append(_ew(f"norm", T * d, 5.0, 4.0))
        if cfg.family == "ssm" or (cfg.family == "hybrid" and not cfg.is_attn_layer(i)):
            di, dst = cfg.d_inner, cfg.ssm_state
            ks.append(_mm("ssm_in_proj", T, d, 2 * di))
            ks.append(_ew("ssm_conv", T * di, 8.0, 6.0))
            ks.append(_mm("ssm_x_proj", T, di, cfg.dt_rank + 2 * dst))
            ks.append(_mm("ssm_dt_proj", T, cfg.dt_rank, di))
            # selective scan: ~9 flops per (token, di, ds) state element,
            # bandwidth-bound on state traffic
            ks.append(Kernel("ssm_scan", 9.0 * T * di * dst,
                             6.0 * T * di * dst / 16))
            ks.append(_mm("ssm_out_proj", T, di, d))
        elif cfg.use_mla:
            H, qk = cfg.num_heads, cfg.mla_qk_nope + cfg.qk_rope_dim
            ks.append(_mm("mla_q", T, d, H * qk))
            ks.append(_mm("mla_kva", T, d, cfg.kv_lora_rank + cfg.qk_rope_dim))
            ks.append(_mm("mla_kvb", T, cfg.kv_lora_rank,
                          H * (cfg.mla_qk_nope + cfg.mla_v_dim)))
            ks.append(_attn_core(T, s, H, qk, causal=shape.kind != "decode"))
            ks.append(_mm("mla_o", T, H * cfg.mla_v_dim, d))
        elif cfg.num_heads:
            H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            ks.append(_mm("attn_qkv", T, d, (H + 2 * KV) * dh))
            ks.append(_attn_core(T, s, H, dh, causal=True))
            ks.append(_mm("attn_o", T, H * dh, d))
        if cfg.family == "vlm" and cfg.cross_attn_period and \
                (i % cfg.cross_attn_period) == (cfg.cross_attn_period - 1):
            H, KV, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            ks.append(_mm("xattn_q", T, d, H * dh))
            ks.append(Kernel("xattn_core",
                             4.0 * T * cfg.num_image_tokens * H * dh,
                             2.0 * T * cfg.num_image_tokens * 2))
        if cfg.is_moe_layer(i):
            E, k, f = cfg.moe_num_experts, cfg.moe_top_k, cfg.moe_d_ff
            C = max(int(cfg.moe_group_size * k * cfg.capacity_factor / E), 1)
            ks.append(_mm("moe_router", T, d, E))
            ks.append(Kernel("moe_dispatch", 2.0 * T * E * C * d / 16,
                             2.0 * T * k * cfg.capacity_factor * d,
                             gap_s=2e-5))   # all-to-all-ish stall
            for mm in ("moe_gate", "moe_up", "moe_down"):
                ks.append(_mm(mm, T * k * cfg.capacity_factor, d if mm != "moe_down" else f,
                              f if mm != "moe_down" else d))
            ks.append(Kernel("moe_combine", 2.0 * T * E * C * d / 16,
                             2.0 * T * k * cfg.capacity_factor * d))
            if cfg.moe_num_shared:
                fs = cfg.moe_num_shared * f
                for mm in ("sh_gate", "sh_up"):
                    ks.append(_mm(mm, T, d, fs))
                ks.append(_mm("sh_down", T, fs, d))
        elif cfg.d_ff:
            n_mats = 3 if cfg.mlp_activation == "swiglu" else 2
            for j in range(n_mats - 1):
                ks.append(_mm(f"mlp_in{j}", T, d, cfg.d_ff))
            ks.append(_mm("mlp_out", T, cfg.d_ff, d))
        return ks

    return layer


def _attn_core(T: float, s: float, H: int, dh: int, causal: bool) -> Kernel:
    # flash-style: scores + AV, causal halves useful work
    factor = 0.5 if causal else 1.0
    flops = 4.0 * T * s * H * dh * factor
    byts = 2.0 * T * 2 * s * dh / 128 * H  # chunked KV re-reads amortized
    return Kernel("attn_core", flops, byts)


def lm_decode_stream(cfg: ModelConfig, shape: ShapeConfig,
                     n_chips: int = 256) -> KernelStream:
    """One decode step: weight-read bound + cache reads."""
    b = shape.global_batch / max(n_chips / 16, 1)   # per data-shard batch
    params = cfg.active_param_count() / 16           # per chip (TP 16)
    ks: list[Kernel] = [
        Kernel("decode_matmuls", 2.0 * params * b, 2.0 * params, gap_s=1e-4),
    ]
    # attention cache read
    S = shape.seq_len
    if cfg.family == "ssm":
        cache = cfg.num_layers * cfg.d_inner * cfg.ssm_state * 4 / 16
    elif cfg.use_mla:
        cache = cfg.num_layers * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2 / 16
    else:
        n_attn = cfg.num_layers // (cfg.attn_period or 1) if cfg.family == "hybrid" \
            else cfg.num_layers
        cache = n_attn * S * 2 * cfg.num_kv_heads * cfg.head_dim * 2 / 16
    ks.append(Kernel("decode_attn", 4.0 * b * cache / 2, b * cache))
    ks.append(_ew("decode_sample", b * cfg.padded_vocab / 16, 2.0, 2.0))
    return KernelStream(f"{cfg.name}:{shape.name}", tuple(ks), domain="decode")


def lm_prefill_stream(cfg: ModelConfig, shape: ShapeConfig,
                      n_chips: int = 256) -> KernelStream:
    T = shape.tokens / n_chips
    ks: list[Kernel] = [_ew("embed", T * cfg.d_model)]
    layers = _layer_kernels(cfg, shape, T)
    for i in range(cfg.num_layers):
        ks.extend(layers(i))
    ks.append(Kernel("kv_write", 0.0,
                     shape.tokens / n_chips * 2 * max(cfg.num_kv_heads, 1)
                     * max(cfg.head_dim, 1) * 2))
    ks.append(_mm("logits_last", shape.global_batch / n_chips * 16,
                  cfg.d_model, cfg.padded_vocab / 16))
    return KernelStream(f"{cfg.name}:{shape.name}", tuple(ks), domain="prefill")


def build_stream(cfg: ModelConfig, shape: ShapeConfig,
                 n_chips: int = 256) -> KernelStream:
    if shape.kind == "train":
        return lm_train_stream(cfg, shape, n_chips)
    if shape.kind == "prefill":
        return lm_prefill_stream(cfg, shape, n_chips)
    return lm_decode_stream(cfg, shape, n_chips)


# ---------------------------------------------------------------------------
# Microbenchmark workloads (paper Table 1 analogues)
# ---------------------------------------------------------------------------
def micro_gemm(n: int = 25536) -> KernelStream:
    """SGEMM 25536^3 (paper's compute-bound microbenchmark)."""
    return KernelStream("sgemm-25k", ( _mm("gemm", n / 16, n, n), ), "micro")


def micro_spmv_memory(nnz: float = 2e8, repeat: int = 24) -> KernelStream:
    """Pannotia-PageRank-like: bandwidth-bound irregular SpMV iterations."""
    ks = []
    for i in range(repeat):
        ks.append(Kernel("spmv", 2.0 * nnz / 16, 14.0 * nnz / 16, gap_s=3e-4))
        ks.append(_ew("rank_update", nnz / 64, 3.0, 8.0))
    return KernelStream("pagerank-pannotia", tuple(ks), "graph")


def micro_spmv_compute(nnz: float = 2e8, repeat: int = 24) -> KernelStream:
    """Gunrock-PageRank-like: fused frontier kernels, higher compute density."""
    ks = []
    for i in range(repeat):
        ks.append(Kernel("frontier", 24.0 * nnz / 16, 8.0 * nnz / 16))
        ks.append(_ew("rank_update", nnz / 64, 3.0, 8.0))
    return KernelStream("pagerank-gunrock", tuple(ks), "graph")


def micro_idle_burst(burst_flops: float = 5e13, bursts: int = 6,
                     gap_s: float = 0.12) -> KernelStream:
    """LSMS-like: GPU near idle with periodic dense bursts (matrix inversion
    on device, the rest on host)."""
    ks = []
    for i in range(bursts):
        ks.append(Kernel("zgetrf_burst", burst_flops, burst_flops / 250,
                         gap_s=gap_s))
    return KernelStream("lsms-like", tuple(ks), "hpc")


def micro_vector_search(nq: int = 4096, nd: float = 5e7, dim: int = 128
                        ) -> KernelStream:
    """FAISS-like fused batched-distance + top-k (held-out workload).

    Like the real FAISS GPU kernels, distances are reduced to top-k in
    registers — the (nq x nd) distance matrix is never materialized, so the
    op is compute-bound (the paper matches FAISS to SD-XL, a high-spike
    compute workload)."""
    n_loc = nd / 16
    flops = 2.0 * nq * dim * n_loc + 6.0 * nq * n_loc   # distances + topk cmp
    byts = (nq * dim + dim * n_loc + nq * 128) * 2.0    # inputs + topk out
    ks = [Kernel("dist_topk_fused", flops, byts, gap_s=5e-5)]
    return KernelStream("vector-search", tuple(ks), "micro")


def micro_stencil(cells: float = 990 ** 3, repeat: int = 10) -> KernelStream:
    """M-PSDNS-like FFT/stencil sweep: mixed compute + bandwidth."""
    ks = []
    for i in range(repeat):
        ks.append(Kernel("fft", 5.0 * cells * 30 / 16, 8.0 * cells / 16))
        ks.append(_ew("pointwise", cells / 16, 6.0, 10.0))
    return KernelStream("mpsdns-like", tuple(ks), "hpc")
