from repro.telemetry.kernel_stream import Kernel, KernelStream, build_stream
from repro.telemetry.power_model import TPUPowerModel
from repro.telemetry.simulator import (SimTrace, TelemetryChunk, TraceMeta,
                                       profile_once, profile_workload,
                                       simulate, stream_telemetry)
from repro.telemetry.workloads import (build_holdout_profiles, build_reference_set,
                                       holdout_streams, reference_streams)
