"""The reference workload zoo (paper Table 1 analogue).

Reference set: arch x shape cells from the assigned pool + HPC/graph
microbenchmarks — spanning compute-bound, memory-bound, hybrid, and
bursty-idle behavior, mirroring the paper's 18-workload diversity.

Held-out (never in the reference set; used for the §7.1 case study):
  * ``vector-search``  — FAISS analogue
  * ``granite-moe``    — Qwen1.5-MoE analogue (an unseen MoE architecture)
"""
from __future__ import annotations

import numpy as np

from repro.analysis.hardware import FREQ_SWEEP
from repro.configs import ARCHS, SHAPES
from repro.telemetry import kernel_stream as kstream
from repro.telemetry.power_model import TPUPowerModel
# NOTE: repro.pipeline.builder imports repro.telemetry.simulator, so the
# stream_profile_* builders must be imported lazily inside the two build
# functions below to keep `import repro.telemetry` cycle-free.

HOLDOUT_PREFIX = ("vector-search", "granite-moe-3b-a800m")

# arch x shape cells in the zoo (kept to a representative-but-diverse set;
# granite cells are excluded from references as the held-out MoE)
_REFERENCE_CELLS = [
    ("falcon-mamba-7b", "train_4k"), ("falcon-mamba-7b", "decode_32k"),
    ("falcon-mamba-7b", "long_500k"),
    ("glm4-9b", "train_4k"), ("glm4-9b", "decode_32k"),
    ("glm4-9b", "prefill_32k"),
    ("command-r-35b", "train_4k"), ("command-r-35b", "decode_32k"),
    ("command-r-35b", "prefill_32k"),
    ("phi3-medium-14b", "train_4k"), ("phi3-medium-14b", "decode_32k"),
    ("qwen2.5-14b", "train_4k"), ("qwen2.5-14b", "decode_32k"),
    ("llama-3.2-vision-11b", "train_4k"), ("llama-3.2-vision-11b", "decode_32k"),
    ("jamba-1.5-large-398b", "train_4k"), ("jamba-1.5-large-398b", "decode_32k"),
    ("jamba-1.5-large-398b", "long_500k"),
    ("deepseek-v2-236b", "train_4k"), ("deepseek-v2-236b", "decode_32k"),
    ("deepseek-v2-236b", "prefill_32k"),
    ("whisper-medium", "train_4k"), ("whisper-medium", "decode_32k"),
]

_HOLDOUT_CELLS = [
    ("granite-moe-3b-a800m", "decode_32k"),
    ("granite-moe-3b-a800m", "train_4k"),
]

# Novel families for the online class-discovery evaluation: shapes the
# shipped reference library has never seen — an encoder-decoder prefill
# (whisper), an SSM prefill (falcon-mamba), a sparse-MoE prefill (granite)
# and a hybrid SSM-MoE prefill (jamba).  Deliberately NOT part of
# ``reference_streams``: they exist to arrive unannounced from production
# traffic and be discovered (quarantine -> re-cluster -> promote).
_NOVEL_CELLS = [
    ("whisper-medium", "prefill_32k"),
    ("falcon-mamba-7b", "prefill_32k"),
    ("granite-moe-3b-a800m", "prefill_32k"),
    ("jamba-1.5-large-398b", "prefill_32k"),
]


def reference_streams(n_chips: int = 256) -> list[kstream.KernelStream]:
    out = []
    for arch, shape in _REFERENCE_CELLS:
        out.append(kstream.build_stream(ARCHS[arch], SHAPES[shape], n_chips))
    out += [
        kstream.micro_gemm(),
        kstream.micro_spmv_memory(),
        kstream.micro_spmv_compute(),
        kstream.micro_idle_burst(),
        kstream.micro_stencil(),
    ]
    return out


def holdout_streams(n_chips: int = 256) -> list[kstream.KernelStream]:
    out = [kstream.build_stream(ARCHS[a], SHAPES[s], n_chips)
           for a, s in _HOLDOUT_CELLS]
    out.append(kstream.micro_vector_search())
    return out


def novel_streams(n_chips: int = 256) -> list[kstream.KernelStream]:
    """Workload families outside the shipped reference library (see
    ``_NOVEL_CELLS``) — the discovery evaluation's unknown arrivals."""
    return [kstream.build_stream(ARCHS[a], SHAPES[s], n_chips)
            for a, s in _NOVEL_CELLS]


def _mix_weight(name: str) -> int:
    """Sampling weight of a zoo stream in the fleet job mix.  Production
    accelerator fleets are dominated by serving traffic (arXiv:2502.18680),
    so decode cells are drawn 4x as often as training, prefill/long-context
    and the HPC microbenchmarks 2x."""
    if ":decode" in name:
        return 4
    if ":prefill" in name or ":long" in name:
        return 2
    if ":" not in name:          # microbenchmarks / HPC analogues
        return 2
    return 1                     # train cells


def fleet_job_mix(n_jobs: int, seed: int = 0,
                  chips_choices=(32, 64, 128, 256),
                  include_novel: bool = False
                  ) -> list[tuple[kstream.KernelStream, int]]:
    """A deterministic mix of ``(kernel stream, chip count)`` jobs for fleet
    simulations, sampled (seeded, serving-weighted — see ``_mix_weight``)
    from the reference + holdout zoos — the arrival queue used by
    ``benchmarks/bench_fleet.py`` and the fleet example.

    ``include_novel=True`` extends the sampling pool with the
    ``novel_streams`` families (the discovery evaluation's unknown
    arrivals); the default pool — and hence every historical seed's draw
    sequence — is unchanged."""
    rng = np.random.default_rng(seed)
    pool = [s for s in reference_streams() + holdout_streams()
            for _ in range(_mix_weight(s.name))]
    if include_novel:
        pool += [s for s in novel_streams()
                 for _ in range(_mix_weight(s.name))]
    out = []
    for _ in range(n_jobs):
        stream = pool[int(rng.integers(len(pool)))]
        out.append((stream, int(chips_choices[int(
            rng.integers(len(chips_choices)))])))
    return out


def build_reference_set(model: TPUPowerModel | None = None,
                        freqs=FREQ_SWEEP, seed: int = 0,
                        target_duration: float = 4.0):
    """Profiles with full frequency sweeps (the shipped reference library)."""
    from repro.pipeline.builder import stream_profile_workload
    model = model or TPUPowerModel()
    tdp = model.spec.tdp_w
    return [stream_profile_workload(s, model, freqs, tdp, seed=seed + i,
                                    target_duration=target_duration)
            for i, s in enumerate(reference_streams())]


def build_holdout_profiles(model: TPUPowerModel | None = None, seed: int = 77,
                           with_truth: bool = False, freqs=FREQ_SWEEP):
    """Held-out workloads: single uncapped profile (what Minos sees) plus —
    separately — the ground-truth sweep used only for evaluating predictions."""
    from repro.pipeline.builder import (stream_profile_once,
                                        stream_profile_workload)
    model = model or TPUPowerModel()
    tdp = model.spec.tdp_w
    observed, truth = [], []
    for i, s in enumerate(holdout_streams()):
        observed.append(stream_profile_once(s, model, tdp, seed=seed + i))
        if with_truth:
            truth.append(stream_profile_workload(s, model, freqs, tdp,
                                                 seed=seed + i))
    return (observed, truth) if with_truth else observed
