"""DVFS-aware analytical power/performance model of a TPU v5e chip.

Physically-grounded structure:
  * kernel duration  t = max(flops / (F_peak * f/f_max * eff), bytes / BW)
  * dynamic power    P = P_idle + A_c * util_c * (f/f_max) * V(f)^2 + A_m * util_m
    with V(f) linear (hardware.ChipSpec); A_c/A_m calibrated so a fully
    compute-bound kernel at f_max sustains ~1.3x TDP and a bandwidth-bound
    kernel ~0.75x TDP (the regimes the paper observes on MI300X).
  * low->high activity transitions overshoot (di/dt inrush): amplitude
    proportional to the power step, clipped at the OCP 2x TDP excursion
    ceiling, decaying over ~1 ms — these are the paper's "power spikes".

The model is fully parameterized by its ``ChipSpec``: per-model constants
(TDP, idle, DVFS range) *and* per-instance variability (``perf_scale``
scales achievable compute/bandwidth, ``power_scale`` scales drawn power).
At the default scales of exactly 1.0 every multiplication is an IEEE
identity, so a nominal chip is bit-exact with the pre-fleet model.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.analysis.hardware import ChipSpec, V5E
from repro.telemetry.kernel_stream import Kernel

T_LAUNCH = 2e-6          # fixed per-kernel launch overhead (s)
OVERSHOOT_KAPPA = 1.1    # overshoot amplitude vs power step
OVERSHOOT_TAU = 1.0e-3   # overshoot duration (s)
OVERSHOOT_MIN_STEP = 30.0  # W of step needed to trigger an excursion


@dataclass(frozen=True)
class KernelExec:
    duration: float
    util_c: float            # fraction of peak compute at current f
    util_m: float            # fraction of peak HBM bandwidth
    power: float             # steady-state W


class TPUPowerModel:
    def __init__(self, spec: ChipSpec = V5E, mxu_eff: float = 0.85,
                 hbm_eff: float = 0.9):
        self.spec = spec
        self.mxu_eff = mxu_eff
        self.hbm_eff = hbm_eff
        # calibrate A_c, A_m (see module docstring)
        tdp, idle = spec.tdp_w, spec.idle_w
        # compute-bound @ (uc=1.0, um=0.2, f=1): 1.3*TDP
        # memory-bound  @ (uc=0.15, um=0.9):     0.75*TDP
        #   idle + A_c + 0.2 A_m = 1.3 tdp ; idle + 0.15 A_c + 0.9 A_m = 0.75 tdp
        b1 = 1.3 * tdp - idle
        b2 = 0.75 * tdp - idle
        self.A_m = (b2 - 0.15 * b1) / (0.9 - 0.15 * 0.2)
        self.A_c = b1 - 0.2 * self.A_m

    # ------------------------------------------------------------------
    def exec_kernel(self, k: Kernel, f: float) -> KernelExec:
        s = self.spec
        f = min(max(f, s.f_min), s.f_max)
        fc = s.peak_flops_bf16 * (f / s.f_max) * self.mxu_eff * s.perf_scale
        bm = s.hbm_bw * self.hbm_eff * s.perf_scale   # memory clock not SM-capped
        t_c = k.flops / fc if k.flops else 0.0
        t_m = k.bytes / bm if k.bytes else 0.0
        t = max(t_c, t_m, T_LAUNCH)
        util_c = t_c / t
        util_m = t_m / t
        p = self.steady_power(util_c, util_m, f)
        return KernelExec(t, util_c, util_m, p)

    def steady_power(self, util_c: float, util_m: float, f: float) -> float:
        s = self.spec
        v = s.voltage(f)
        return (s.idle_w
                + self.A_c * util_c * (f / s.f_max) * v * v
                + self.A_m * util_m) * s.power_scale

    def overshoot(self, p_prev: float, p_new: float) -> float | None:
        """Excursion amplitude for a low->high transition (None if none).

        The ceiling is deliberately the *nameplate* OCP limit
        (``max_excursion * tdp_w``), not scaled by ``power_scale``: it
        models the platform's power-delivery spec, which doesn't move with
        the silicon lottery.  Consequence: on a far-off-nominal chip,
        effective-TDP normalization recovers the intrinsic relative curve
        exactly for steady power but only approximately for
        ceiling-clipped spikes — the fleet's device-portability tests
        bound the effect."""
        step = p_new - p_prev
        if step < OVERSHOOT_MIN_STEP:
            return None
        amp = p_new + OVERSHOOT_KAPPA * step
        return min(amp, self.spec.max_excursion * self.spec.tdp_w)

    @property
    def idle_w(self) -> float:
        return self.spec.idle_w * self.spec.power_scale
