"""Event-based telemetry simulator: kernel stream -> sampled power trace.

Produces exactly what the paper's profiling harness sees on hardware:
  * an energy-accumulator counter sampled every 1-2 ms (noisy, per [87])
  * a busy-cycles counter (for idle trimming)
  * per-kernel (duration, compute-util, memory-util) rows (the nsight
    analogue) — aggregated into the app-level utilization point.

Integration is vectorized: power is piecewise-constant over events, so the
cumulative energy E(t) is piecewise-linear and sampling it at bin edges is a
single ``np.interp``.  Concretely (``integrate_events``): power deltas are
accumulated at the sorted event endpoints with ``np.add.at``, one prefix sum
gives the piecewise-constant rate, a second gives the cumulative integral at
the breakpoints, and ``np.interp`` evaluates it at all sample edges — O((E+S)
log E) instead of the seed's O(E x S) dense clip-broadcast (preserved in
``repro.legacy.integrate_events_dense`` and pinned equivalent to 1e-9 by
``tests/test_profiling_engine.py``).  The busy counter uses the same engine
with unit weights.

Two consumption modes share the event engine:

  * ``simulate`` — the batch path: the whole trace at once (``SimTrace``).
  * ``stream_telemetry`` — the streaming path: yields ``TelemetryChunk``s of
    raw *counter readings* (cumulative energy joules + cumulative busy
    seconds at each sample edge), exactly what a telemetry daemon polls on
    hardware.  ``repro.pipeline.ProfileBuilder`` ingests these chunks
    incrementally and can emit a partial profile at any point.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.classify import WorkloadProfile
from repro.core import spikes as spk
from repro.telemetry.kernel_stream import KernelStream
from repro.telemetry.power_model import (
    OVERSHOOT_TAU, TPUPowerModel,
)


@dataclass
class SimTrace:
    power_filtered: np.ndarray       # after Δe/Δt + EMA + trim (what Minos sees)
    power_raw: np.ndarray
    busy: np.ndarray
    sample_dt: float
    exec_time: float                 # one iteration of the stream (s)
    app_sm_util: float
    app_dram_util: float
    kernel_rows: list = field(default_factory=list)


@dataclass
class TelemetryChunk:
    """One poll of the chip's accumulating counters: readings at the sample
    edges ``start_index+1 .. start_index+len(energy_j)`` (edge 0 reads 0/0,
    so the first chunk starts at index 0).  Readings are cumulative since
    trace start; the consumer differentiates against its own prefix state."""
    energy_j: np.ndarray         # cumulative energy counter (J), one per edge
    busy_s: np.ndarray           # cumulative busy-time counter (s), aligned
    sample_dt: float
    start_index: int             # absolute sample index of the first reading


@dataclass
class TraceMeta:
    """Trace-level context a streaming consumer needs up front."""
    name: str
    domain: str
    sample_dt: float
    n_samples: int               # total samples the stream will deliver
    exec_time: float             # one iteration of the kernel stream (s)
    app_sm_util: float
    app_dram_util: float
    kernel_rows: list = field(default_factory=list)
    device_id: str = ""          # originating fleet device ("" = unspecified)


@dataclass
class _EventTrace:
    """Shared precursor of both consumption modes: the event list plus the
    per-stream aggregates, before any sampling/noise is applied."""
    t0: np.ndarray               # power-event starts
    t1: np.ndarray               # power-event ends
    pw: np.ndarray               # power-event rates (W)
    busy_t0: np.ndarray          # busy-segment starts
    busy_t1: np.ndarray          # busy-segment ends
    edges: np.ndarray            # sample edges (n_samples + 1)
    n_samples: int
    sample_dt: float
    exec_time: float
    app_sm_util: float
    app_dram_util: float
    kernel_rows: list


def _event_trace(stream: KernelStream, freq: float, model: TPUPowerModel,
                 sample_dt: float, target_duration: float,
                 max_iterations: int) -> _EventTrace:
    execs = [model.exec_kernel(k, freq) for k in stream.kernels]
    gaps = np.array([k.gap_s for k in stream.kernels])
    durs = np.array([e.duration for e in execs])
    pows = np.array([e.power for e in execs])
    step_time = float(np.sum(gaps) + np.sum(durs))
    iters = int(np.clip(np.ceil(target_duration / max(step_time, 1e-9)),
                        1, max_iterations))

    # --- build the event list (times, power levels) for all iterations ---
    nk = len(execs)
    idle = model.idle_w
    # per-iteration event pattern: [gap_0, k_0, gap_1, k_1, ...]
    seg_d = np.empty(2 * nk)
    seg_p = np.empty(2 * nk)
    seg_busy = np.empty(2 * nk)
    seg_d[0::2] = gaps
    seg_d[1::2] = durs
    seg_p[0::2] = idle
    seg_p[1::2] = pows
    seg_busy[0::2] = 0.0
    seg_busy[1::2] = 1.0
    # head/tail idle padding so trimming has something to trim
    pad = max(10 * sample_dt, 0.01)
    d = np.concatenate([[pad], np.tile(seg_d, iters), [pad]])
    p = np.concatenate([[idle], np.tile(seg_p, iters), [idle]])
    busy_flag = np.concatenate([[0.0], np.tile(seg_busy, iters), [0.0]])
    # drop zero-length segments
    keep = d > 0
    d, p, busy_flag = d[keep], p[keep], busy_flag[keep]

    # --- overshoot events at low->high transitions ---
    t_edges = np.concatenate([[0.0], np.cumsum(d)])
    starts, ends = t_edges[:-1], t_edges[1:]
    ev_t0, ev_t1, ev_p = [starts], [ends], [p]
    prev_p = np.concatenate([[idle], p[:-1]])
    for i in np.nonzero(p - prev_p >= 30.0)[0]:
        amp = model.overshoot(prev_p[i], p[i])
        if amp is None:
            continue
        tau = min(OVERSHOOT_TAU, d[i])
        ev_t0.append(np.array([starts[i]]))
        ev_t1.append(np.array([starts[i] + tau]))
        # overshoot is *additional* power on top of the segment
        ev_p.append(np.array([amp - p[i]]))
    t0 = np.concatenate(ev_t0)
    t1 = np.concatenate(ev_t1)
    pw = np.concatenate(ev_p)

    total_t = t_edges[-1]
    n_samples = int(total_t / sample_dt)
    edges = np.arange(n_samples + 1) * sample_dt

    busy_t0, busy_t1 = starts[busy_flag > 0], ends[busy_flag > 0]
    tot_d = durs.sum()
    app_sm = float((durs * [e.util_c for e in execs]).sum() / max(tot_d, 1e-12))
    app_dr = float((durs * [e.util_m for e in execs]).sum() / max(tot_d, 1e-12))
    rows = [(e.duration, e.util_c, e.util_m) for e in execs]
    return _EventTrace(t0=t0, t1=t1, pw=pw, busy_t0=busy_t0, busy_t1=busy_t1,
                       edges=edges, n_samples=n_samples, sample_dt=sample_dt,
                       exec_time=step_time, app_sm_util=app_sm,
                       app_dram_util=app_dr, kernel_rows=rows)


def _noisy_energy_increments(ev: _EventTrace, noise: float,
                             seed: int) -> np.ndarray:
    """Per-sample energy-counter increments with sensor noise (paper [87]:
    energy-derived power is spiky).  RNG call order is frozen — the golden
    tests pin it against ``legacy.simulate_dense``."""
    energy = integrate_events(ev.t0, ev.t1, ev.pw, ev.edges)
    rng = np.random.default_rng(seed)
    de = np.diff(energy)
    de = de * (1.0 + noise * rng.standard_normal(ev.n_samples))
    # occasional sensor outliers
    out_mask = rng.random(ev.n_samples) < 0.01
    return np.where(out_mask, de * (1.0 + 0.5 * rng.random(ev.n_samples)), de)


def _busy_counter(ev: _EventTrace) -> np.ndarray:
    """Cumulative busy-seconds counter at every sample edge."""
    return integrate_events(ev.busy_t0, ev.busy_t1,
                            np.ones_like(ev.busy_t0), ev.edges)


def simulate(stream: KernelStream, freq: float, model: TPUPowerModel,
             sample_dt: float = 1e-3, target_duration: float = 4.0,
             max_iterations: int = 2000, noise: float = 0.03,
             seed: int = 0) -> SimTrace:
    ev = _event_trace(stream, freq, model, sample_dt, target_duration,
                      max_iterations)
    de = _noisy_energy_increments(ev, noise, seed)
    p_raw = de / sample_dt

    # busy counter per sample: busy-time overlap via the same event engine
    busy_time = np.diff(_busy_counter(ev))
    busy = (busy_time > 0).astype(np.float64)

    # backend pinned: host-side profiling must stay float64-reproducible
    # across CPU and TPU hosts (the Pallas f32 kernel is for on-device use)
    filt = spk.ema_filter(p_raw, alpha=0.5, backend="numpy")
    filt = spk.trim_idle(filt, busy)

    return SimTrace(power_filtered=filt, power_raw=p_raw, busy=busy,
                    sample_dt=sample_dt, exec_time=ev.exec_time,
                    app_sm_util=ev.app_sm_util, app_dram_util=ev.app_dram_util,
                    kernel_rows=ev.kernel_rows)


def stream_telemetry(stream: KernelStream, freq: float, model: TPUPowerModel,
                     sample_dt: float = 1e-3, target_duration: float = 4.0,
                     max_iterations: int = 2000, noise: float = 0.03,
                     seed: int = 0, chunk_samples: int = 256,
                     device_id: str = ""):
    """Streaming twin of ``simulate``: ``(meta, chunk_iterator)``.

    The iterator yields ``TelemetryChunk``s of cumulative counter readings —
    the same noisy energy increments the batch path turns into ``power_raw``,
    re-accumulated into the counter a real daemon would poll.  Feeding every
    chunk to ``repro.pipeline.ProfileBuilder`` reproduces the batch
    ``simulate`` trace (golden-tested at 1e-9), and any prefix of the chunks
    yields a valid partial profile.
    """
    if chunk_samples <= 0:
        raise ValueError(f"chunk_samples must be positive, got {chunk_samples}")
    ev = _event_trace(stream, freq, model, sample_dt, target_duration,
                      max_iterations)
    de = _noisy_energy_increments(ev, noise, seed)
    energy_ctr = np.concatenate([[0.0], np.cumsum(de)])
    busy_ctr = _busy_counter(ev)
    meta = TraceMeta(name=stream.name, domain=stream.domain,
                     sample_dt=sample_dt, n_samples=ev.n_samples,
                     exec_time=ev.exec_time, app_sm_util=ev.app_sm_util,
                     app_dram_util=ev.app_dram_util,
                     kernel_rows=ev.kernel_rows, device_id=device_id)

    def chunks():
        for i in range(0, ev.n_samples, chunk_samples):
            j = min(i + chunk_samples, ev.n_samples)
            yield TelemetryChunk(energy_j=energy_ctr[i + 1:j + 1],
                                 busy_s=busy_ctr[i + 1:j + 1],
                                 sample_dt=sample_dt, start_index=i)

    return meta, chunks()


def integrate_events(t0: np.ndarray, t1: np.ndarray, pw: np.ndarray,
                     edges: np.ndarray) -> np.ndarray:
    """Cumulative integral of overlapping box signals, sampled at ``edges``.

    Each event contributes rate ``pw[i]`` on ``[t0[i], t1[i])``.  The summed
    rate is piecewise-constant, so its integral is piecewise-linear with
    breakpoints only at event endpoints: accumulate the +pw/-pw rate deltas
    at the unique endpoint times (``np.add.at`` handles coincident events),
    prefix-sum twice (rate, then integral), and evaluate with one
    ``np.interp``.  Queries outside the event span clamp to 0 / the total.
    """
    if len(t0) == 0:
        return np.zeros(len(edges))
    times = np.concatenate([t0, t1])
    deltas = np.concatenate([pw, -np.asarray(pw)])
    uniq, inv = np.unique(times, return_inverse=True)
    rate_delta = np.zeros(len(uniq))
    np.add.at(rate_delta, inv, deltas)
    rate = np.cumsum(rate_delta)                       # rate on [uniq_k, uniq_k+1)
    cum = np.empty(len(uniq))
    cum[0] = 0.0
    np.cumsum(np.diff(uniq) * rate[:-1], out=cum[1:])
    return np.interp(edges, uniq, cum)


def profile_workload(stream: KernelStream, model: TPUPowerModel,
                     freqs, tdp: float, seed: int = 0,
                     sample_dt: float = 1e-3,
                     target_duration: float = 4.0) -> WorkloadProfile:
    """DEPRECATED batch sweep — routes through the streaming
    ``ProfileBuilder`` (``repro.pipeline.stream_profile_workload``), the one
    profiling implementation; output matches the retired batch assembly at
    1e-9 (golden-pinned in ``tests/test_pipeline.py``)."""
    import warnings
    warnings.warn(
        "repro.telemetry.profile_workload is deprecated; use "
        "repro.pipeline.stream_profile_workload (or repro.api.MinosSession)",
        DeprecationWarning, stacklevel=2)
    from repro.pipeline.builder import stream_profile_workload
    return stream_profile_workload(stream, model, freqs, tdp, seed=seed,
                                   sample_dt=sample_dt,
                                   target_duration=target_duration)


def profile_once(stream: KernelStream, model: TPUPowerModel, tdp: float,
                 freq: float = 1.0, seed: int = 0) -> WorkloadProfile:
    """DEPRECATED single low-cost profile — routes through the streaming
    ``ProfileBuilder`` (``repro.pipeline.stream_profile_once``); output
    matches the retired batch assembly at 1e-9 (golden-pinned in
    ``tests/test_pipeline.py``)."""
    import warnings
    warnings.warn(
        "repro.telemetry.profile_once is deprecated; use "
        "repro.pipeline.stream_profile_once (or repro.api.MinosSession"
        ".submit)",
        DeprecationWarning, stacklevel=2)
    from repro.pipeline.builder import stream_profile_once
    return stream_profile_once(stream, model, tdp, freq=freq, seed=seed)
