"""Clustering primitives implemented from scratch (no scipy/sklearn in the
container): agglomerative hierarchical clustering (Lance-Williams updates,
ward/average/complete linkage) over cosine distances, a jit'd K-Means, and
silhouette scores.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# distances
# ---------------------------------------------------------------------------
def cosine_distance_matrix(V: np.ndarray) -> np.ndarray:
    """Pairwise cosine distances between row vectors (zero rows -> dist 1)."""
    V = np.asarray(V, np.float64)
    norms = np.linalg.norm(V, axis=1)
    safe = np.where(norms > 0, norms, 1.0)
    U = V / safe[:, None]
    sim = U @ U.T
    sim = np.clip(sim, -1.0, 1.0)
    d = 1.0 - sim
    zero = norms == 0
    d[zero, :] = 1.0
    d[:, zero] = 1.0
    np.fill_diagonal(d, 0.0)
    return d


def euclidean_distance_matrix(X: np.ndarray) -> np.ndarray:
    X = np.asarray(X, np.float64)
    sq = np.sum(X * X, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2 * X @ X.T
    return np.sqrt(np.maximum(d2, 0.0))


def mahalanobis_distance_matrix(X: np.ndarray, reg: float = 1e-6) -> np.ndarray:
    """Mahalanobis pairwise distances (paper §4.1.2 names this as an
    alternative metric that accounts for feature correlations)."""
    X = np.asarray(X, np.float64)
    cov = np.cov(X, rowvar=False) + reg * np.eye(X.shape[1])
    prec = np.linalg.inv(cov)
    diff = X[:, None, :] - X[None, :, :]
    d2 = np.einsum("ijk,kl,ijl->ij", diff, prec, diff)
    return np.sqrt(np.maximum(d2, 0.0))


# ---------------------------------------------------------------------------
# agglomerative hierarchical clustering (Lance-Williams)
# ---------------------------------------------------------------------------
_LW = {
    # (ai_fn, aj_fn, b_fn, g) over cluster sizes (ni, nj, nk)
    "average": lambda ni, nj, nk: (ni / (ni + nj), nj / (ni + nj), 0.0, 0.0),
    "complete": lambda ni, nj, nk: (0.5, 0.5, 0.0, 0.5),
    "single": lambda ni, nj, nk: (0.5, 0.5, 0.0, -0.5),
}


def linkage(dist: np.ndarray, method: str = "ward") -> np.ndarray:
    """scipy-compatible linkage matrix Z (n-1, 4): [i, j, dist, size].

    ward uses the Lance-Williams recurrence on squared distances; other
    methods operate on raw distances.
    """
    n = dist.shape[0]
    D = dist.astype(np.float64).copy()
    if method == "ward":
        D = D * D
    np.fill_diagonal(D, np.inf)
    sizes = np.ones(n)
    ids = np.arange(n)                      # row -> cluster id
    alive = np.ones(n, bool)
    Z = np.zeros((n - 1, 4))
    next_id = n
    for step in range(n - 1):
        # closest pair: dead rows/cols are held at inf, so a flat argmin over
        # the full matrix finds the same first-minimum as the seed's
        # active-submatrix scan (row-major order is preserved)
        i, j = divmod(int(np.argmin(D)), n)
        if i == j:
            raise RuntimeError("degenerate linkage state")
        if i > j:
            i, j = j, i
        dij = D[i, j]
        d_rep = np.sqrt(dij) if method == "ward" else dij
        Z[step] = [ids[i], ids[j], d_rep, sizes[i] + sizes[j]]
        ni, nj = sizes[i], sizes[j]
        # Lance-Williams update of the merged cluster (stored in slot i),
        # one vectorized pass over the surviving rows
        upd = alive.copy()
        upd[i] = upd[j] = False
        nk = sizes[upd]
        dik, djk = D[i, upd], D[j, upd]
        if method == "ward":
            new = ((ni + nk) * dik + (nj + nk) * djk - nk * dij) \
                / (ni + nj + nk)
        else:
            ai, aj, bb, g = _LW[method](ni, nj, nk)
            new = ai * dik + aj * djk + bb * dij + g * np.abs(dik - djk)
        D[i, upd] = new
        D[upd, i] = new
        sizes[i] = ni + nj
        ids[i] = next_id
        next_id += 1
        alive[j] = False
        D[j, :] = np.inf
        D[:, j] = np.inf
    return Z


def cut(Z: np.ndarray, threshold: float) -> np.ndarray:
    """Cluster labels from slicing the dendrogram at ``threshold``."""
    n = Z.shape[0] + 1
    parent = list(range(2 * n - 1))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for step in range(n - 1):
        i, j, d, _ = Z[step]
        if d <= threshold:
            node = n + step
            parent[find(int(i))] = node
            parent[find(int(j))] = node
    roots = {}
    labels = np.zeros(n, np.int64)
    for leaf in range(n):
        r = find(leaf)
        labels[leaf] = roots.setdefault(r, len(roots))
    return labels


def cut_k(Z: np.ndarray, k: int) -> np.ndarray:
    """Labels for exactly k clusters (cut just below the (k-1)-th last merge)."""
    n = Z.shape[0] + 1
    k = max(1, min(k, n))
    if k == 1:
        return np.zeros(n, np.int64)
    threshold = Z[n - k, 2] - 1e-12
    return cut(Z, threshold)


def dendrogram_order(Z: np.ndarray) -> list[int]:
    """Leaf ordering for display."""
    n = Z.shape[0] + 1
    children = {}
    for step in range(n - 1):
        children[n + step] = (int(Z[step, 0]), int(Z[step, 1]))

    def leaves(node):
        if node < n:
            return [node]
        a, b = children[node]
        return leaves(a) + leaves(b)

    return leaves(2 * n - 2)


# ---------------------------------------------------------------------------
# K-Means (jit'd Lloyd iterations) + silhouette
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("k", "iters"))
def _lloyd(X: jax.Array, init: jax.Array, k: int, iters: int):
    def body(centers, _):
        d = jnp.sum((X[:, None, :] - centers[None]) ** 2, axis=-1)
        lab = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(lab, k, dtype=X.dtype)
        counts = onehot.sum(0)
        sums = onehot.T @ X
        new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1),
                        centers)
        return new, None

    centers, _ = jax.lax.scan(body, init, None, length=iters)
    d = jnp.sum((X[:, None, :] - centers[None]) ** 2, axis=-1)
    labels = jnp.argmin(d, axis=1)
    inertia = jnp.sum(jnp.min(d, axis=1))
    return centers, labels, inertia


def kmeanspp_init(X: np.ndarray, k: int, rng: np.random.Generator) -> np.ndarray:
    """kmeans++ seeding with an incrementally-maintained nearest-center
    distance (O(kn) instead of recomputing all centers each draw, O(k^2 n)).
    Draws the same RNG stream — and therefore the same centers — as the
    recompute-everything seed loop (``repro.legacy.kmeanspp_init_loop``)."""
    X = np.asarray(X, np.float64)
    centers = np.empty((k, X.shape[1]))
    centers[0] = X[rng.integers(len(X))]
    d2 = np.sum((X - centers[0]) ** 2, axis=1)
    for m in range(1, k):
        tot = d2.sum()
        if tot <= 0:
            idx = rng.integers(len(X))
        else:
            idx = rng.choice(len(X), p=d2 / tot)
        centers[m] = X[idx]
        d2 = np.minimum(d2, np.sum((X - centers[m]) ** 2, axis=1))
    return centers


def kmeans(X: np.ndarray, k: int, seed: int = 0, iters: int = 50,
           restarts: int = 4):
    """K-Means with kmeans++ seeding; returns (centers, labels, inertia)."""
    X = np.asarray(X, np.float64)
    rng = np.random.default_rng(seed)
    best = None
    for _ in range(restarts):
        init = kmeanspp_init(X, k, rng)
        c, lab, inertia = _lloyd(jnp.asarray(X), jnp.asarray(init), k, iters)
        inertia = float(inertia)
        if best is None or inertia < best[2]:
            best = (np.asarray(c), np.asarray(lab), inertia)
    return best


def silhouette_score(X: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette, fully vectorized: the per-point distance sums to every
    cluster come from one (n, k) matmul of the distance matrix against the
    cluster one-hot, instead of per-point/per-cluster Python loops."""
    X = np.asarray(X, np.float64)
    labels = np.asarray(labels)
    n = len(X)
    uniq, inv = np.unique(labels, return_inverse=True)
    k = len(uniq)
    if k < 2 or n < 3:
        return 0.0
    D = euclidean_distance_matrix(X)
    onehot = np.zeros((n, k))
    onehot[np.arange(n), inv] = 1.0
    counts = onehot.sum(axis=0)                       # (k,)
    sums = D @ onehot                                 # (n, k): sum_i->cluster
    own = counts[inv]                                 # own-cluster sizes
    rows = np.arange(n)
    a = sums[rows, inv] / np.maximum(own - 1, 1)      # D[i,i]=0: self drops out
    means = sums / counts[None, :]
    means[rows, inv] = np.inf                         # b: nearest OTHER cluster
    b = means.min(axis=1)
    mx = np.maximum(a, b)
    s = np.where((own > 1) & (mx > 0),
                 (b - a) / np.where(mx > 0, mx, 1.0), 0.0)
    return float(np.mean(s))


def best_k_by_silhouette(X: np.ndarray, k_range=range(3, 18), seed: int = 0):
    """Silhouette sweep (paper: K_util in [3, 17], optimum 3)."""
    scores = {}
    for k in k_range:
        if k >= len(X):
            break
        _, labels, _ = kmeans(X, k, seed=seed)
        scores[k] = silhouette_score(X, labels)
    best = max(scores, key=scores.get)
    return best, scores
