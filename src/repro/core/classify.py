"""Workload profiles + the Minos dual classifier (paper §4).

A ``WorkloadProfile`` is what one low-cost profiling run produces:
  * the filtered power trace at the profiled frequency (uncapped by default)
  * per-kernel (duration, sm_util, dram_util) -> duration-weighted app point
  * optionally, per-frequency scaling data {freq: FreqPoint} — available only
    for *reference* workloads (that is exactly the paper's premise: new
    workloads are profiled once, at the default clock).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

import numpy as np

from repro.core import spikes
from repro.core.clustering import (
    best_k_by_silhouette,
    cosine_distance_matrix,
    cut_k,
    kmeans,
    linkage,
)


@dataclass
class FreqPoint:
    freq: float                  # normalized cap (f / f_max)
    p90: float                   # 90th pct of power, relative to TDP
    p95: float
    p99: float
    mean_power: float            # relative to TDP
    exec_time: float             # seconds per iteration
    spike_vec: np.ndarray | None = None


@dataclass
class WorkloadProfile:
    name: str
    tdp: float
    power_trace: np.ndarray              # filtered, trimmed, at profile freq
    sm_util: float                       # duration-weighted app SM/MXU util
    dram_util: float                     # duration-weighted app HBM util
    exec_time: float                     # at profile freq
    scaling: dict[float, FreqPoint] = field(default_factory=dict)
    domain: str = ""

    def spike_vec(self, bin_size: float) -> np.ndarray:
        return spikes.spike_vector(self.power_trace, self.tdp, bin_size)

    def p_quantile(self, q: float) -> float:
        return spikes.p_quantile(self.power_trace, self.tdp, q)

    @property
    def mean_power(self) -> float:
        return spikes.mean_power_rel(self.power_trace, self.tdp)

    @property
    def util_point(self) -> np.ndarray:
        return np.array([self.dram_util, self.sm_util], np.float64)


def app_utilization(kernels: list[tuple[float, float, float]]) -> tuple[float, float]:
    """Duration-weighted (sm, dram) utilization from per-kernel rows
    (duration, sm_util, dram_util) — paper Eq. (1)/(2)."""
    t = np.array([k[0] for k in kernels], np.float64)
    sm = np.array([k[1] for k in kernels], np.float64)
    dr = np.array([k[2] for k in kernels], np.float64)
    tot = t.sum()
    if tot <= 0:
        return 0.0, 0.0
    return float((t * sm).sum() / tot), float((t * dr).sum() / tot)


class MinosClassifier:
    """Power-spike (hierarchical/cosine) + utilization (K-Means) classifier."""

    def __init__(self, references: list[WorkloadProfile], bin_size: float = 0.1):
        if not references:
            raise ValueError("empty reference set")
        self.references = list(references)
        self.bin_size = bin_size

    # -- power side -----------------------------------------------------
    def spike_matrix(self, bin_size: float | None = None) -> np.ndarray:
        c = bin_size or self.bin_size
        return np.stack([r.spike_vec(c) for r in self.references])

    def power_linkage(self, bin_size: float | None = None) -> np.ndarray:
        D = cosine_distance_matrix(self.spike_matrix(bin_size))
        return linkage(D, method="ward")

    def power_classes(self, k: int = 3, bin_size: float | None = None) -> np.ndarray:
        """Dendrogram slice for interpretation only (predictions use NN)."""
        return cut_k(self.power_linkage(bin_size), k)

    def power_neighbor(self, target: WorkloadProfile,
                       bin_size: float | None = None,
                       exclude: str | None = None) -> tuple[WorkloadProfile, float]:
        c = bin_size or self.bin_size
        v = target.spike_vec(c)
        best, best_d = None, np.inf
        for r in self.references:
            if r.name == target.name or r.name == exclude:
                continue
            d = _cosine_distance(v, r.spike_vec(c))
            if d < best_d:
                best, best_d = r, d
        return best, float(best_d)

    # -- utilization side -------------------------------------------------
    def util_matrix(self) -> np.ndarray:
        return np.stack([r.util_point for r in self.references])

    def util_classes(self, k: int | None = None, seed: int = 0):
        X = self.util_matrix()
        if k is None:
            k, scores = best_k_by_silhouette(X, seed=seed)
        else:
            scores = None
        centers, labels, _ = kmeans(X, k, seed=seed)
        return labels, centers, k, scores

    def util_neighbor(self, target: WorkloadProfile,
                      exclude: str | None = None) -> tuple[WorkloadProfile, float]:
        v = target.util_point
        best, best_d = None, np.inf
        for r in self.references:
            if r.name == target.name or r.name == exclude:
                continue
            d = float(np.linalg.norm(v - r.util_point))
            if d < best_d:
                best, best_d = r, d
        return best, best_d


def _cosine_distance(a: np.ndarray, b: np.ndarray) -> float:
    na, nb = np.linalg.norm(a), np.linalg.norm(b)
    if na == 0 or nb == 0:
        return 1.0
    return float(1.0 - np.clip(np.dot(a, b) / (na * nb), -1.0, 1.0))
