"""Workload profiles + the Minos dual classifier (paper §4).

A ``WorkloadProfile`` is what one low-cost profiling run produces:
  * the filtered power trace at the profiled frequency (uncapped by default)
  * per-kernel (duration, sm_util, dram_util) -> duration-weighted app point
  * optionally, per-frequency scaling data {freq: FreqPoint} — available only
    for *reference* workloads (that is exactly the paper's premise: new
    workloads are profiled once, at the default clock).

``MinosClassifier`` owns the reference set: it caches the reference spike
matrix per bin size and the utilization matrix, and answers nearest-neighbor
queries in batch (``power_neighbors`` / ``util_neighbors``) as single
(n_targets, n_refs) distance-matrix ops — the engine behind Algorithm 1 and
the hold-one-out benchmarks.
"""
from __future__ import annotations

import json
import numbers
from dataclasses import dataclass, field

import numpy as np

from repro.core import spikes
from repro.core.clustering import (
    best_k_by_silhouette,
    cosine_distance_matrix,
    cut_k,
    kmeans,
    linkage,
)


@dataclass
class FreqPoint:
    freq: float                  # normalized cap (f / f_max)
    p90: float                   # 90th pct of power, relative to TDP
    p95: float
    p99: float
    mean_power: float            # relative to TDP
    exec_time: float             # seconds per iteration
    spike_vec: np.ndarray | None = None


@dataclass
class WorkloadProfile:
    name: str
    tdp: float
    power_trace: np.ndarray              # filtered, trimmed, at profile freq
    sm_util: float                       # duration-weighted app SM/MXU util
    dram_util: float                     # duration-weighted app HBM util
    exec_time: float                     # at profile freq
    scaling: dict[float, FreqPoint] = field(default_factory=dict)
    domain: str = ""

    def spike_vec(self, bin_size: float) -> np.ndarray:
        return spikes.spike_vector(self.power_trace, self.tdp, bin_size)

    def p_quantile(self, q: float) -> float:
        # the trace is immutable after construction and the online path asks
        # every reference for the same quantile on every classify (the
        # choose_bin_size sweep) — memoize per q, like PartialProfile's
        # spike-vector memo.  First call computes, later calls return the
        # identical float, so decisions are unchanged bit-for-bit.
        cache = self.__dict__.setdefault("_pq_memo", {})
        q = float(q)
        if q not in cache:
            cache[q] = spikes.p_quantile(self.power_trace, self.tdp, q)
        return cache[q]

    @property
    def mean_power(self) -> float:
        return spikes.mean_power_rel(self.power_trace, self.tdp)

    @property
    def util_point(self) -> np.ndarray:
        return np.array([self.dram_util, self.sm_util], np.float64)


def app_utilization(kernels: list[tuple[float, float, float]]) -> tuple[float, float]:
    """Duration-weighted (sm, dram) utilization from per-kernel rows
    (duration, sm_util, dram_util) — paper Eq. (1)/(2)."""
    t = np.array([k[0] for k in kernels], np.float64)
    sm = np.array([k[1] for k in kernels], np.float64)
    dr = np.array([k[2] for k in kernels], np.float64)
    tot = t.sum()
    if tot <= 0:
        return 0.0, 0.0
    return float((t * sm).sum() / tot), float((t * dr).sum() / tot)


class MinosClassifier:
    """Power-spike (hierarchical/cosine) + utilization (K-Means) classifier.

    The classifier treats its reference set as immutable after construction
    and memoizes the expensive per-reference features:

      * ``spike_matrix(c)`` — the (n_refs, n_bins) stack of spike vectors —
        is cached per bin size, so a ``choose_bin_size`` sweep or a
        hold-one-out benchmark histograms each reference trace once per c
        instead of once per query.
      * ``util_matrix()`` — the (n_refs, 2) utilization points — is cached
        outright.

    Nearest-neighbor queries come in batched form (``power_neighbors`` /
    ``util_neighbors``): all target-vs-reference distances are computed as a
    single (n_targets, n_refs) matrix op, with self-matches (same workload
    name) and an optional ``exclude`` name masked out.  The scalar
    ``power_neighbor`` / ``util_neighbor`` wrappers are one-target batches.
    """

    def __init__(self, references: list[WorkloadProfile], bin_size: float = 0.1,
                 spike_cache: dict[float, np.ndarray] | None = None):
        """``spike_cache`` warm-starts the per-bin-size spike matrices (e.g.
        from ``pipeline.ReferenceLibrary``'s persisted cache) so construction
        skips re-histogramming every reference trace; each matrix must be
        (n_refs, num_bins(c)) and row-aligned with ``references``."""
        if not references:
            raise ValueError("empty reference set")
        self.references = list(references)
        self.bin_size = self._validate_bin(bin_size)
        self._ref_names = np.array([r.name for r in self.references])
        self._spike_cache: dict[float, np.ndarray] = {}
        self._util_cache: np.ndarray | None = None
        for c, M in (spike_cache or {}).items():
            c = self._validate_bin(c)
            M = np.asarray(M, np.float64)
            want = (len(self.references), spikes.num_bins(c))
            if M.shape != want:
                raise ValueError(
                    f"spike_cache[{c}] has shape {M.shape}, expected {want}")
            self._spike_cache[c] = M

    @staticmethod
    def _validate_bin(c) -> float:
        if isinstance(c, bool) or not isinstance(c, numbers.Real) or not c > 0:
            raise ValueError(f"bin_size must be a positive number, got {c!r}")
        return float(c)

    def _resolve_bin(self, bin_size: float | None) -> float:
        return self.bin_size if bin_size is None else self._validate_bin(bin_size)

    # -- power side -----------------------------------------------------
    def spike_matrix(self, bin_size: float | None = None) -> np.ndarray:
        """(n_refs, n_bins) reference spike vectors, cached per bin size."""
        c = self._resolve_bin(bin_size)
        M = self._spike_cache.get(c)
        if M is None:
            M = np.stack([r.spike_vec(c) for r in self.references])
            self._spike_cache[c] = M
        return M

    def power_linkage(self, bin_size: float | None = None) -> np.ndarray:
        D = cosine_distance_matrix(self.spike_matrix(bin_size))
        return linkage(D, method="ward")

    def power_classes(self, k: int = 3, bin_size: float | None = None) -> np.ndarray:
        """Dendrogram slice for interpretation only (predictions use NN)."""
        return cut_k(self.power_linkage(bin_size), k)

    def power_neighbors(self, targets: list[WorkloadProfile],
                        bin_size: float | None = None,
                        exclude: str | None = None
                        ) -> list[tuple[WorkloadProfile, float]]:
        """Nearest reference by cosine distance, for a batch of targets.

        One (n_targets, n_refs) distance matrix; per-target self-exclusion
        by workload name plus the optional shared ``exclude`` name.  Raises
        ``ValueError`` if some target has every reference excluded.
        """
        D = self._power_distances(targets, bin_size)
        return self._pick(D, targets, exclude)

    def power_neighbor(self, target: WorkloadProfile,
                       bin_size: float | None = None,
                       exclude: str | None = None) -> tuple[WorkloadProfile, float]:
        return self.power_neighbors([target], bin_size, exclude)[0]

    def power_top2(self, targets: list[WorkloadProfile],
                   bin_size: float | None = None,
                   exclude: str | None = None
                   ) -> list[tuple[WorkloadProfile, float, float]]:
        """Like ``power_neighbors`` but with the runner-up distance: returns
        ``(best_ref, d_best, d_second)`` per target.  ``d_second`` is ``inf``
        when only one reference is eligible — the margin signal the online
        cap controller turns into a confidence score."""
        idx, best, second = self._top2(targets, bin_size, exclude)
        return [(self.references[i], float(d1), float(d2))
                for i, d1, d2 in zip(idx, best, second)]

    def power_neighbors_idx(self, targets: list[WorkloadProfile],
                            bin_size: float | None = None,
                            exclude: str | None = None
                            ) -> tuple[np.ndarray, np.ndarray]:
        """Allocation-light twin of ``power_neighbors`` for fleet-scale
        batches: the nearest reference per target as parallel ``(index,
        distance)`` arrays instead of ``(ref, float)`` tuples.  Row values
        are bit-identical to ``power_neighbors``."""
        D = self._mask(self._power_distances(targets, bin_size), targets,
                       exclude)
        return self._argbest(D, targets, exclude)

    def power_top2_idx(self, targets: list[WorkloadProfile],
                       bin_size: float | None = None,
                       exclude: str | None = None
                       ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Array-form ``power_top2``: ``(index, d_best, d_second)``."""
        return self._top2(targets, bin_size, exclude)

    def power_sweep(self, targets: list[WorkloadProfile], bin_sizes,
                    exclude: str | None = None, second: bool = True
                    ) -> list[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Fused bin-size sweep for fleet batches: for every candidate bin
        size, the nearest reference ``(index, d_best)`` plus the runner-up
        ``d_second``, sharing one exclusion mask across candidates and one
        distance matrix per candidate.  Each entry is bit-identical to a
        ``power_top2_idx(targets, bin_size=c)`` call — the distances come
        from the same ``_power_distances`` matrix, just not recomputed.

        With ``second=False`` the third element is the *masked distance
        matrix itself* instead of the runner-up column: callers that only
        need ``d_second`` at one chosen bin size per row (the online margin
        path) can partition just those rows — each row of the matrix is
        untouched, so a sliced partition is bit-identical."""
        names = np.array([t.name for t in targets])
        masked = self._ref_names[None, :] == names[:, None]
        if exclude is not None:
            masked |= self._ref_names[None, :] == exclude
        # targets minted by one BatchProfileEngine snapshot/finalize batch
        # carry a shared memo matrix per bin size: gather their rows with one
        # fancy index instead of a per-target Python stack (identical rows)
        shared = None
        mats = targets[0].__dict__.get("_spike_mat") if targets else None
        if mats is not None:
            refs = [t.__dict__.get("_spike_mat") for t in targets]
            if all(r is not None and r[0] is mats[0] for r in refs):
                shared = (mats[0],
                          np.array([r[1] for r in refs], np.int64))
        out = []
        for c in bin_sizes:
            c = float(c)
            if shared is not None and c in shared[0]:
                D = _cosine_distances(shared[0][c][shared[1]],
                                      self.spike_matrix(c))
            else:
                D = self._power_distances(targets, c)
            D = np.where(masked, np.inf, D)
            idx, best = self._argbest(D, targets, exclude)
            if not second:
                out.append((idx, best, D))
            elif D.shape[1] > 1:
                out.append((idx, best, np.partition(D, 1, axis=1)[:, 1]))
            else:
                out.append((idx, best, np.full(len(targets), np.inf)))
        return out

    def _top2(self, targets, bin_size, exclude):
        D = self._mask(self._power_distances(targets, bin_size), targets,
                       exclude)
        idx, best = self._argbest(D, targets, exclude)
        if D.shape[1] > 1:
            second = np.partition(D, 1, axis=1)[:, 1]
        else:
            second = np.full(len(targets), np.inf)
        return idx, best, second

    def _power_distances(self, targets: list[WorkloadProfile],
                         bin_size: float | None) -> np.ndarray:
        """(n_targets, n_refs) cosine distances on spike vectors, reusing the
        cached reference matrix on both sides for hold-one-out batches."""
        c = self._resolve_bin(bin_size)
        if self._is_reference_batch(targets):
            T = self.spike_matrix(c)           # hold-one-out: reuse the cache
        else:
            T = np.stack([t.spike_vec(c) for t in targets])
        return _cosine_distances(T, self.spike_matrix(c))

    # -- utilization side -------------------------------------------------
    def util_matrix(self) -> np.ndarray:
        """(n_refs, 2) [dram_util, sm_util] reference points, cached."""
        if self._util_cache is None:
            self._util_cache = np.stack([r.util_point for r in self.references])
        return self._util_cache

    def util_classes(self, k: int | None = None, seed: int = 0):
        X = self.util_matrix()
        if k is None:
            k, scores = best_k_by_silhouette(X, seed=seed)
        else:
            scores = None
        centers, labels, _ = kmeans(X, k, seed=seed)
        return labels, centers, k, scores

    def util_neighbors(self, targets: list[WorkloadProfile],
                       exclude: str | None = None
                       ) -> list[tuple[WorkloadProfile, float]]:
        """Nearest reference by Euclidean distance in utilization space, for
        a batch of targets (one (n_targets, n_refs) matrix op; exclusion
        semantics as in ``power_neighbors``)."""
        return self._pick(self._util_distances(targets), targets, exclude)

    def util_neighbors_idx(self, targets: list[WorkloadProfile],
                           exclude: str | None = None
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Array-form ``util_neighbors``: ``(index, distance)`` arrays."""
        D = self._mask(self._util_distances(targets), targets, exclude)
        return self._argbest(D, targets, exclude)

    def _util_distances(self, targets: list[WorkloadProfile]) -> np.ndarray:
        if self._is_reference_batch(targets):
            T = self.util_matrix()
        else:
            # same values as stacking each target's util_point, without the
            # per-target array construction
            T = np.array([(t.dram_util, t.sm_util) for t in targets],
                         np.float64).reshape(-1, 2)
        diff = T[:, None, :] - self.util_matrix()[None, :, :]
        return np.sqrt(np.sum(diff * diff, axis=-1))

    def util_neighbor(self, target: WorkloadProfile,
                      exclude: str | None = None) -> tuple[WorkloadProfile, float]:
        return self.util_neighbors([target], exclude)[0]

    # -- shared ----------------------------------------------------------
    def _is_reference_batch(self, targets: list[WorkloadProfile]) -> bool:
        """True when the target batch is exactly the reference set (the
        hold-one-out pattern), so cached feature matrices can stand in for
        the target-side stack."""
        return len(targets) == len(self.references) and \
            all(t is r for t, r in zip(targets, self.references))

    def _mask(self, D: np.ndarray, targets: list[WorkloadProfile],
              exclude: str | None) -> np.ndarray:
        # fixed-width string dtype (not object) keeps the comparison a C
        # broadcast — same booleans, no per-cell Python equality at fleet
        # batch sizes
        masked = self._ref_names[None, :] == \
            np.array([t.name for t in targets])[:, None]
        if exclude is not None:
            masked |= self._ref_names[None, :] == exclude
        return np.where(masked, np.inf, D)

    @staticmethod
    def _check_eligible(best: np.ndarray, targets: list[WorkloadProfile],
                        exclude: str | None) -> None:
        if np.any(np.isinf(best)):
            bad = targets[int(np.nonzero(np.isinf(best))[0][0])].name
            raise ValueError(
                f"no eligible reference for target {bad!r}: every reference "
                f"is excluded (self-match or exclude={exclude!r})")

    def _argbest(self, D: np.ndarray, targets: list[WorkloadProfile],
                 exclude: str | None) -> tuple[np.ndarray, np.ndarray]:
        idx = np.argmin(D, axis=1)
        best = D[np.arange(len(targets)), idx]
        self._check_eligible(best, targets, exclude)
        return idx, best

    def _pick(self, D: np.ndarray, targets: list[WorkloadProfile],
              exclude: str | None) -> list[tuple[WorkloadProfile, float]]:
        idx, best = self._argbest(self._mask(D, targets, exclude), targets,
                                  exclude)
        return [(self.references[i], float(d)) for i, d in zip(idx, best)]


def _cosine_distances(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Pairwise cosine distances between the rows of A and of B; rows with
    zero norm are at distance 1 from everything (the seed convention).

    The dot products go through ``np.einsum`` rather than ``@``: einsum's
    per-row summation order does not depend on how many rows A has, so row i
    of a batched call is bit-identical to a one-row call — the property the
    fleet's batched classification relies on to stay byte-identical to the
    per-job path (BLAS matmul kernels do NOT guarantee this across shapes).
    """
    na = np.linalg.norm(A, axis=1)
    nb = np.linalg.norm(B, axis=1)
    Ua = A / np.where(na > 0, na, 1.0)[:, None]
    Ub = B / np.where(nb > 0, nb, 1.0)[:, None]
    D = 1.0 - np.clip(np.einsum("ik,jk->ij", Ua, Ub), -1.0, 1.0)
    D[na == 0, :] = 1.0
    D[:, nb == 0] = 1.0
    return D


def count_classifier_calls(clf: "MinosClassifier") -> dict:
    """Instrument ``clf`` in place to count its neighbor/margin queries
    (``power_neighbors`` / ``util_neighbors`` / ``power_top2``); returns a
    live ``{"n": count}`` dict.  This is the shared spy behind the
    zero-reclassification pins: repacks, retirements, budget changes, and
    every chaos-handling path (fail/degrade/restore/migrate) must leave the
    count unchanged (``tests/test_api.py``, ``tests/test_chaos.py``,
    ``benchmarks/bench_chaos.py``)."""
    calls = {"n": 0}
    for name in ("power_neighbors", "util_neighbors", "power_top2",
                 "power_neighbors_idx", "util_neighbors_idx",
                 "power_top2_idx", "power_sweep"):
        orig = getattr(clf, name)

        def wrapped(*a, _orig=orig, **k):
            calls["n"] += 1
            return _orig(*a, **k)

        setattr(clf, name, wrapped)
    return calls
