"""Power-trace processing + spike-distribution vectors (paper §4.1, §5.3.1).

Pipeline (exactly the paper's):
  1. instantaneous power from the energy accumulator: P_inst = de/dt
  2. EMA filter with alpha = 0.5
  3. trim idle head/tail via the busy-cycles counter
  4. spike detection at P >= 0.5*TDP, relative magnitude r = P/TDP
  5. bin r into [0.5, 2.0) with width c; normalize -> spike vector v
"""
from __future__ import annotations

import numpy as np

SPIKE_LO = 0.5
SPIKE_HI = 2.0


def power_from_energy(energy_counter: np.ndarray, sample_dt_s: float) -> np.ndarray:
    """P_inst ~= delta_e / delta_t from an accumulating energy counter (J)."""
    de = np.diff(energy_counter.astype(np.float64))
    return (de / sample_dt_s).astype(np.float64)


def ema_filter(power: np.ndarray, alpha: float = 0.5,
               backend: str | None = None) -> np.ndarray:
    """P_filt(t) = alpha*P(t) + (1-alpha)*P_filt(t-1)   (paper uses 0.5).

    The recurrence (filter state seeded with P(0)) is evaluated without a
    per-sample Python loop by prefix-doubling: with w = 1-alpha and
    c = alpha*P (c_0 = P_0, absorbing the seed state), the fixpoint of
    ``out[s:] += w^s * out[:-s]`` for s = 1, 2, 4, ... is exactly
    out_i = sum_j c_j w^(i-j) — O(n log n) vectorized NumPy ops, and the
    loop short-circuits once w^s underflows to 0 (s ~ 50 for alpha = 0.5).

    ``backend`` selects the implementation: ``"numpy"`` (float64 host path),
    ``"pallas"`` (the ``repro.kernels.ema_scan`` TPU scan kernel, float32),
    or ``None`` to autodetect — the kernel on a TPU backend, NumPy elsewhere.
    """
    power = np.asarray(power, np.float64)
    if backend not in (None, "numpy", "pallas"):
        raise ValueError(f"unknown ema backend {backend!r}")
    if len(power) == 0:
        return np.empty(0, np.float64)
    if backend == "pallas" or (backend is None and _on_tpu()):
        from repro.kernels.ops import ema_scan
        return np.asarray(ema_scan(power, alpha=alpha), np.float64)
    w = 1.0 - alpha
    out = alpha * power
    out[0] = power[0]
    shift, decay = 1, w
    while shift < len(out) and decay != 0.0:
        out[shift:] += decay * out[:-shift]
        shift *= 2
        decay *= decay
    return out


def _on_tpu() -> bool:
    try:
        import jax
        return jax.default_backend() == "tpu"
    except Exception:      # pragma: no cover - jax is always present here
        return False


def trim_idle(power: np.ndarray, busy: np.ndarray) -> np.ndarray:
    """Keep samples between the first and last non-zero busy-counter reading."""
    nz = np.nonzero(busy > 0)[0]
    if len(nz) == 0:
        return power[:0]
    return power[nz[0]:nz[-1] + 1]


def num_bins(bin_size: float) -> int:
    return int(round((SPIKE_HI - SPIKE_LO) / bin_size))


def spike_vector(power: np.ndarray, tdp: float, bin_size: float = 0.1) -> np.ndarray:
    """Normalized spike-magnitude distribution vector v (paper §4.1.1)."""
    r = np.asarray(power, np.float64) / tdp
    r = r[r >= SPIKE_LO]
    n = num_bins(bin_size)
    if len(r) == 0:
        return np.zeros(n)
    idx = np.clip(((r - SPIKE_LO) / bin_size).astype(np.int64), 0, n - 1)
    v = np.bincount(idx, minlength=n).astype(np.float64)
    return v / v.sum()


def spike_cdf(power: np.ndarray, tdp: float, grid: np.ndarray | None = None):
    """Cumulative power distribution relative to TDP (paper Figs. 2/5/6)."""
    r = np.sort(np.asarray(power, np.float64) / tdp)
    if grid is None:
        grid = np.linspace(0.0, SPIKE_HI, 201)
    cdf = np.searchsorted(r, grid, side="right") / max(len(r), 1)
    return grid, cdf


def p_quantile(power: np.ndarray, tdp: float, q: float = 90.0) -> float:
    """q-th percentile of power relative to TDP (p90/p95/p99 in the paper)."""
    if len(power) == 0:
        return 0.0
    return float(np.percentile(np.asarray(power, np.float64), q) / tdp)


def mean_power_rel(power: np.ndarray, tdp: float) -> float:
    """Mean power relative to TDP (the Guerreiro et al. feature)."""
    if len(power) == 0:
        return 0.0
    return float(np.mean(power) / tdp)
