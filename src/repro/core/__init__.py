"""Minos core: the paper's contribution (spike vectors, dual classification,
Algorithm 1 frequency selection, baselines)."""
from repro.core import spikes
from repro.core.algorithm1 import (FreqSelection, cap_perf_centric,
                                   cap_power_centric, choose_bin_size,
                                   profiling_savings, select_optimal_freq)
from repro.core.baselines import mean_power_neighbor, util_only_neighbor
from repro.core.classify import (FreqPoint, MinosClassifier, WorkloadProfile,
                                 app_utilization)
from repro.core.clustering import (best_k_by_silhouette, cosine_distance_matrix,
                                   cut, cut_k, dendrogram_order,
                                   euclidean_distance_matrix, kmeans, linkage,
                                   silhouette_score)
from repro.core.reference_store import load_profiles, save_profiles
