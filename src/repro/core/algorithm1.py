"""Algorithm 1 — SELECT_OPTIMAL_FREQ (paper §4.3).

Faithful implementation:
  ChooseBinSize     - offline argmin of p90 prediction error over candidates
  GetPwrNeighbor    - nearest reference by cosine distance on spike vectors
  GetUtilNeighbor   - nearest reference by Euclidean distance in util space
  CapPowerCentric   - highest frequency whose *neighbor* p90 spikes < 1.3*TDP
  CapPerfCentric    - lowest frequency whose *neighbor* perf loss <= 5%

The target workload contributes exactly ONE profile (at the uncapped clock);
all frequency-scaling information comes from the neighbor — that is the
paper's 89-90% profiling-time saving.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.classify import MinosClassifier, WorkloadProfile

DEFAULT_BIN_CANDIDATES = (0.05, 0.1, 0.15, 0.2, 0.25, 0.5)
POWER_BOUND = 1.3       # x TDP on p90 spikes (paper)
PERF_BOUND = 0.05       # 5% max degradation (paper, same as POLCA)


@dataclass
class FreqSelection:
    target: str
    bin_size: float
    power_neighbor: str
    power_distance: float
    util_neighbor: str
    util_distance: float
    f_pwr: float
    f_perf: float

    def cap(self, objective: str) -> float:
        return self.f_pwr if objective == "powercentric" else self.f_perf


@dataclass(frozen=True)
class ObjectivePolicy:
    """A pluggable capping objective: maps an Algorithm 1 ``FreqSelection``
    to the frequency cap it actuates.  The two paper objectives are builtin;
    custom policies register by name through ``repro.api.register_objective``
    and flow through the same controllers as the builtins."""
    name: str
    cap_fn: Callable[[FreqSelection], float] = field(compare=False)

    def cap(self, sel: FreqSelection) -> float:
        return self.cap_fn(sel)


POWERCENTRIC = ObjectivePolicy("powercentric", lambda sel: sel.f_pwr)
PERFCENTRIC = ObjectivePolicy("perfcentric", lambda sel: sel.f_perf)
_BUILTIN_OBJECTIVES = {p.name: p for p in (POWERCENTRIC, PERFCENTRIC)}


def resolve_objective(objective) -> ObjectivePolicy:
    """Resolve a builtin objective name or an ``ObjectivePolicy``-like object
    (``.name`` + ``.cap(selection)``) to an ``ObjectivePolicy``.

    Strings only resolve the two builtins here — custom objectives are
    registered by name in ``repro.api.OBJECTIVES`` and must be resolved
    through that registry (the session facade does this) so the core layer
    stays independent of the plugin namespace."""
    if isinstance(objective, ObjectivePolicy):
        return objective
    if isinstance(objective, str):
        try:
            return _BUILTIN_OBJECTIVES[objective]
        except KeyError:
            raise ValueError(
                f"unknown objective {objective!r} (builtins: "
                f"{', '.join(sorted(_BUILTIN_OBJECTIVES))}; custom objectives "
                f"resolve by name through repro.api.OBJECTIVES)") from None
    name = getattr(objective, "name", None)
    if name and callable(getattr(objective, "cap", None)):
        return ObjectivePolicy(str(name), objective.cap)
    raise ValueError(f"objective must be a builtin name or an "
                     f"ObjectivePolicy-like object, got {objective!r}")


def choose_bin_size(target: WorkloadProfile, clf: MinosClassifier,
                    candidates=DEFAULT_BIN_CANDIDATES,
                    quantile: float = 90.0) -> float:
    """Err_c(T) = |p90(T) - p90(NN_c(T))| at the profiled frequency (§7.4).

    Each candidate bin size hits the classifier's cached spike matrix, so a
    sweep re-histograms the target once per c but the references only once
    per c *per classifier lifetime* (not per call).
    """
    best_c, best_err = candidates[0], np.inf
    p_t = target.p_quantile(quantile)
    for c in candidates:
        (nn, _), = clf.power_neighbors([target], bin_size=c)
        err = abs(p_t - nn.p_quantile(quantile))
        if err < best_err:
            best_c, best_err = c, err
    return best_c


def cap_power_centric(neighbor: WorkloadProfile, bound: float = POWER_BOUND,
                      quantile: str = "p90") -> float:
    """Highest frequency cap keeping the neighbor's p90 spikes under bound."""
    freqs = sorted(neighbor.scaling, reverse=True)
    for f in freqs:
        if getattr(neighbor.scaling[f], quantile) < bound:
            return f
    return freqs[-1] if freqs else 1.0


def cap_perf_centric(neighbor: WorkloadProfile, bound: float = PERF_BOUND) -> float:
    """Lowest frequency cap keeping the neighbor's degradation within bound."""
    freqs = sorted(neighbor.scaling)
    if not freqs:
        return 1.0
    base = neighbor.scaling[max(freqs)].exec_time
    for f in freqs:
        degr = neighbor.scaling[f].exec_time / base - 1.0
        if degr <= bound:
            return f
    return max(freqs)


def select_optimal_freq(target: WorkloadProfile, clf: MinosClassifier,
                        bin_candidates=DEFAULT_BIN_CANDIDATES) -> FreqSelection:
    c_star = choose_bin_size(target, clf, bin_candidates)
    (r_pwr, d_pwr), = clf.power_neighbors([target], bin_size=c_star)
    (r_util, d_util), = clf.util_neighbors([target])
    return FreqSelection(
        target=target.name,
        bin_size=c_star,
        power_neighbor=r_pwr.name,
        power_distance=d_pwr,
        util_neighbor=r_util.name,
        util_distance=d_util,
        f_pwr=cap_power_centric(r_pwr),
        f_perf=cap_perf_centric(r_util),
    )


def profiling_savings(target: WorkloadProfile, freqs: list[float]) -> float:
    """1 - T_f0 / sum_f T_f  (paper §7.1.3): one profiled frequency vs a
    sweep; exec times taken from the target's true scaling data."""
    if not target.scaling:
        return 1.0 - 1.0 / max(len(freqs), 1)
    total = sum(target.scaling[f].exec_time for f in freqs if f in target.scaling)
    f0 = max(target.scaling)
    return 1.0 - target.scaling[f0].exec_time / total
