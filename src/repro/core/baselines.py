"""Baselines Minos is compared against (paper §7.3).

Guerreiro et al. [29] — the state of the art the paper beats — classifies
workloads by *mean power*; we implement its nearest-neighbor analogue
(closest mean relative power) with the same prediction protocol as Minos so
the comparison isolates the feature (mean power vs spike distribution).
"""
from __future__ import annotations

import numpy as np

from repro.core.classify import WorkloadProfile


def _check_eligible(best: WorkloadProfile | None, target: WorkloadProfile,
                    exclude: str | None) -> None:
    # same contract as MinosClassifier._check_eligible: an all-excluded
    # reference set is a ValueError, never a (None, inf) return that blows
    # up callers later with an AttributeError
    if best is None:
        raise ValueError(
            f"no eligible reference for target {target.name!r}: every "
            f"reference is excluded (self-match or exclude={exclude!r})")


def mean_power_neighbor(target: WorkloadProfile,
                        references: list[WorkloadProfile],
                        exclude: str | None = None
                        ) -> tuple[WorkloadProfile, float]:
    mt = target.mean_power
    best, best_d = None, np.inf
    for r in references:
        if r.name == target.name or r.name == exclude:
            continue
        d = abs(mt - r.mean_power)
        if d < best_d:
            best, best_d = r, d
    _check_eligible(best, target, exclude)
    return best, float(best_d)


def util_only_neighbor(target: WorkloadProfile,
                       references: list[WorkloadProfile],
                       exclude: str | None = None
                       ) -> tuple[WorkloadProfile, float]:
    """Performance-counter-only classification (no power signal)."""
    v = target.util_point
    best, best_d = None, np.inf
    for r in references:
        if r.name == target.name or r.name == exclude:
            continue
        d = float(np.linalg.norm(v - r.util_point))
        if d < best_d:
            best, best_d = r, d
    _check_eligible(best, target, exclude)
    return best, best_d
