"""Persistence for the Minos reference library (profiles + scaling data).

The framework ships a reference store built by `benchmarks/` from the
workload zoo; the launcher loads it to pick frequency caps for new jobs
(``launch/train.py --minos-cap``).
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.classify import FreqPoint, WorkloadProfile


def save_profiles(profiles: list[WorkloadProfile], directory: str) -> None:
    os.makedirs(directory, exist_ok=True)
    meta = {}
    arrays = {}
    for i, p in enumerate(profiles):
        key = f"trace_{i}"
        arrays[key] = np.asarray(p.power_trace, np.float32)
        meta[p.name] = {
            "trace_key": key,
            "tdp": p.tdp,
            "sm_util": p.sm_util,
            "dram_util": p.dram_util,
            "exec_time": p.exec_time,
            "domain": p.domain,
            "scaling": {
                str(f): {
                    "freq": fp.freq, "p90": fp.p90, "p95": fp.p95,
                    "p99": fp.p99, "mean_power": fp.mean_power,
                    "exec_time": fp.exec_time,
                }
                for f, fp in p.scaling.items()
            },
        }
    np.savez_compressed(os.path.join(directory, "traces.npz"), **arrays)
    with open(os.path.join(directory, "profiles.json"), "w") as f:
        json.dump(meta, f, indent=1)


def load_profiles(directory: str) -> list[WorkloadProfile]:
    with open(os.path.join(directory, "profiles.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(directory, "traces.npz"))
    out = []
    for name, m in meta.items():
        scaling = {
            float(f): FreqPoint(**fp) for f, fp in m["scaling"].items()
        }
        out.append(WorkloadProfile(
            name=name,
            tdp=m["tdp"],
            power_trace=data[m["trace_key"]].astype(np.float64),
            sm_util=m["sm_util"],
            dram_util=m["dram_util"],
            exec_time=m["exec_time"],
            scaling=scaling,
            domain=m.get("domain", ""),
        ))
    return out
