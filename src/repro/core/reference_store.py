"""DEPRECATED persistence shim — use ``repro.pipeline.ReferenceLibrary``.

The store's flat ``save_profiles``/``load_profiles`` functions have been
folded into the versioned ``ReferenceLibrary`` (which additionally persists
the fingerprinted spike-matrix cache for classifier warm starts and keeps
traces float64 so reloads are bit-exact).  These wrappers delegate there and
emit ``DeprecationWarning``; directories written by either API load with
either API — the library's reader tolerates stores without the
``library.json``/``spike_cache.npz`` sidecars (including pre-PR-2 float32
trace archives).
"""
from __future__ import annotations

import warnings

from repro.core.classify import WorkloadProfile


def save_profiles(profiles: list[WorkloadProfile], directory: str) -> None:
    warnings.warn(
        "repro.core.reference_store.save_profiles is deprecated; use "
        "repro.pipeline.ReferenceLibrary(profiles).save(directory)",
        DeprecationWarning, stacklevel=2)
    from repro.pipeline.library import ReferenceLibrary
    ReferenceLibrary(profiles).save(directory)


def load_profiles(directory: str) -> list[WorkloadProfile]:
    warnings.warn(
        "repro.core.reference_store.load_profiles is deprecated; use "
        "repro.pipeline.ReferenceLibrary.load(directory)",
        DeprecationWarning, stacklevel=2)
    from repro.pipeline.library import ReferenceLibrary
    return ReferenceLibrary.load(directory).profiles
