"""Canonical registry of journal record kinds.

Every record the write-ahead journal carries has a ``kind`` string; this
module is the ONE place those strings are defined.  Emitters
(``FleetCapController._journal``, ``MinosSession``'s store records) and the
resume dispatch (``MinosSession._apply_record``) both key on these
constants, so adding a record kind is a three-step contract:

  1. add the constant here (and to the matching group below);
  2. emit it write-ahead at the mutation site;
  3. handle it in ``MinosSession._apply_record`` (or add it to
     ``MARKER_KINDS`` if replay intentionally skips it).

``python -m repro.lint`` enforces the contract statically: the
record-exhaustiveness pass (rules W201/W202/W203) cross-checks every
emitted kind against this registry and the replay dispatch, failing CI on
emitted-but-unhandled kinds, dead handlers, and unregistered literals.

The values are wire format — they appear verbatim in ``journal.jsonl``
records and inside their sha256 checksums — so renaming one breaks every
existing store.  Add, never rename.
"""
from __future__ import annotations

# -- session lifecycle -----------------------------------------------------
OPEN = "open"            # session construction facts (always record #1)
RESUME = "resume"        # a resume happened (marker; never replayed)

# -- job lifecycle ---------------------------------------------------------
ADMIT = "admit"          # job admitted (device binding + trace context)
DECISION = "decision"    # cap decision landed, with its JobPlan
RETIRE = "retire"        # job retired; its plan left the packing
REPROFILE = "reprofile"  # profiling run restarted (post-migration)
CURSOR = "cursor"        # round-robin placement cursor advanced

# -- fleet control ---------------------------------------------------------
BUDGET = "budget"        # shared power budget changed
FAIL = "fail"            # device failed (jobs migrate/shrink/strand)
DEGRADE = "degrade"      # device degraded (decided jobs drain)
RESTORE = "restore"      # device restored to the placement pool
EVENT = "event"          # informational FleetEvent (regenerated on replay)

# -- online class discovery ------------------------------------------------
QUARANTINE = "quarantine"  # low-margin profile entered the quarantine pool
PROMOTE = "promote"        # library version promoted (profiles journaled)
ROLLBACK = "rollback"      # promotion rolled back to the N-1 version

#: kinds replay acknowledges but intentionally skips: ``OPEN`` is the
#: construction record ``resume`` consumes up front, ``EVENT`` records are
#: informational (the deterministic controller logic regenerates identical
#: events), and ``RESUME`` is a marker of a past recovery.
MARKER_KINDS = frozenset({OPEN, EVENT, RESUME})

#: every registered record kind (the exhaustiveness pass's universe).
ALL_KINDS = frozenset({
    OPEN, RESUME, ADMIT, DECISION, RETIRE, REPROFILE, CURSOR,
    BUDGET, FAIL, DEGRADE, RESTORE, EVENT,
    QUARANTINE, PROMOTE, ROLLBACK,
})
