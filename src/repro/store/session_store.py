"""SessionStore: one directory = one durable session.

Layout::

    <store>/
        journal.jsonl            append-only write-ahead event journal
        journal-<k>.jsonl        sealed journal segments (rotation)
        journal.base.json        compaction base (folded-segment floor)
        snapshot-<seq>.json      checksummed state snapshots (latest 2 kept)

The store is codec-agnostic: callers hand it an ``encode`` callable (the
session passes ``repro.api.results.to_dict``) so ``repro.store`` never
imports ``repro.api`` — payloads are encoded to JSON-ready dicts at record
time and handed back verbatim on recovery.

Snapshot cadence is record-count based (``snapshot_every``).  Writing a
snapshot synchronously inside :meth:`record` would capture state *before*
the just-journaled mutation applies, so reaching the cadence only marks a
snapshot as *due*; the session calls :meth:`flush_snapshot` after each
completed mutation, at which point the captured state includes everything
up to ``journal.last_seq``.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

from .journal import JOURNAL_FILE, EventJournal, JournalRecord
from .snapshots import SnapshotStore

SNAPSHOT_EVERY = 25              # journal records between snapshots
ROTATE_EVERY = 10_000            # journal records per sealed segment


class StoreError(RuntimeError):
    """A session store could not be opened (distinct from 'no store')."""


class NoStoreError(StoreError):
    """The path holds no session store at all (nothing to resume)."""


def _identity(obj):
    return obj


class SessionStore:
    """Write-ahead journal + snapshot cadence for one session directory."""

    def __init__(self, path: str, *, encode=None, fsync: bool = False,
                 snapshot_every: int = SNAPSHOT_EVERY,
                 rotate_every: int | None = ROTATE_EVERY,
                 compact_every: int | None = None):
        self.path = path
        self.encode = encode or _identity
        self.capture = None          # zero-arg state capture (session-set)
        self.snapshot_every = max(int(snapshot_every), 1)
        self.rotate_every = int(rotate_every) if rotate_every else None
        self.compact_every = int(compact_every) if compact_every else None
        self.snapshots = SnapshotStore(path, fsync=fsync)
        self.journal: EventJournal | None = None
        self._recovered: list[JournalRecord] = []
        self._since_snapshot = 0
        self._snapshot_due = False
        self._since_compact = 0
        self._fsync = bool(fsync)

    # -- opening ---------------------------------------------------------
    @classmethod
    def create(cls, path: str, *, encode=None, fsync: bool = False,
               snapshot_every: int = SNAPSHOT_EVERY,
               rotate_every: int | None = ROTATE_EVERY,
               compact_every: int | None = None) -> "SessionStore":
        """Open ``path`` for a NEW session, extending any existing journal."""
        store = cls(path, encode=encode, fsync=fsync,
                    snapshot_every=snapshot_every, rotate_every=rotate_every,
                    compact_every=compact_every)
        journal_path = os.path.join(path, JOURNAL_FILE)
        if os.path.exists(journal_path) \
                or EventJournal.segments(journal_path) \
                or os.path.exists(EventJournal.base_path(journal_path)):
            store.journal, store._recovered = EventJournal.open_existing(
                journal_path, fsync=fsync, rotate_every=store.rotate_every)
        else:
            store.journal = EventJournal(journal_path, fsync=fsync,
                                         rotate_every=store.rotate_every)
        return store

    @classmethod
    def open_existing(cls, path: str, *, encode=None, fsync: bool = False,
                      snapshot_every: int = SNAPSHOT_EVERY,
                      rotate_every: int | None = ROTATE_EVERY,
                      compact_every: int | None = None) \
            -> "SessionStore":
        """Open ``path`` for resume.  Raises :class:`NoStoreError` when the
        path holds no store at all, :class:`StoreError` when a store exists
        but every record in it is damaged beyond recovery."""
        journal_path = os.path.join(path, JOURNAL_FILE)
        if not os.path.isdir(path) or not (
                os.path.exists(journal_path)
                or EventJournal.segments(journal_path)
                or os.path.exists(EventJournal.base_path(journal_path))):
            raise NoStoreError(
                f"no session store at {path!r}: the directory "
                f"{'exists but ' if os.path.isdir(path) else 'does not exist and '}"
                f"holds no {JOURNAL_FILE}. Pass the directory given as the "
                f"'store' config key of the session you want to resume.")
        store = cls(path, encode=encode, fsync=fsync,
                    snapshot_every=snapshot_every, rotate_every=rotate_every,
                    compact_every=compact_every)
        store.journal, store._recovered = EventJournal.open_existing(
            journal_path, fsync=fsync, rotate_every=store.rotate_every)
        # a fully-compacted store legitimately has zero loose records — its
        # state lives in the snapshot the base floor points at
        if not store._recovered and store.journal.base is None:
            raise StoreError(
                f"session store at {path!r} is corrupt: {JOURNAL_FILE} "
                f"exists but contains no intact records. The session cannot "
                f"be reconstructed; start fresh with "
                f"from_config({{'store': ...}}) on a new directory.")
        return store

    # -- recovered state -------------------------------------------------
    @property
    def recovered_records(self) -> list[JournalRecord]:
        """Every intact journal record found when the store was opened."""
        return self._recovered

    def records(self, after_seq: int = 0) -> list[JournalRecord]:
        """Recovered records with ``seq > after_seq`` (the replay tail)."""
        return [r for r in self._recovered if r.seq > after_seq]

    def load_snapshot(self) -> tuple[dict | None, int]:
        """Latest usable snapshot ``(state, seq)``; ``(None, 0)`` if none.
        Snapshots past the recovered journal tip (describing state a
        truncated journal can no longer reach) are skipped."""
        state, seq = self.snapshots.load_latest(
            max_seq=self.journal.last_seq if self.journal else None)
        base = self.journal.base if self.journal else None
        if state is None and base is not None and base["base_seq"] > 0:
            # compaction removed the records before the base floor; without
            # an intact snapshot at/under the tip there is nothing to
            # replay them from
            raise StoreError(
                f"session store at {self.path!r} was compacted through seq "
                f"{base['base_seq']} but no intact snapshot survives; the "
                f"folded records cannot be reconstructed.")
        return state, seq

    def open_record(self) -> JournalRecord | None:
        """The session's ``open`` record — the first journal record on an
        uncompacted store, or the copy preserved in the compaction base
        once the segment that held it has been folded away."""
        base = self.journal.base if self.journal else None
        if base is not None and base.get("open") is not None:
            o = base["open"]
            return JournalRecord(seq=int(o["seq"]), ts=float(o["ts"]),
                                 kind=o["kind"], data=o["data"])
        if self._recovered:
            return self._recovered[0]
        return None

    # -- writing ---------------------------------------------------------
    def record(self, kind: str, **data) -> int:
        """Journal one event (write-ahead: call BEFORE applying the
        mutation).  Payload values pass through ``encode``."""
        seq = self.journal.append(kind, {k: self.encode(v)
                                         for k, v in data.items()})
        self._since_snapshot += 1
        self._since_compact += 1
        if self._since_snapshot >= self.snapshot_every:
            self._snapshot_due = True
        return seq

    @contextmanager
    def batch(self):
        """Coalesce journal flushes across one fleet tick (see
        ``EventJournal.batch``): records inside the block land in append
        order but share one flush at exit.  ``fsync=True`` stores keep
        per-record durability.  Snapshots written mid-batch are safe — a
        crash that tears the unflushed journal tail truncates it on
        recovery, and :meth:`load_snapshot` already skips snapshots past
        the recovered tip."""
        if self.journal is None:
            yield self
            return
        with self.journal.batch():
            yield self

    def flush_snapshot(self, capture=None, force: bool = False) -> bool:
        """Write a snapshot if one is due (or ``force``).  ``capture`` is a
        zero-arg callable returning the JSON-ready session state (defaults
        to the attached ``self.capture``); it runs only when a snapshot is
        actually written.  With no capture available the due flag persists,
        so the next flush with one still writes."""
        capture = capture if capture is not None else self.capture
        if not (self._snapshot_due or force) or capture is None:
            return False
        self.snapshots.write(capture(), self.journal.last_seq)
        self._since_snapshot = 0
        self._snapshot_due = False
        if self.compact_every and self._since_compact >= self.compact_every:
            self._since_compact = 0
            self.compact(capture=capture)
        return True

    # -- compaction ------------------------------------------------------
    def compact(self, capture=None) -> int:
        """Fold sealed journal segments fully covered by the retained
        snapshots into the compaction base and remove them; returns the
        number of segments folded (0 when nothing is safely foldable).

        Safety rule: a segment folds only when *every* retained intact
        snapshot sits at or past its last record — restoring ANY surviving
        snapshot (including the N-1 fallback) then never needs the folded
        records.  The base file is written before the segments are
        removed, so a crash between the two leaves skippable leftovers.
        The session's ``open`` record is preserved inside the base."""
        journal = self.journal
        if journal is None:
            return 0
        journal_path = journal.path
        base = journal.base
        base_seq = base["base_seq"] if base else 0
        folded_k = base["through_segment"] if base else 0
        # sweep compaction leftovers from a prior crash (base written,
        # removal interrupted)
        for k, seg in EventJournal.segments(journal_path):
            if k <= folded_k:
                os.remove(seg)
        cap = capture if capture is not None else self.capture
        if cap is not None and journal.last_seq > base_seq:
            # a fresh snapshot at the tip maximizes how much can fold
            self.snapshots.write(cap(), journal.last_seq)
            self._since_snapshot = 0
            self._snapshot_due = False
        intact = self.snapshots.intact_seqs(max_seq=journal.last_seq)
        if len(intact) < 2:
            return 0                 # keep the N-1 fallback replayable
        floor = min(intact)          # oldest retained snapshot's seq
        open_rec = base["open"] if base else None
        folded: list[tuple[int, str]] = []
        after = base_seq
        for k, seg in EventJournal.segments(journal_path):
            recs, good = EventJournal._scan(seg, after)
            if not recs or good < os.path.getsize(seg):
                break                # damaged segment: leave for recovery
            if recs[-1].seq > floor:
                break                # still needed by the oldest snapshot
            if open_rec is None:
                for r in recs:
                    if r.kind == "open":
                        open_rec = {"seq": r.seq, "ts": r.ts,
                                    "kind": r.kind, "data": r.data}
                        break
            after = recs[-1].seq
            folded.append((k, seg))
        if not folded:
            return 0
        journal.base = EventJournal.write_base(
            journal_path, base_seq=after, through_segment=folded[-1][0],
            open_record=open_rec, fsync=self._fsync)
        for _, seg in folded:
            os.remove(seg)
        return len(folded)

    def close(self) -> None:
        if self.journal is not None:
            self.journal.close()
