"""SessionStore: one directory = one durable session.

Layout::

    <store>/
        journal.jsonl            append-only write-ahead event journal
        snapshot-<seq>.json      checksummed state snapshots (latest 2 kept)

The store is codec-agnostic: callers hand it an ``encode`` callable (the
session passes ``repro.api.results.to_dict``) so ``repro.store`` never
imports ``repro.api`` — payloads are encoded to JSON-ready dicts at record
time and handed back verbatim on recovery.

Snapshot cadence is record-count based (``snapshot_every``).  Writing a
snapshot synchronously inside :meth:`record` would capture state *before*
the just-journaled mutation applies, so reaching the cadence only marks a
snapshot as *due*; the session calls :meth:`flush_snapshot` after each
completed mutation, at which point the captured state includes everything
up to ``journal.last_seq``.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

from .journal import JOURNAL_FILE, EventJournal, JournalRecord
from .snapshots import SnapshotStore

SNAPSHOT_EVERY = 25              # journal records between snapshots
ROTATE_EVERY = 10_000            # journal records per sealed segment


class StoreError(RuntimeError):
    """A session store could not be opened (distinct from 'no store')."""


class NoStoreError(StoreError):
    """The path holds no session store at all (nothing to resume)."""


def _identity(obj):
    return obj


class SessionStore:
    """Write-ahead journal + snapshot cadence for one session directory."""

    def __init__(self, path: str, *, encode=None, fsync: bool = False,
                 snapshot_every: int = SNAPSHOT_EVERY,
                 rotate_every: int | None = ROTATE_EVERY):
        self.path = path
        self.encode = encode or _identity
        self.capture = None          # zero-arg state capture (session-set)
        self.snapshot_every = max(int(snapshot_every), 1)
        self.rotate_every = int(rotate_every) if rotate_every else None
        self.snapshots = SnapshotStore(path, fsync=fsync)
        self.journal: EventJournal | None = None
        self._recovered: list[JournalRecord] = []
        self._since_snapshot = 0
        self._snapshot_due = False
        self._fsync = bool(fsync)

    # -- opening ---------------------------------------------------------
    @classmethod
    def create(cls, path: str, *, encode=None, fsync: bool = False,
               snapshot_every: int = SNAPSHOT_EVERY,
               rotate_every: int | None = ROTATE_EVERY) -> "SessionStore":
        """Open ``path`` for a NEW session, extending any existing journal."""
        store = cls(path, encode=encode, fsync=fsync,
                    snapshot_every=snapshot_every, rotate_every=rotate_every)
        journal_path = os.path.join(path, JOURNAL_FILE)
        if os.path.exists(journal_path) \
                or EventJournal.segments(journal_path):
            store.journal, store._recovered = EventJournal.open_existing(
                journal_path, fsync=fsync, rotate_every=store.rotate_every)
        else:
            store.journal = EventJournal(journal_path, fsync=fsync,
                                         rotate_every=store.rotate_every)
        return store

    @classmethod
    def open_existing(cls, path: str, *, encode=None, fsync: bool = False,
                      snapshot_every: int = SNAPSHOT_EVERY,
                      rotate_every: int | None = ROTATE_EVERY) \
            -> "SessionStore":
        """Open ``path`` for resume.  Raises :class:`NoStoreError` when the
        path holds no store at all, :class:`StoreError` when a store exists
        but every record in it is damaged beyond recovery."""
        journal_path = os.path.join(path, JOURNAL_FILE)
        if not os.path.isdir(path) or not (
                os.path.exists(journal_path)
                or EventJournal.segments(journal_path)):
            raise NoStoreError(
                f"no session store at {path!r}: the directory "
                f"{'exists but ' if os.path.isdir(path) else 'does not exist and '}"
                f"holds no {JOURNAL_FILE}. Pass the directory given as the "
                f"'store' config key of the session you want to resume.")
        store = cls(path, encode=encode, fsync=fsync,
                    snapshot_every=snapshot_every, rotate_every=rotate_every)
        store.journal, store._recovered = EventJournal.open_existing(
            journal_path, fsync=fsync, rotate_every=store.rotate_every)
        if not store._recovered:
            raise StoreError(
                f"session store at {path!r} is corrupt: {JOURNAL_FILE} "
                f"exists but contains no intact records. The session cannot "
                f"be reconstructed; start fresh with "
                f"from_config({{'store': ...}}) on a new directory.")
        return store

    # -- recovered state -------------------------------------------------
    @property
    def recovered_records(self) -> list[JournalRecord]:
        """Every intact journal record found when the store was opened."""
        return self._recovered

    def records(self, after_seq: int = 0) -> list[JournalRecord]:
        """Recovered records with ``seq > after_seq`` (the replay tail)."""
        return [r for r in self._recovered if r.seq > after_seq]

    def load_snapshot(self) -> tuple[dict | None, int]:
        """Latest usable snapshot ``(state, seq)``; ``(None, 0)`` if none.
        Snapshots past the recovered journal tip (describing state a
        truncated journal can no longer reach) are skipped."""
        return self.snapshots.load_latest(
            max_seq=self.journal.last_seq if self.journal else None)

    # -- writing ---------------------------------------------------------
    def record(self, kind: str, **data) -> int:
        """Journal one event (write-ahead: call BEFORE applying the
        mutation).  Payload values pass through ``encode``."""
        seq = self.journal.append(kind, {k: self.encode(v)
                                         for k, v in data.items()})
        self._since_snapshot += 1
        if self._since_snapshot >= self.snapshot_every:
            self._snapshot_due = True
        return seq

    @contextmanager
    def batch(self):
        """Coalesce journal flushes across one fleet tick (see
        ``EventJournal.batch``): records inside the block land in append
        order but share one flush at exit.  ``fsync=True`` stores keep
        per-record durability.  Snapshots written mid-batch are safe — a
        crash that tears the unflushed journal tail truncates it on
        recovery, and :meth:`load_snapshot` already skips snapshots past
        the recovered tip."""
        if self.journal is None:
            yield self
            return
        with self.journal.batch():
            yield self

    def flush_snapshot(self, capture=None, force: bool = False) -> bool:
        """Write a snapshot if one is due (or ``force``).  ``capture`` is a
        zero-arg callable returning the JSON-ready session state (defaults
        to the attached ``self.capture``); it runs only when a snapshot is
        actually written.  With no capture available the due flag persists,
        so the next flush with one still writes."""
        capture = capture if capture is not None else self.capture
        if not (self._snapshot_due or force) or capture is None:
            return False
        self.snapshots.write(capture(), self.journal.last_seq)
        self._since_snapshot = 0
        self._snapshot_due = False
        return True

    def close(self) -> None:
        if self.journal is not None:
            self.journal.close()
