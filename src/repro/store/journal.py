"""Append-only write-ahead event journal (the durability primitive).

One ``journal.jsonl`` file per session store: every record is a single
JSON line carrying a monotonically increasing sequence number, a wall-clock
timestamp, a record kind, an arbitrary JSON payload, and a sha256 checksum
over the canonical encoding of the other four fields.  Records are written
*before* the mutation they describe takes effect (write-ahead semantics),
flushed per record, and optionally fsynced.

Crash tolerance is asymmetric by design: appends are cheap and optimistic,
recovery is paranoid.  ``EventJournal.recover`` replays the file line by
line and stops at the FIRST sign of damage — a line without a trailing
newline (torn write), unparseable JSON, a checksum mismatch, or a sequence
break — warning and discarding everything from that point on (a corrupt
record invalidates its successors: they may describe state that was never
reached).  Re-opening a journal for append truncates the file back to the
last intact record, so the recovered session and the on-disk tail agree.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass

JOURNAL_FILE = "journal.jsonl"

_CANONICAL = dict(sort_keys=True, separators=(",", ":"), allow_nan=False)


def _checksum(seq: int, ts: float, kind: str, data) -> str:
    body = json.dumps({"seq": seq, "ts": ts, "kind": kind, "data": data},
                      **_CANONICAL)
    return hashlib.sha256(body.encode()).hexdigest()


@dataclass(frozen=True)
class JournalRecord:
    """One durably recorded session event."""
    seq: int                     # 1-based, strictly consecutive
    ts: float                    # wall-clock append time (time.time())
    kind: str                    # admit|decision|retire|budget|fail|...
    data: dict                   # JSON-ready payload (pre-encoded by caller)


class EventJournal:
    """Append-only JSONL journal with per-record checksums."""

    def __init__(self, path: str, fsync: bool = False,
                 start_seq: int = 0):
        self.path = path
        self.fsync = bool(fsync)
        self._seq = int(start_seq)
        self._fh = None
        self._batch_depth = 0
        self._dirty = False

    @property
    def last_seq(self) -> int:
        return self._seq

    def _handle(self):
        if self._fh is None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")
        return self._fh

    # -- writing ---------------------------------------------------------
    def append(self, kind: str, data: dict, ts: float | None = None) -> int:
        """Durably record one event; returns its sequence number.  The line
        hits the OS (flush) before this returns — and the disk, with
        ``fsync`` — so a crash immediately after sees the record.

        Inside a ``batch()`` block (and without ``fsync``) the flush is
        deferred to batch exit, coalescing one syscall per record into one
        per tick; recovery already tolerates a torn batched tail exactly
        like any torn record."""
        seq = self._seq + 1
        ts = time.time() if ts is None else float(ts)
        rec = {"seq": seq, "ts": ts, "kind": str(kind), "data": data}
        rec["sha"] = _checksum(seq, ts, rec["kind"], data)
        fh = self._handle()
        fh.write(json.dumps(rec, **_CANONICAL) + "\n")
        if self._batch_depth and not self.fsync:
            self._dirty = True
        else:
            fh.flush()
            if self.fsync:
                os.fsync(fh.fileno())
        self._seq = seq
        return seq

    @contextmanager
    def batch(self):
        """Coalesce appends: records written inside the block share one
        flush at exit instead of flushing per record.  Write-ahead ordering
        within the file is unchanged (records still land in append order),
        and ``fsync=True`` journals keep their per-record flush+fsync —
        explicit durability is never weakened by batching.  Re-entrant."""
        self._batch_depth += 1
        try:
            yield self
        finally:
            self._batch_depth -= 1
            if self._batch_depth == 0 and self._dirty:
                self._dirty = False
                if self._fh is not None:
                    self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- recovery --------------------------------------------------------
    @staticmethod
    def recover(path: str) -> tuple[list[JournalRecord], int]:
        """Read every intact record, tolerating a damaged tail.

        Returns ``(records, good_bytes)`` where ``good_bytes`` is the byte
        offset just past the last intact record — the truncation point for
        re-opening the journal in append mode.  Never raises on damage:
        torn/corrupt tails produce a ``RuntimeWarning`` and are dropped."""
        records: list[JournalRecord] = []
        good = 0
        with open(path, "rb") as f:
            raw = f.read()
        for line in raw.split(b"\n"):
            end = good + len(line) + 1          # +1 for the newline
            if end > len(raw):
                if line.strip():
                    warnings.warn(
                        f"journal {path}: torn record after seq "
                        f"{records[-1].seq if records else 0} (no trailing "
                        f"newline); truncating the damaged tail",
                        RuntimeWarning)
                break
            if not line.strip():
                good = end
                continue
            reason = None
            try:
                rec = json.loads(line)
                seq, ts = int(rec["seq"]), float(rec["ts"])
                kind, data, sha = rec["kind"], rec["data"], rec["sha"]
                if sha != _checksum(seq, ts, kind, data):
                    reason = "checksum mismatch"
                elif seq != (records[-1].seq if records else 0) + 1:
                    reason = f"sequence break (got {seq})"
            except (ValueError, KeyError, TypeError) as e:
                reason = f"unparseable record ({type(e).__name__})"
            if reason is not None:
                warnings.warn(
                    f"journal {path}: {reason} after seq "
                    f"{records[-1].seq if records else 0}; truncating the "
                    f"damaged tail", RuntimeWarning)
                break
            records.append(JournalRecord(seq=seq, ts=ts, kind=kind,
                                         data=data))
            good = end
        return records, good

    @classmethod
    def open_existing(cls, path: str,
                      fsync: bool = False) -> tuple["EventJournal",
                                                    list[JournalRecord]]:
        """Recover ``path`` and open it for appending: the file is truncated
        back to its last intact record so new appends extend clean state."""
        records, good = cls.recover(path)
        size = os.path.getsize(path)
        if good < size:
            with open(path, "r+b") as f:
                f.truncate(good)
        journal = cls(path, fsync=fsync,
                      start_seq=records[-1].seq if records else 0)
        return journal, records
