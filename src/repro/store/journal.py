"""Append-only write-ahead event journal (the durability primitive).

One ``journal.jsonl`` file per session store: every record is a single
JSON line carrying a monotonically increasing sequence number, a wall-clock
timestamp, a record kind, an arbitrary JSON payload, and a sha256 checksum
over the canonical encoding of the other four fields.  Records are written
*before* the mutation they describe takes effect (write-ahead semantics),
flushed per record, and optionally fsynced.

Crash tolerance is asymmetric by design: appends are cheap and optimistic,
recovery is paranoid.  ``EventJournal.recover`` replays the file line by
line and stops at the FIRST sign of damage — a line without a trailing
newline (torn write), unparseable JSON, a checksum mismatch, or a sequence
break — warning and discarding everything from that point on (a corrupt
record invalidates its successors: they may describe state that was never
reached).  Re-opening a journal for append truncates the file back to the
last intact record, so the recovered session and the on-disk tail agree.

Segment rotation bounds the live file for month-long sessions: with
``rotate_every=k`` the live ``journal.jsonl`` is sealed as
``journal-<n>.jsonl`` every ``k`` records and a fresh live file starts.
Sequence numbers run unbroken across segments; ``recover`` reads sealed
segments in order before the live file, so readers see one continuous
journal.  Sealed segments are immutable — torn-tail *truncation* only ever
applies to the live segment.  A damaged sealed segment invalidates its
successors exactly like a damaged record: recovery stops there, and
re-opening for append quarantines the unreachable suffix (``.corrupt``
renames, nothing deleted) and resumes appending from the last intact
record.

Compaction (``SessionStore.compact``) folds sealed segments whose records
are fully covered by the retained snapshots into a checksummed *base file*
(``journal.base.json``): it records the sequence number the surviving
journal now starts after (``base_seq``), the highest folded segment number
(``through_segment``), and the session's preserved ``open`` record.
Recovery chains from ``base_seq`` instead of 0 and skips any segment at or
below ``through_segment`` (a crash between the base write and the segment
removal leaves harmless leftovers).  Sequence numbers never restart — the
journal stays one unbroken sequence, just with a floor.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass

JOURNAL_FILE = "journal.jsonl"

_CANONICAL = dict(sort_keys=True, separators=(",", ":"), allow_nan=False)


def _checksum(seq: int, ts: float, kind: str, data) -> str:
    body = json.dumps({"seq": seq, "ts": ts, "kind": kind, "data": data},
                      **_CANONICAL)
    return hashlib.sha256(body.encode()).hexdigest()


def _base_checksum(base_seq: int, through_segment: int, open_record) -> str:
    body = json.dumps({"base_seq": base_seq,
                       "through_segment": through_segment,
                       "open": open_record}, **_CANONICAL)
    return hashlib.sha256(body.encode()).hexdigest()


@dataclass(frozen=True)
class JournalRecord:
    """One durably recorded session event."""
    seq: int                     # 1-based, strictly consecutive
    ts: float                    # wall-clock append time (time.time())
    kind: str                    # admit|decision|retire|budget|fail|...
    data: dict                   # JSON-ready payload (pre-encoded by caller)


class EventJournal:
    """Append-only JSONL journal with per-record checksums and optional
    record-count segment rotation."""

    def __init__(self, path: str, fsync: bool = False,
                 start_seq: int = 0, rotate_every: int | None = None,
                 segment_records: int = 0, next_segment: int = 1):
        self.path = path
        self.fsync = bool(fsync)
        self.rotate_every = int(rotate_every) if rotate_every else None
        self._seq = int(start_seq)
        self._fh = None
        self._batch_depth = 0
        self._dirty = False
        self._segment_records = int(segment_records)
        self._next_segment = int(next_segment)
        # compaction base ({"base_seq", "through_segment", "open"} or None):
        # set by open_existing from the on-disk base file and updated by
        # SessionStore.compact when segments fold
        self.base: dict | None = None

    @property
    def last_seq(self) -> int:
        return self._seq

    def _handle(self):
        if self._fh is None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self._fh = open(self.path, "a", encoding="utf-8")
        return self._fh

    # -- segment naming --------------------------------------------------
    def _segment_path(self, k: int) -> str:
        return self.segment_path(self.path, k)

    @staticmethod
    def segment_path(path: str, k: int) -> str:
        """Sealed-segment name for a live journal ``path``:
        ``journal.jsonl`` -> ``journal-<k>.jsonl``."""
        root, ext = os.path.splitext(path)
        return f"{root}-{k}{ext}"

    @staticmethod
    def segments(path: str) -> list[tuple[int, str]]:
        """Sealed segments beside the live journal ``path``, as ``(k,
        segment_path)`` sorted by seal order (oldest first)."""
        dirname = os.path.dirname(path) or "."
        root, ext = os.path.splitext(os.path.basename(path))
        pat = re.compile(rf"^{re.escape(root)}-(\d+){re.escape(ext)}$")
        found = []
        if os.path.isdir(dirname):
            for name in os.listdir(dirname):
                m = pat.match(name)
                if m:
                    found.append((int(m.group(1)),
                                  os.path.join(dirname, name)))
        return sorted(found)

    # -- compaction base -------------------------------------------------
    @staticmethod
    def base_path(path: str) -> str:
        """Compaction-base name for a live journal ``path``:
        ``journal.jsonl`` -> ``journal.base.json``."""
        root, _ = os.path.splitext(path)
        return f"{root}.base.json"

    @classmethod
    def read_base(cls, path: str) -> dict | None:
        """The journal's compaction base (``None`` when never compacted).
        A corrupt base file is warned about and treated as absent — the
        records folded into it are unrecoverable, so downstream recovery
        will (correctly) fail rather than rebuild partial state."""
        bp = cls.base_path(path)
        if not os.path.exists(bp):
            return None
        try:
            with open(bp, encoding="utf-8") as f:
                payload = json.load(f)
            base_seq = int(payload["base_seq"])
            through = int(payload["through_segment"])
            open_rec = payload["open"]
            if payload["sha"] != _base_checksum(base_seq, through, open_rec):
                raise ValueError("checksum mismatch")
        except (OSError, ValueError, KeyError, TypeError) as e:
            warnings.warn(
                f"journal base {bp} is corrupt ({e}); ignoring it — the "
                f"records compacted into it are lost", RuntimeWarning)
            return None
        return {"base_seq": base_seq, "through_segment": through,
                "open": open_rec}

    @classmethod
    def write_base(cls, path: str, base_seq: int, through_segment: int,
                   open_record: dict | None, fsync: bool = False) -> dict:
        """Atomically persist the compaction base (tmp + ``os.replace``);
        written BEFORE the folded segments are removed, so a crash between
        the two leaves skippable leftovers, never a gap."""
        bp = cls.base_path(path)
        payload = {"base_seq": int(base_seq),
                   "through_segment": int(through_segment),
                   "open": open_record,
                   "sha": _base_checksum(int(base_seq), int(through_segment),
                                         open_record)}
        tmp = bp + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(payload, f, **_CANONICAL)
            f.flush()
            if fsync:
                os.fsync(f.fileno())
        os.replace(tmp, bp)
        return {"base_seq": int(base_seq),
                "through_segment": int(through_segment), "open": open_record}

    # -- writing ---------------------------------------------------------
    def append(self, kind: str, data: dict, ts: float | None = None) -> int:
        """Durably record one event; returns its sequence number.  The line
        hits the OS (flush) before this returns — and the disk, with
        ``fsync`` — so a crash immediately after sees the record.

        Inside a ``batch()`` block (and without ``fsync``) the flush is
        deferred to batch exit, coalescing one syscall per record into one
        per tick; recovery already tolerates a torn batched tail exactly
        like any torn record."""
        seq = self._seq + 1
        # ts is informational wall-clock metadata, never replayed into
        # session state; deterministic callers pin it via the parameter
        ts = time.time() if ts is None else float(ts)  # minoslint: disable=W301
        rec = {"seq": seq, "ts": ts, "kind": str(kind), "data": data}
        rec["sha"] = _checksum(seq, ts, rec["kind"], data)
        fh = self._handle()
        fh.write(json.dumps(rec, **_CANONICAL) + "\n")
        if self._batch_depth and not self.fsync:
            self._dirty = True
        else:
            fh.flush()
            if self.fsync:
                os.fsync(fh.fileno())
        self._seq = seq
        self._segment_records += 1
        if self.rotate_every and self._segment_records >= self.rotate_every:
            self._rotate()
        return seq

    def _rotate(self) -> None:
        """Seal the live file as the next numbered segment and start a
        fresh live journal.  The sealed bytes are flushed (and fsynced,
        when configured) before the rename, so rotation never weakens
        durability — even mid-``batch()``."""
        fh = self._fh
        if fh is not None:
            fh.flush()
            if self.fsync:
                os.fsync(fh.fileno())
            fh.close()
            self._fh = None
        self._dirty = False
        os.replace(self.path, self._segment_path(self._next_segment))
        self._next_segment += 1
        self._segment_records = 0

    @contextmanager
    def batch(self):
        """Coalesce appends: records written inside the block share one
        flush at exit instead of flushing per record.  Write-ahead ordering
        within the file is unchanged (records still land in append order),
        and ``fsync=True`` journals keep their per-record flush+fsync —
        explicit durability is never weakened by batching.  Re-entrant."""
        self._batch_depth += 1
        try:
            yield self
        finally:
            self._batch_depth -= 1
            if self._batch_depth == 0 and self._dirty:
                self._dirty = False
                if self._fh is not None:
                    self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- recovery --------------------------------------------------------
    @staticmethod
    def _scan(path: str, after_seq: int) -> tuple[list[JournalRecord], int]:
        """One file's intact records (expecting ``after_seq + 1`` first)
        and the byte offset just past the last intact record."""
        records: list[JournalRecord] = []
        good = 0
        with open(path, "rb") as f:
            raw = f.read()
        for line in raw.split(b"\n"):
            end = good + len(line) + 1          # +1 for the newline
            if end > len(raw):
                if line.strip():
                    warnings.warn(
                        f"journal {path}: torn record after seq "
                        f"{records[-1].seq if records else after_seq} (no "
                        f"trailing newline); truncating the damaged tail",
                        RuntimeWarning)
                break
            if not line.strip():
                good = end
                continue
            reason = None
            try:
                rec = json.loads(line)
                seq, ts = int(rec["seq"]), float(rec["ts"])
                kind, data, sha = rec["kind"], rec["data"], rec["sha"]
                if sha != _checksum(seq, ts, kind, data):
                    reason = "checksum mismatch"
                elif seq != (records[-1].seq if records
                             else after_seq) + 1:
                    reason = f"sequence break (got {seq})"
            except (ValueError, KeyError, TypeError) as e:
                reason = f"unparseable record ({type(e).__name__})"
            if reason is not None:
                warnings.warn(
                    f"journal {path}: {reason} after seq "
                    f"{records[-1].seq if records else after_seq}; "
                    f"truncating the damaged tail", RuntimeWarning)
                break
            records.append(JournalRecord(seq=seq, ts=ts, kind=kind,
                                         data=data))
            good = end
        return records, good

    @classmethod
    def _recover_all(cls, path: str):
        """Recover sealed segments (in order) then the live file.

        Returns ``(records, live_good, live_count, damage, base)``: all
        intact records across segments, the live file's truncation offset,
        how many of the records came from the live file, — when a SEALED
        segment is damaged — ``(k, segment_path, good_bytes, count)`` for
        it (everything after a sealed-segment wound is unreachable and is
        dropped, live file included), and the compaction base (or None).
        With a base, recovery chains from ``base_seq`` and segments at or
        below ``through_segment`` are skipped (compaction leftovers)."""
        base = cls.read_base(path)
        base_seq = base["base_seq"] if base else 0
        folded_k = base["through_segment"] if base else 0
        records: list[JournalRecord] = []
        for k, seg in cls.segments(path):
            if k <= folded_k:
                continue            # already folded into the base
            segrecs, good = cls._scan(
                seg, records[-1].seq if records else base_seq)
            records.extend(segrecs)
            if good < os.path.getsize(seg):
                warnings.warn(
                    f"journal segment {seg} is damaged mid-archive; "
                    f"records after seq "
                    f"{records[-1].seq if records else base_seq} (later "
                    f"segments and the live tail) are unreachable and "
                    f"dropped", RuntimeWarning)
                return records, 0, 0, (k, seg, good, len(segrecs)), base
        if not os.path.exists(path):
            return records, 0, 0, None, base
        liverecs, good = cls._scan(path,
                                   records[-1].seq if records else base_seq)
        records.extend(liverecs)
        return records, good, len(liverecs), None, base

    @classmethod
    def recover(cls, path: str) -> tuple[list[JournalRecord], int]:
        """Read every intact record — sealed segments in seal order, then
        the live file — tolerating a damaged tail.

        Returns ``(records, good_bytes)`` where ``good_bytes`` is the byte
        offset just past the live file's last intact record — the
        truncation point for re-opening the journal in append mode (0 when
        a damaged *sealed* segment made the live file unreachable).  Never
        raises on damage: torn/corrupt tails produce a ``RuntimeWarning``
        and are dropped.  Read-only: no file is modified.  On a compacted
        journal only the records after the base floor are returned."""
        records, live_good, _, _, _ = cls._recover_all(path)
        return records, live_good

    @classmethod
    def open_existing(cls, path: str, fsync: bool = False,
                      rotate_every: int | None = None) \
            -> tuple["EventJournal", list[JournalRecord]]:
        """Recover ``path`` (segments included) and open it for appending.

        The live file is truncated back to its last intact record so new
        appends extend clean state.  If a *sealed* segment is damaged, its
        unreachable successors (later segments and the old live file) are
        quarantined under ``.corrupt`` names — bytes renamed, never
        deleted — and the damaged segment, truncated to its intact prefix,
        becomes the live journal again."""
        records, live_good, live_count, damage, base = cls._recover_all(path)
        folded_k = base["through_segment"] if base else 0
        if damage is not None:
            k, seg, seg_good, seg_count = damage
            for k2, seg2 in cls.segments(path):
                if k2 > k:
                    os.replace(seg2, seg2 + ".corrupt")
            if os.path.exists(path):
                os.replace(path, path + ".corrupt")
            os.replace(seg, path)
            with open(path, "r+b") as f:
                f.truncate(seg_good)
            live_count, next_segment = seg_count, k
        else:
            if os.path.exists(path) \
                    and live_good < os.path.getsize(path):
                with open(path, "r+b") as f:
                    f.truncate(live_good)
            ks = [k for k, _ in cls.segments(path) if k > folded_k]
            next_segment = (max(ks + [folded_k]) + 1
                            if (ks or folded_k) else 1)
        journal = cls(path, fsync=fsync, rotate_every=rotate_every,
                      start_seq=records[-1].seq if records
                      else (base["base_seq"] if base else 0),
                      segment_records=live_count,
                      next_segment=next_segment)
        journal.base = base
        return journal, records
