"""Windowed reports derived from the event journal.

The journal is the source of truth for everything a ``SessionReport``
summarizes — so operational reports (utilization, headroom, migration
counts over time windows) are computed here straight from the recovered
records, without a live session and without importing ``repro.api``.
Payloads are consumed as the raw JSON-ready dicts the codec produced
(``__type__`` tags are ignored, ``__float__`` tags are decoded locally).
"""
from __future__ import annotations

import math
import os

from .journal import JOURNAL_FILE, EventJournal, JournalRecord


def _num(value, default: float = 0.0) -> float:
    """Decode a journal number: plain float/int or a ``__float__`` tag."""
    if isinstance(value, dict) and set(value) == {"__float__"}:
        return float(value["__float__"])
    if isinstance(value, (int, float)):
        return float(value)
    return default


def _fields(record) -> tuple[int, float, str, dict]:
    if isinstance(record, JournalRecord):
        return record.seq, record.ts, record.kind, record.data
    return (int(record["seq"]), float(record["ts"]),
            str(record["kind"]), record.get("data", {}))


def _blank_window(start: float, end: float) -> dict:
    return {"start": start, "end": end, "records": 0,
            "admits": 0, "decisions": 0, "retires": 0,
            "migrations": 0, "shrinks": 0, "strands": 0,
            "failures": 0, "degrades": 0, "restores": 0}


def windowed_report(records, window_s: float = 60.0) -> list[dict]:
    """Aggregate journal ``records`` into consecutive time windows.

    Each window reports event counts (admits, decisions, retires,
    migrations, shrinks, strands, device failures/degrades/restores) plus
    the power picture at the window's close: ``planned_w`` (sum of the
    predicted p90 draw of every decided, still-active plan), ``budget_w``,
    ``headroom_w`` and ``utilization`` (``planned_w / budget_w``, ``None``
    under an unbounded budget).  Windows with no records are still emitted
    so the timeline has no gaps.
    """
    window_s = float(window_s)
    if window_s <= 0:
        raise ValueError(f"window_s must be positive, got {window_s}")
    rows = sorted((_fields(r) for r in records), key=lambda f: f[0])
    if not rows:
        return []

    budget_w = math.inf
    planned: dict[str, float] = {}       # job_id -> predicted p90 watts
    windows: list[dict] = []
    origin = rows[0][1]

    def _close(win):
        total = sum(planned.values())
        win["planned_w"] = total
        win["budget_w"] = budget_w
        win["headroom_w"] = budget_w - total
        win["utilization"] = (total / budget_w
                              if math.isfinite(budget_w) and budget_w > 0
                              else None)
        windows.append(win)

    win = _blank_window(origin, origin + window_s)
    for _seq, ts, kind, data in rows:
        while ts >= win["end"]:
            _close(win)
            win = _blank_window(win["end"], win["end"] + window_s)
        win["records"] += 1
        if kind == "open":
            budget_w = _num(data.get("budget_w"), math.inf)
        elif kind == "budget":
            budget_w = _num(data.get("budget_w"), math.inf)
        elif kind == "admit":
            win["admits"] += 1
        elif kind == "decision":
            win["decisions"] += 1
            plan = data.get("plan") or {}
            job_id = plan.get("job_id") or data.get("job_id", "")
            planned[job_id] = _num(plan.get("predicted_p90_w"))
        elif kind == "retire":
            win["retires"] += 1
            planned.pop(data.get("job_id", ""), None)
        elif kind == "fail":
            win["failures"] += 1
        elif kind == "degrade":
            win["degrades"] += 1
        elif kind == "restore":
            win["restores"] += 1
        elif kind == "event":
            ev = data.get("event") or {}
            ev_kind = ev.get("kind", "")
            if ev_kind == "migrate":
                win["migrations"] += 1
            elif ev_kind == "shrink":
                win["shrinks"] += 1
            elif ev_kind == "strand":
                win["strands"] += 1
        elif kind == "reprofile":
            planned.pop(data.get("job_id", ""), None)
    _close(win)
    return windows


def store_report(path: str, window_s: float = 60.0) -> list[dict]:
    """``windowed_report`` over the journal found in store ``path``."""
    journal_path = os.path.join(path, JOURNAL_FILE)
    if not os.path.exists(journal_path):
        raise FileNotFoundError(f"no {JOURNAL_FILE} under {path!r}")
    records, _ = EventJournal.recover(journal_path)
    return windowed_report(records, window_s=window_s)
