"""Windowed reports derived from the event journal.

The journal is the source of truth for everything a ``SessionReport``
summarizes — so operational reports (utilization, headroom, migration
counts over time windows) are computed here straight from the recovered
records, without a live session and without importing ``repro.api``.
Payloads are consumed as the raw JSON-ready dicts the codec produced
(``__type__`` tags are ignored, ``__float__`` tags are decoded locally).

Repeated reporting over the same on-disk journal is cheap:
``store_report`` parses each journal once into a columnar
:class:`JournalView` — parallel ``(seq, ts, kind, value)`` columns holding
only the fields the aggregation consumes — and caches it keyed by a
fingerprint of every segment's ``(name, size, mtime_ns)``.  Re-windowing a
10k-record journal at a different ``window_s`` then re-aggregates the
digest instead of re-reading and re-checksumming the file; any append or
rotation changes the fingerprint and invalidates the cache.
"""
from __future__ import annotations

import math
import os

from . import kinds
from .journal import JOURNAL_FILE, EventJournal, JournalRecord


def _num(value, default: float = 0.0) -> float:
    """Decode a journal number: plain float/int or a ``__float__`` tag."""
    if isinstance(value, dict) and set(value) == {"__float__"}:
        return float(value["__float__"])
    if isinstance(value, (int, float)):
        return float(value)
    return default


def _fields(record) -> tuple[int, float, str, dict]:
    if isinstance(record, JournalRecord):
        return record.seq, record.ts, record.kind, record.data
    return (int(record["seq"]), float(record["ts"]),
            str(record["kind"]), record.get("data", {}))


def _digest(kind: str, data: dict):
    """The one value aggregation needs from a record's payload."""
    if kind in (kinds.OPEN, kinds.BUDGET):
        return _num(data.get("budget_w"), math.inf)
    if kind == kinds.DECISION:
        plan = data.get("plan") or {}
        return (plan.get("job_id") or data.get("job_id", ""),
                _num(plan.get("predicted_p90_w")))
    if kind in (kinds.RETIRE, kinds.REPROFILE):
        return data.get("job_id", "")
    if kind == kinds.EVENT:
        return (data.get("event") or {}).get("kind", "")
    return None


class JournalView:
    """Columnar digest of a journal: parallel ``seqs``/``tss``/``kinds``/
    ``vals`` tuples in sequence order, holding only what windowed
    aggregation consumes.  Building one costs a single pass over the
    records; re-aggregating it (any ``window_s``) never touches disk."""

    __slots__ = ("seqs", "tss", "kinds", "vals")

    def __init__(self, records):
        rows = sorted((_fields(r) for r in records), key=lambda f: f[0])
        self.seqs = tuple(r[0] for r in rows)
        self.tss = tuple(r[1] for r in rows)
        self.kinds = tuple(r[2] for r in rows)
        self.vals = tuple(_digest(r[2], r[3]) for r in rows)

    def __len__(self) -> int:
        return len(self.seqs)


def _blank_window(start: float, end: float) -> dict:
    return {"start": start, "end": end, "records": 0,
            "admits": 0, "decisions": 0, "retires": 0,
            "migrations": 0, "shrinks": 0, "strands": 0,
            "failures": 0, "degrades": 0, "restores": 0}


def _aggregate(view: JournalView, window_s: float) -> list[dict]:
    window_s = float(window_s)
    if window_s <= 0:
        raise ValueError(f"window_s must be positive, got {window_s}")
    if not view.seqs:
        return []

    budget_w = math.inf
    planned: dict[str, float] = {}       # job_id -> predicted p90 watts
    windows: list[dict] = []
    origin = view.tss[0]

    def _close(win):
        total = sum(planned.values())
        win["planned_w"] = total
        win["budget_w"] = budget_w
        win["headroom_w"] = budget_w - total
        win["utilization"] = (total / budget_w
                              if math.isfinite(budget_w) and budget_w > 0
                              else None)
        windows.append(win)

    win = _blank_window(origin, origin + window_s)
    for ts, kind, val in zip(view.tss, view.kinds, view.vals):
        while ts >= win["end"]:
            _close(win)
            win = _blank_window(win["end"], win["end"] + window_s)
        win["records"] += 1
        if kind in (kinds.OPEN, kinds.BUDGET):
            budget_w = val
        elif kind == kinds.ADMIT:
            win["admits"] += 1
        elif kind == kinds.DECISION:
            win["decisions"] += 1
            job_id, p90 = val
            planned[job_id] = p90
        elif kind == kinds.RETIRE:
            win["retires"] += 1
            planned.pop(val, None)
        elif kind == kinds.FAIL:
            win["failures"] += 1
        elif kind == kinds.DEGRADE:
            win["degrades"] += 1
        elif kind == kinds.RESTORE:
            win["restores"] += 1
        elif kind == kinds.EVENT:
            if val == "migrate":
                win["migrations"] += 1
            elif val == "shrink":
                win["shrinks"] += 1
            elif val == "strand":
                win["strands"] += 1
        elif kind == kinds.REPROFILE:
            planned.pop(val, None)
    _close(win)
    return windows


def windowed_report(records, window_s: float = 60.0) -> list[dict]:
    """Aggregate journal ``records`` into consecutive time windows.

    Each window reports event counts (admits, decisions, retires,
    migrations, shrinks, strands, device failures/degrades/restores) plus
    the power picture at the window's close: ``planned_w`` (sum of the
    predicted p90 draw of every decided, still-active plan), ``budget_w``,
    ``headroom_w`` and ``utilization`` (``planned_w / budget_w``, ``None``
    under an unbounded budget).  Windows with no records are still emitted
    so the timeline has no gaps.
    """
    return _aggregate(JournalView(records), window_s)


# -- on-disk view cache --------------------------------------------------
_VIEW_CACHE: dict[str, tuple[tuple, JournalView]] = {}


def _fingerprint(journal_path: str) -> tuple:
    """Identity of the on-disk journal: every segment's (name, size,
    mtime_ns), sealed segments first, live file last."""
    parts = []
    for _k, seg in EventJournal.segments(journal_path):
        st = os.stat(seg)
        parts.append((os.path.basename(seg), st.st_size, st.st_mtime_ns))
    if os.path.exists(journal_path):
        st = os.stat(journal_path)
        parts.append((os.path.basename(journal_path), st.st_size,
                      st.st_mtime_ns))
    return tuple(parts)


def journal_view(journal_path: str) -> JournalView:
    """Cached columnar view of the journal at ``journal_path`` (segments
    included).  The fingerprint is taken BEFORE reading, so a concurrent
    append mid-read changes the next call's fingerprint and re-parses."""
    key = os.path.abspath(journal_path)
    fp = _fingerprint(journal_path)
    cached = _VIEW_CACHE.get(key)
    if cached is not None and cached[0] == fp:
        return cached[1]
    records, _ = EventJournal.recover(journal_path)
    view = JournalView(records)
    _VIEW_CACHE[key] = (fp, view)
    return view


def store_report(path: str, window_s: float = 60.0) -> list[dict]:
    """``windowed_report`` over the journal found in store ``path``,
    served from the fingerprint-keyed columnar view cache."""
    journal_path = os.path.join(path, JOURNAL_FILE)
    if not os.path.exists(journal_path) \
            and not EventJournal.segments(journal_path):
        raise FileNotFoundError(f"no {JOURNAL_FILE} under {path!r}")
    return _aggregate(journal_view(journal_path), window_s)
