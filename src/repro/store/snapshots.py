"""Checksummed snapshot store with N-1 rollback.

A snapshot is the fully materialized session state (jobs with their adopted
decisions/plans, retired jobs, device health, the event trail, counters) as
of one journal sequence number: restoring snapshot ``k`` and replaying the
journal records with ``seq > k`` reconstructs the exact pre-crash state
without touching the records before ``k``.

Snapshots are written atomically (tmp file + ``os.replace``) with a sha256
checksum over the canonical payload, and the store retains the latest TWO:
if the newest snapshot is corrupt (torn write, bit rot), ``load_latest``
warns and falls back to its predecessor — recovery then just replays a
longer journal tail.  Older snapshots are pruned on every write, so disk
use is bounded no matter how long the session runs.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import time
import warnings

SNAPSHOT_RETAIN = 2              # latest + one fallback (N-1 rollback)

_CANONICAL = dict(sort_keys=True, separators=(",", ":"), allow_nan=False)
_SNAP_RE = re.compile(r"^snapshot-(\d{10})\.json$")


def _checksum(seq: int, ts: float, state) -> str:
    body = json.dumps({"seq": seq, "ts": ts, "state": state}, **_CANONICAL)
    return hashlib.sha256(body.encode()).hexdigest()


class SnapshotStore:
    """Write/load checksummed state snapshots under a store directory."""

    def __init__(self, directory: str, retain: int = SNAPSHOT_RETAIN,
                 fsync: bool = False):
        self.directory = directory
        self.retain = max(int(retain), 1)
        self.fsync = bool(fsync)

    def _path(self, seq: int) -> str:
        return os.path.join(self.directory, f"snapshot-{seq:010d}.json")

    def _listing(self) -> list[tuple[int, str]]:
        """(seq, path) pairs for every snapshot file, newest first."""
        out = []
        if os.path.isdir(self.directory):
            for name in os.listdir(self.directory):
                m = _SNAP_RE.match(name)
                if m:
                    out.append((int(m.group(1)),
                                os.path.join(self.directory, name)))
        return sorted(out, reverse=True)

    # -- writing ---------------------------------------------------------
    def write(self, state, seq: int, ts: float | None = None) -> str:
        """Atomically persist ``state`` as the snapshot at journal ``seq``
        and prune beyond the retention window.  Returns the file path."""
        os.makedirs(self.directory, exist_ok=True)
        # ts is informational metadata (recovery keys on seq, not ts);
        # deterministic callers pin it via the parameter
        ts = time.time() if ts is None else float(ts)  # minoslint: disable=W301
        payload = {"seq": int(seq), "ts": ts, "state": state,
                   "sha": _checksum(int(seq), ts, state)}
        path = self._path(int(seq))
        tmp = path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(payload, f, **_CANONICAL)
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())
        os.replace(tmp, path)
        for _, old in self._listing()[self.retain:]:
            os.remove(old)
        return path

    # -- recovery --------------------------------------------------------
    def _verify(self, seq: int, path: str) -> dict:
        """Parse + checksum one snapshot file; raises on any damage."""
        with open(path, encoding="utf-8") as f:
            payload = json.load(f)
        state = payload["state"]
        if payload["sha"] != _checksum(int(payload["seq"]),
                                       float(payload["ts"]), state):
            raise ValueError("checksum mismatch")
        if int(payload["seq"]) != seq:
            raise ValueError(f"claims seq {payload['seq']}, "
                             f"file says {seq}")
        return state

    def intact_seqs(self, max_seq: float | None = None) -> list[int]:
        """Sequence numbers of every snapshot that verifies, newest first.
        Corrupt files are silently skipped (no warning — this is a
        compaction-planning probe, not a recovery path); ``max_seq``
        filters like ``load_latest``."""
        out = []
        for seq, path in self._listing():
            if max_seq is not None and seq > max_seq:
                continue
            try:
                self._verify(seq, path)
            except (OSError, ValueError, KeyError, TypeError):
                continue
            out.append(seq)
        return out

    def load_latest(self, max_seq: float | None = None) \
            -> tuple[dict | None, int]:
        """The newest *intact* snapshot as ``(state, seq)``.

        A snapshot that fails to parse or checksum is warned about and
        skipped in favor of its predecessor (the N-1 rollback); with no
        intact snapshot at all, returns ``(None, 0)`` — the session then
        recovers by replaying the journal from the beginning.

        ``max_seq`` (the journal's recovered tip) silently skips snapshots
        from *beyond* the surviving journal: after a tail truncation they
        describe state the journal can no longer reach."""
        for seq, path in self._listing():
            if max_seq is not None and seq > max_seq:
                continue
            try:
                state = self._verify(seq, path)
            except (OSError, ValueError, KeyError, TypeError) as e:
                warnings.warn(
                    f"snapshot {path} is corrupt ({e}); falling back to the "
                    f"previous snapshot (longer journal replay)",
                    RuntimeWarning)
                continue
            return state, seq
        return None, 0
