"""Durable session storage: write-ahead event journal + snapshot store.

``repro.store`` is the persistence layer under ``MinosSession``'s
``store`` config key: every decision, plan, retirement, budget change and
device-health transition is journaled before it takes effect, snapshots of
the materialized state are written on a record-count cadence, and
``MinosSession.resume`` reconstructs a crashed session from the latest
intact snapshot plus the journal tail — with zero classifier calls.

This package is deliberately codec-agnostic (no ``repro.api`` imports):
the session injects its own encoder, and :mod:`repro.store.reports`
consumes the raw journal dicts directly.
"""
from .journal import JOURNAL_FILE, EventJournal, JournalRecord
from .reports import JournalView, journal_view, store_report, windowed_report
from .session_store import (
    ROTATE_EVERY,
    SNAPSHOT_EVERY,
    NoStoreError,
    SessionStore,
    StoreError,
)
from .snapshots import SNAPSHOT_RETAIN, SnapshotStore

__all__ = [
    "JOURNAL_FILE",
    "ROTATE_EVERY",
    "SNAPSHOT_EVERY",
    "SNAPSHOT_RETAIN",
    "EventJournal",
    "JournalRecord",
    "JournalView",
    "journal_view",
    "NoStoreError",
    "SessionStore",
    "SnapshotStore",
    "StoreError",
    "store_report",
    "windowed_report",
]
