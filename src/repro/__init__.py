"""repro: Minos (power/performance workload classification) on a multi-pod
JAX training/serving framework. See DESIGN.md."""
__version__ = "0.1.0"
