"""Model zoo entry point: build models + input specs per (arch x shape)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import Topo
from repro.models.encdec import EncDecLM
from repro.models.transformer import LM


def build_model(cfg: ModelConfig, topo: Topo, kind: str = "train"):
    if cfg.is_encoder_decoder:
        return EncDecLM(cfg, topo, kind)
    return LM(cfg, topo, kind)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``train``/``prefill``: full (batch, seq) token batches (+ stub modality
    embeddings for vlm/audio).  ``decode``: one new token per sequence.
    """
    b, s = shape.global_batch, shape.seq_len
    i32, bf16 = jnp.int32, jnp.bfloat16
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((b,), i32)}
        return specs
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), i32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    if cfg.family == "vlm":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), bf16)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)
    return specs


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig, topo: Topo) -> dict:
    """PartitionSpecs congruent with input_specs."""
    specs = input_specs(cfg, shape)
    out = {}
    for name, st in specs.items():
        axes: tuple = ("batch",) + (None,) * (len(st.shape) - 1)
        out[name] = topo.pspec(axes, st.shape)
    return out


def make_batch(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array) -> dict:
    """Materialize a random batch matching input_specs (smoke tests)."""
    specs = input_specs(cfg, shape)
    out = {}
    for i, (name, st) in enumerate(sorted(specs.items())):
        k = jax.random.fold_in(key, i)
        if st.dtype == jnp.int32:
            out[name] = jax.random.randint(k, st.shape, 0, cfg.vocab_size, jnp.int32)
        else:
            out[name] = jax.random.normal(k, st.shape, jnp.float32).astype(st.dtype) * 0.02
    return out
