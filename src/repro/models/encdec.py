"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB: ``input_specs()`` supplies precomputed frame
embeddings (batch, frames, d_model). Positional information is sinusoidal on
both sides (whisper uses learned decoder positions; sinusoidal keeps the
parameter tree shape-independent — noted in DESIGN.md).

Decode uses a self-attention KV cache (seq-sharded) plus per-layer
cross-attention K/V caches precomputed from the encoder output at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import Attention
from repro.models.common import ParamStore, Topo, maybe_remat
from repro.models.layers import Embedding, Mlp, Norm, chunked_ce_loss


def sinusoidal(positions: jax.Array, dim: int) -> jax.Array:
    """(s,) int32 -> (s, dim) float32 sinusoidal embeddings."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, topo: Topo, kind: str = "train"):
        assert kind in ("train", "prefill", "decode")
        self.cfg, self.topo, self.kind = cfg, topo, kind
        layout = "decode_rp" if kind == "decode" else (
            "megatron" if cfg.num_heads % max(topo.axis_size("tp"), 1) == 0 else "fsdp_sp")
        self.layout = layout
        d = cfg.d_model

        def attn(name, cross=False, causal=True, lo=None):
            return Attention(name, d, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                             layout=lo or layout, use_rope=False, qkv_bias=cfg.qkv_bias,
                             out_bias=cfg.attn_out_bias, causal=causal, is_cross=cross)

        # encoder blocks (always full-sequence, even when decoding happens later)
        enc_layout = "megatron" if kind != "decode" else "decode_rp"
        self.enc_attn = attn("enc_attn/core", cross=False, causal=False, lo=enc_layout)
        self.enc_norm1 = Norm("enc_attn/norm", d, cfg.norm_type, cfg.norm_eps)
        self.enc_mlp = Mlp("enc_mlp/core", d, cfg.d_ff, cfg.mlp_activation)
        self.enc_norm2 = Norm("enc_mlp/norm", d, cfg.norm_type, cfg.norm_eps)
        # decoder blocks
        self.dec_self = attn("dec_self/core", cross=False, causal=True)
        self.dec_norm1 = Norm("dec_self/norm", d, cfg.norm_type, cfg.norm_eps)
        self.dec_cross = attn("dec_cross/core", cross=True)
        self.dec_norm2 = Norm("dec_cross/norm", d, cfg.norm_type, cfg.norm_eps)
        self.dec_mlp = Mlp("dec_mlp/core", d, cfg.d_ff, cfg.mlp_activation,
                           zero3=kind != "decode")
        self.dec_norm3 = Norm("dec_mlp/norm", d, cfg.norm_type, cfg.norm_eps)

        self.embedding = Embedding("embed", cfg.padded_vocab, d)
        self.enc_final = Norm("enc_final_norm", d, cfg.norm_type, cfg.norm_eps)
        self.final_norm = Norm("final_norm", d, cfg.norm_type, cfg.norm_eps)

        store = ParamStore()
        self.embedding.register(store)
        self.enc_final.register(store)
        self.final_norm.register(store)
        enc_store = ParamStore()
        for blk, nm in ((self.enc_norm1, None), (self.enc_attn, None),
                        (self.enc_norm2, None), (self.enc_mlp, None)):
            blk.register(enc_store)
        store.stacked(cfg.num_encoder_layers, "enc_layers", enc_store)
        dec_store = ParamStore()
        for blk in (self.dec_norm1, self.dec_self, self.dec_norm2, self.dec_cross,
                    self.dec_norm3, self.dec_mlp):
            blk.register(dec_store)
        store.stacked(cfg.num_layers, "dec_layers", dec_store)
        self.store = store
        # see transformer.LM: constrain per-layer params (and their
        # cotangents) to storage sharding inside the scan bodies
        self._enc_pspecs = enc_store.pspecs(topo)
        self._dec_pspecs = dec_store.pspecs(topo)

    def _constrain(self, layer_params, pspecs):
        if not self.topo.active:
            return layer_params
        return jax.tree.map(jax.lax.with_sharding_constraint, layer_params, pspecs)

    # ------------------------------------------------------------------
    def init_params(self, key):
        return self.store.init(key)

    def param_shapes(self):
        return self.store.shape_structs()

    def param_specs(self):
        return self.store.pspecs(self.topo)

    # ------------------------------------------------------------------
    def encode(self, params: dict, frames: jax.Array) -> jax.Array:
        """frames: (b, s_enc, d) stub embeddings -> encoder output."""
        cfg, topo = self.cfg, self.topo
        b, s, d = frames.shape
        pos = jnp.arange(s, dtype=jnp.int32)
        h = frames + sinusoidal(pos, d)[None].astype(frames.dtype)
        h = topo.shard(h, "batch", None, None)

        def body(h, lp):
            lp = self._constrain(lp, self._enc_pspecs)
            x = self.enc_norm1(lp["enc_attn"]["norm"], h)
            h = h + self.enc_attn(lp["enc_attn"]["core"], x, pos, topo)
            x = self.enc_norm2(lp["enc_mlp"]["norm"], h)
            h = h + self.enc_mlp(lp["enc_mlp"]["core"], x, topo)
            h = topo.shard(h, "batch", "seq_tp", None)
            return h, ()

        body = maybe_remat(body, cfg.remat and self.kind == "train")
        h, _ = jax.lax.scan(body, h, params["enc_layers"])
        return self.enc_final(params["enc_final_norm"], h)

    def _decoder_stack(self, params, h, positions, enc_out, enc_pos, collect: bool):
        cfg, topo = self.cfg, self.topo

        def body(carry, lp):
            h = carry
            lp = self._constrain(lp, self._dec_pspecs)
            kvs = {}
            x = self.dec_norm1(lp["dec_self"]["norm"], h)
            if collect:
                out, kv = self.dec_self(lp["dec_self"]["core"], x, positions, topo,
                                        return_kv=True)
                kvs["self"] = {"k": kv[0], "v": kv[1]}
            else:
                out = self.dec_self(lp["dec_self"]["core"], x, positions, topo)
            h = h + out
            x = self.dec_norm2(lp["dec_cross"]["norm"], h)
            if collect:
                out, kv = self.dec_cross(lp["dec_cross"]["core"], x, positions, topo,
                                         memory=enc_out, memory_positions=enc_pos,
                                         return_kv=True)
                kvs["cross"] = {"k": kv[0], "v": kv[1]}
            else:
                out = self.dec_cross(lp["dec_cross"]["core"], x, positions, topo,
                                     memory=enc_out, memory_positions=enc_pos)
            h = h + out
            x = self.dec_norm3(lp["dec_mlp"]["norm"], h)
            h = h + self.dec_mlp(lp["dec_mlp"]["core"], x, topo)
            h = topo.shard(h, "batch", "seq_tp", None)
            return h, kvs

        body = maybe_remat(body, cfg.remat and self.kind == "train")
        return jax.lax.scan(body, h, params["dec_layers"])

    # ------------------------------------------------------------------
    def loss(self, params: dict, batch: dict):
        cfg, topo = self.cfg, self.topo
        frames, tokens, labels = batch["frames"], batch["tokens"], batch["labels"]
        enc_out = self.encode(params, frames)
        b, s = tokens.shape
        pos = jnp.arange(s, dtype=jnp.int32)
        h = self.embedding.embed(params["embed"], tokens, topo)
        h = h + sinusoidal(pos, cfg.d_model)[None].astype(h.dtype)
        enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        h, _ = self._decoder_stack(params, h, pos, enc_out, enc_pos, False)
        h = self.final_norm(params["final_norm"], h)
        loss = chunked_ce_loss(self.embedding, params["embed"], h, labels,
                               cfg.vocab_size, topo)
        return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}

    def prefill(self, params: dict, batch: dict):
        """Encode audio + prefill decoder tokens -> (last logits, caches)."""
        cfg, topo = self.cfg, self.topo
        frames, tokens = batch["frames"], batch["tokens"]
        enc_out = self.encode(params, frames)
        b, s = tokens.shape
        pos = jnp.arange(s, dtype=jnp.int32)
        h = self.embedding.embed(params["embed"], tokens, topo)
        h = h + sinusoidal(pos, cfg.d_model)[None].astype(h.dtype)
        enc_pos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)
        h, kvs = self._decoder_stack(params, h, pos, enc_out, enc_pos, True)
        h = self.final_norm(params["final_norm"], h)
        logits = self.embedding.logits(params["embed"], h[:, -1], topo)
        caches = {}
        for grp in ("self", "cross"):
            caches[grp] = {
                kk: topo.shard(kvs[grp][kk], None, "batch", "seq_tp", None, None)
                for kk in ("k", "v")}
        return logits, caches

    def decode_step(self, params: dict, caches: dict, tokens: jax.Array, t):
        cfg, topo = self.cfg, self.topo
        h = self.embedding.embed(params["embed"], tokens, topo)
        h = h + sinusoidal(jnp.full((1,), t, jnp.int32), cfg.d_model)[0].astype(h.dtype)

        def body(h, xs):
            lp, lc = xs
            new_c = {}
            x = self.dec_norm1(lp["dec_self"]["norm"], h)
            out, (k_c, v_c) = self.dec_self.decode(
                lp["dec_self"]["core"], x, t, lc["self"]["k"], lc["self"]["v"], topo)
            new_c["self"] = {"k": k_c, "v": v_c}
            h = h + out
            x = self.dec_norm2(lp["dec_cross"]["norm"], h)
            out, _ = self.dec_cross.decode(
                lp["dec_cross"]["core"], x, t, lc["cross"]["k"], lc["cross"]["v"], topo,
                update_cache=False)
            new_c["cross"] = lc["cross"]
            h = h + out
            x = self.dec_norm3(lp["dec_mlp"]["norm"], h)
            h = h + self.dec_mlp(lp["dec_mlp"]["core"], x, topo)
            return h, new_c

        h, new_caches = jax.lax.scan(body, h, (params["dec_layers"], caches))
        h = self.final_norm(params["final_norm"], h)
        logits = self.embedding.logits(params["embed"], h, topo)
        return logits, new_caches

    # ------------------------------------------------------------------
    def cache_shape_structs(self, batch: int, seq: int,
                            memory_len: int | None = None) -> dict:
        """``seq`` sizes the growing self-attention cache; ``memory_len``
        (default: seq) is the fixed encoder-memory length for cross caches."""
        cfg = self.cfg
        n = cfg.num_layers
        mem = memory_len if memory_len is not None else seq
        kvd = (n, batch, seq, cfg.num_kv_heads, cfg.head_dim)
        kvx = (n, batch, mem, cfg.num_kv_heads, cfg.head_dim)
        return {
            "self": {"k": jax.ShapeDtypeStruct(kvd, jnp.bfloat16),
                     "v": jax.ShapeDtypeStruct(kvd, jnp.bfloat16)},
            "cross": {"k": jax.ShapeDtypeStruct(kvx, jnp.bfloat16),
                      "v": jax.ShapeDtypeStruct(kvx, jnp.bfloat16)},
        }

    def cache_pspecs(self, batch: int, seq: int,
                     memory_len: int | None = None) -> dict:
        topo = self.topo
        structs = self.cache_shape_structs(batch, seq, memory_len)
        axes = (None, "batch", "seq_tp", None, None)
        return {
            grp: {k: topo.pspec(axes, st.shape) for k, st in entry.items()}
            for grp, entry in structs.items()
        }
