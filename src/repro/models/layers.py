"""Core layers: norms, RoPE, MLPs, embeddings/logits.

All weights are stored 2D-sharded: a tensor-parallel dim on the "model" mesh
axis ("tp") and a ZeRO-3/FSDP dim on ("pod","data") ("fsdp"); the XLA SPMD
partitioner all-gathers the fsdp dim just-in-time inside each scan step
(MaxText-style), so optimizer state and gradients stay fully sharded.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, ParamStore, Topo, cross_entropy_loss


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Norm:
    name: str
    dim: int
    kind: str = "rmsnorm"   # rmsnorm | layernorm
    eps: float = 1e-5

    def register(self, store: ParamStore) -> None:
        store.add(f"{self.name}/scale", ParamDef((self.dim,), (None,), init="ones"))
        if self.kind == "layernorm":
            store.add(f"{self.name}/bias", ParamDef((self.dim,), (None,), init="zeros"))

    def __call__(self, p: dict, x: jax.Array) -> jax.Array:
        dt = x.dtype
        xf = x.astype(jnp.float32)
        if self.kind == "layernorm":
            mu = jnp.mean(xf, axis=-1, keepdims=True)
            var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
            y = (xf - mu) * jax.lax.rsqrt(var + self.eps)
            return (y * p["scale"].astype(jnp.float32)
                    + p["bias"].astype(jnp.float32)).astype(dt)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + self.eps)
        return (y * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    dim = x.shape[-1]
    freqs = rope_freqs(dim, theta)                       # (dim/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, dim/2)
    cos = jnp.cos(ang)[..., None, :]                     # (..., seq, 1, dim/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU), column+row tensor-parallel over "model"
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Mlp:
    name: str
    d_model: int
    d_ff: int
    activation: str = "swiglu"   # swiglu | gelu
    mode: str = "tp"             # tp: ff column/row-parallel over "model";
                                 # gathered: weights JIT-gathered, activations
                                 # stay sequence-sharded (fsdp_sp archs)
    zero3: bool = True           # ZeRO-3 storage dim (off for decode layouts)

    def register(self, store: ParamStore) -> None:
        d, f = self.d_model, self.d_ff
        fs = "fsdp" if self.zero3 else None
        if self.activation == "swiglu":
            store.add(f"{self.name}/w_gate", ParamDef((d, f), (fs, "tp")))
            store.add(f"{self.name}/w_up", ParamDef((d, f), (fs, "tp")))
        else:
            store.add(f"{self.name}/w_up", ParamDef((d, f), (fs, "tp")))
        store.add(f"{self.name}/w_down", ParamDef((f, d), ("tp", fs)))

    def __call__(self, p: dict, x: jax.Array, topo: Topo) -> jax.Array:
        two_d = x.ndim == 2
        seq_ax = "seq_tp" if (self.mode == "gathered" and not two_d) else None
        ff_ax = None if self.mode == "gathered" else "tp"
        if self.activation == "swiglu":
            g = x @ p["w_gate"]
            u = x @ p["w_up"]
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        else:
            u = x @ p["w_up"]
            h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
        if two_d:
            h = topo.shard(h, "batch", ff_ax)
            out = h @ p["w_down"]
            return topo.shard(out, "batch", None)
        h = topo.shard(h, "batch", seq_ax, ff_ax)
        out = h @ p["w_down"]
        # row-parallel output reduce-scattered onto the seq-sharded residual
        # (see attention._out; §Perf C1)
        return topo.shard(out, "batch", "seq_tp", None)


# ---------------------------------------------------------------------------
# Embedding + logits head
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Embedding:
    name: str
    vocab: int        # padded vocab
    d_model: int
    tie: bool = False
    seq_sharded: bool = False   # fsdp_sp archs: residual stream seq-sharded

    def register(self, store: ParamStore) -> None:
        # table is vocab-sharded over "model" (XLA partitions the gather via
        # clamp+select+psum) and ZeRO-sharded on d; head is vocab-column-TP
        store.add(
            f"{self.name}/table",
            ParamDef((self.vocab, self.d_model), ("tp", "fsdp"), scale=1.0),
        )
        if not self.tie:
            store.add(
                f"{self.name}/head",
                ParamDef((self.d_model, self.vocab), ("fsdp", "tp")),
            )

    def embed(self, p: dict, tokens: jax.Array, topo: Topo) -> jax.Array:
        # vocab-sharded table: XLA partitions the row gather (masked local
        # gather + psum); the backward scatter-add stays vocab-local.
        out = jnp.take(p["table"], tokens, axis=0)
        if out.ndim == 2:   # single-token decode (b, d)
            return topo.shard(out, "batch", None)
        return topo.shard(out, "batch", "seq_tp", None)

    def logits(self, p: dict, h: jax.Array, topo: Topo) -> jax.Array:
        # gather the residual over seq (if seq-sharded) once, then vocab-TP
        if h.ndim == 3:
            h = topo.shard(h, "batch", None, None)
        w = p["table"].T if self.tie else p["head"]
        out = h.astype(jnp.float32) @ w.astype(jnp.float32)
        if out.ndim == 2:
            return topo.shard(out, "batch", "tp")
        return topo.shard(out, "batch", None, "tp")


def chunked_ce_loss(embedding: Embedding, emb_params: dict, h: jax.Array,
                    labels: jax.Array, vocab_size: int, topo: Topo) -> jax.Array:
    """Cross-entropy in seq chunks so fp32 logits never materialize at full
    sequence length (a 256k-vocab 1M-token step would need ~1 TB otherwise)."""
    b, s, d = h.shape
    chunk = s
    for c in (512, 256, 128, 64):
        if s % c == 0 and s > c:
            chunk = c
            break
    nc = s // chunk
    h = topo.shard(h, "batch", None, None)
    hs = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        h_c, l_c = xs
        logits = embedding.logits(emb_params, h_c, topo)
        loss = cross_entropy_loss(logits, l_c, vocab_size)
        return carry + loss, ()

    # remat: per-chunk logits are recomputed in the backward pass rather
    # than saved (nc x 0.5 GiB/device otherwise)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return total / nc
