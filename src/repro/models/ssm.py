"""Mamba-1 selective-SSM block (falcon-mamba; jamba's mamba layers).

Tensor-parallel over ``d_inner`` ("tp" on the model axis): in/dt projections
column-parallel, x/out projections row-parallel, the selective scan itself is
fully local per d_inner shard (no comms inside the recurrence).

Sequence handling:
  * train/prefill: sequential ``lax.scan`` over chunks with an associative
    scan inside each chunk -> O(chunk * d_inner * d_state) transient memory.
  * decode: O(1)-state single-step recurrence (+ rolling conv window).
The Pallas kernel (kernels/ssm_scan.py) is the TPU execution path for the
within-chunk scan; this jnp path is the oracle and the dry-run/compile path.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, ParamStore, Topo


def ssm_chunk_scan(a: jax.Array, u: jax.Array, h0: jax.Array):
    """Inclusive scan of h_t = a_t * h_{t-1} + u_t along axis 1.

    a, u: (b, s, di, ds);  h0: (b, di, ds).  Returns (h_all, h_last).
    """

    def combine(x, y):
        a1, u1 = x
        a2, u2 = y
        return a1 * a2, a2 * u1 + u2

    a_s, u_s = jax.lax.associative_scan(combine, (a, u), axis=1)
    h = a_s * h0[:, None] + u_s
    return h, h[:, -1]


@dataclass(frozen=True)
class MambaBlock:
    name: str
    d_model: int
    d_inner: int
    d_state: int
    d_conv: int
    dt_rank: int
    layout: str = "megatron"       # megatron | decode_rp
    chunk: int = 128
    # "sequential": lax.scan over time inside each chunk — O(state) HBM
    #   traffic per step (matches the Pallas kernel's dataflow; §Perf F1)
    # "associative": log-depth associative scan — ~14 full-tensor passes of
    #   (b, chunk, di, ds) per chunk (the measured 60x byte hog; kept as the
    #   paper-faithful-baseline/ablation path)
    scan_impl: str = "sequential"

    @property
    def _fsdp(self) -> str | None:
        # decode keeps weights fully resident (tp-sharded only)
        return None if self.layout == "decode_rp" else "fsdp"

    def register(self, store: ParamStore) -> None:
        d, di, ds, dr, K = self.d_model, self.d_inner, self.d_state, self.dt_rank, self.d_conv
        n = self.name
        store.add(f"{n}/w_in", ParamDef((d, 2 * di), (self._fsdp, "tp")))
        store.add(f"{n}/conv_w", ParamDef((K, di), (None, "tp"), scale=0.5))
        store.add(f"{n}/conv_b", ParamDef((di,), ("tp",), init="zeros"))
        store.add(f"{n}/w_x", ParamDef((di, dr + 2 * ds), ("tp", None)))
        store.add(f"{n}/w_dt", ParamDef((dr, di), (None, "tp")))
        store.add(f"{n}/dt_bias", ParamDef((di,), ("tp",), init="mamba_dt"))
        store.add(f"{n}/A_log", ParamDef((di, ds), ("tp", None), init="mamba_a"))
        store.add(f"{n}/D", ParamDef((di,), ("tp",), init="ones"))
        store.add(f"{n}/w_out", ParamDef((di, d), ("tp", self._fsdp)))

    # ------------------------------------------------------------------
    def _conv(self, p: dict, x: jax.Array) -> jax.Array:
        """Causal depthwise conv along seq via K shifted adds. x: (b,s,di)."""
        K = self.d_conv
        pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        s = x.shape[1]
        out = p["conv_b"].astype(x.dtype)[None, None, :] * jnp.ones_like(x)
        for k in range(K):
            out = out + pad[:, k:k + s, :] * p["conv_w"][k][None, None, :]
        return out

    def _ssm_raw(self, p: dict, x: jax.Array, topo: Topo):
        """x: (b,s,di) post-conv post-silu -> (dt (b,s,di) f32, B, C (b,s,ds))."""
        xdb = jnp.einsum("bsi,ir->bsr", x, p["w_x"])
        xdb = topo.shard(xdb, "batch", None, None)
        dt_raw, B, C = jnp.split(xdb, [self.dt_rank, self.dt_rank + self.d_state], axis=-1)
        dt = jnp.einsum("bsr,ri->bsi", dt_raw, p["w_dt"]) + p["dt_bias"]
        dt = jax.nn.softplus(dt.astype(jnp.float32))
        return dt, B.astype(jnp.float32), C.astype(jnp.float32)

    def _ssm_inputs(self, p: dict, x: jax.Array, topo: Topo):
        """x: (b,s,di) post-conv post-silu -> (decay a, drive u, C, dt)."""
        dt, B, C = self._ssm_raw(p, x, topo)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, ds)
        a = jnp.exp(dt[..., None] * A)                        # (b,s,di,ds)
        u = (dt * x.astype(jnp.float32))[..., None] * B[:, :, None, :]
        return a, u, C, dt

    # -- full-sequence forward (train / prefill) -------------------------
    def __call__(self, p: dict, h: jax.Array, positions, topo: Topo,
                 return_state: bool = False, **_):
        b, s, d = h.shape
        xz = jnp.einsum("bsd,de->bse", h, p["w_in"])
        xz = topo.shard(xz, "batch", None, "tp")
        x_pre, z = jnp.split(xz, 2, axis=-1)
        x = self._conv(p, x_pre)
        x = jax.nn.silu(x.astype(jnp.float32)).astype(h.dtype)

        chunk = min(self.chunk, s)
        nc = s // chunk
        di = self.d_inner

        A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (di, ds)

        def body(carry, x_c):
            # x_c: (b, chunk, di).  SSM inputs are built per chunk so the
            # (b, chunk, di, ds) tensors never materialize at full seq len.
            h0 = carry
            if self.scan_impl == "associative":
                a_c, u_c, C_c, _ = self._ssm_inputs(p, x_c, topo)
                hs, h_last = ssm_chunk_scan(a_c, u_c, h0)
                y_c = jnp.einsum("bsin,bsn->bsi", hs, C_c)
            else:
                # sequential: the (di, ds) expansion happens per step, so
                # only the (b, di, ds) state (+ per-token rows) touches HBM —
                # the same dataflow as the Pallas ssm_scan kernel
                dt_c, B_c, C_c = self._ssm_raw(p, x_c, topo)

                def step(hh, xs):
                    dt_t, x_t, b_t, c_t = xs                   # (b,di),(b,di),(b,ds)
                    a_t = jnp.exp(dt_t[..., None] * A)         # (b,di,ds)
                    u_t = (dt_t * x_t)[..., None] * b_t[:, None, :]
                    hh = a_t * hh + u_t
                    return hh, jnp.einsum("bin,bn->bi", hh, c_t)

                xs = (dt_c.transpose(1, 0, 2),
                      x_c.astype(jnp.float32).transpose(1, 0, 2),
                      B_c.transpose(1, 0, 2), C_c.transpose(1, 0, 2))
                h_last, ys = jax.lax.scan(step, h0, xs)
                y_c = ys.transpose(1, 0, 2)
            return h_last, y_c.astype(h.dtype)

        # remat: recompute the (b, chunk, di, ds) scan intermediates in bwd
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x_r = x.reshape(b, nc, chunk, di).transpose(1, 0, 2, 3)
        h0 = jnp.zeros((b, di, self.d_state), jnp.float32)
        h_last, ys = jax.lax.scan(body, h0, x_r)
        y = ys.transpose(1, 0, 2, 3).reshape(b, s, di).astype(jnp.float32)
        y = y + p["D"].astype(jnp.float32) * x.astype(jnp.float32)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype)
        y = topo.shard(y, "batch", None, "tp")
        out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
        out = topo.shard(out, "batch", None, None)
        if return_state:
            # conv tail: last K-1 pre-conv inputs, for decode continuation
            conv_tail = x_pre[:, s - (self.d_conv - 1):, :]
            return out, (h_last, conv_tail)
        return out

    # -- single-token decode ---------------------------------------------
    def decode(self, p: dict, h: jax.Array, t, state: jax.Array,
               conv_state: jax.Array, topo: Topo):
        """h: (b, d); state: (b, di, ds) f32; conv_state: (b, K-1, di)."""
        b, d = h.shape
        xz = jnp.einsum("bd,de->be", h, p["w_in"])
        xz = topo.shard(xz, "batch", "tp")
        x, z = jnp.split(xz, 2, axis=-1)                      # (b, di)
        window = jnp.concatenate([conv_state, x[:, None, :]], axis=1)  # (b,K,di)
        conv_state = window[:, 1:, :]
        x = jnp.einsum("bki,ki->bi", window, p["conv_w"]) + p["conv_b"]
        x = jax.nn.silu(x.astype(jnp.float32)).astype(h.dtype)
        a, u, C, _ = self._ssm_inputs(p, x[:, None, :], topo)
        state = a[:, 0] * state + u[:, 0]                     # (b, di, ds)
        y = jnp.einsum("bin,bn->bi", state, C[:, 0])
        y = y + p["D"].astype(jnp.float32) * x.astype(jnp.float32)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(h.dtype)
        out = jnp.einsum("bi,id->bd", y, p["w_out"])
        out = topo.shard(out, "batch", None)
        return out, (state, conv_state)
