from repro.models.common import ParamDef, ParamStore, Topo, SMOKE_TOPO, make_mesh_from_config
from repro.models.model_zoo import build_model, input_specs, input_pspecs, make_batch
