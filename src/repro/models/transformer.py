"""Decoder-only LM assembly for dense / moe / ssm / hybrid / vlm families.

The layer stack is expressed as a repeating *period* (dense: 1 layer;
llama-vision: 5 layers with a cross-attention block on the 5th; jamba: 8
layers = 7 mamba + 1 attention with MoE on alternating layers) and scanned
with ``lax.scan`` over stacked period parameters, so HLO size is O(period),
not O(depth) — this keeps 512-device SPMD compiles fast.

Entry points (selected by ``kind``):
  * train   — ``loss(params, batch)``; loss is computed in seq chunks so the
              fp32 logits for 256k vocabs never materialize at full length.
  * prefill — ``prefill(params, batch)`` -> (last-token logits, caches)
  * decode  — ``decode_step(params, caches, tokens, t)`` (single new token
              against sequence-sharded caches)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import Attention, MLAttention
from repro.models.common import (
    ParamDef,
    ParamStore,
    Topo,
    cross_entropy_loss,
    maybe_remat,
)
from repro.models.layers import Embedding, Mlp, Norm, chunked_ce_loss
from repro.models.moe import MoE
from repro.models.ssm import MambaBlock


@dataclass(frozen=True)
class SubLayer:
    name: str
    kind: str                  # attn | cross | mla | mamba | mlp | moe
    norm: Norm
    block: Any
    gated: bool = False        # tanh-gated residual (vlm cross-attn)

    def register(self, store: ParamStore) -> None:
        self.norm.register(store)
        self.block.register(store)
        if self.gated:
            store.add(f"{self.name}/gate", ParamDef((1,), (None,), init="zeros"))


def _attn_layout(cfg: ModelConfig, topo: Topo, kind: str) -> str:
    if kind == "decode":
        return "decode_rp"
    tp = topo.axis_size("tp")
    if cfg.num_heads and cfg.num_heads % max(tp, 1) == 0:
        return "megatron"
    return "fsdp_sp"


def _moe_placement(cfg: ModelConfig, topo: Topo, kind: str) -> str:
    tp = topo.axis_size("tp")
    ep_ok = cfg.moe_num_experts % max(tp, 1) == 0
    if kind == "decode":
        return "ep_decode" if ep_ok else "tp_decode"
    return "ep" if ep_ok else "gathered"


def build_period(cfg: ModelConfig, topo: Topo, kind: str) -> tuple[list[SubLayer], int]:
    """Sublayers of one period + number of periods."""
    layout = _attn_layout(cfg, topo, kind)
    moe_place = _moe_placement(cfg, topo, kind)
    if cfg.family == "hybrid":
        period_len = cfg.attn_period
    elif cfg.family == "vlm":
        period_len = cfg.cross_attn_period
    elif cfg.layers_per_period and cfg.num_layers % cfg.layers_per_period == 0:
        period_len = cfg.layers_per_period
    else:
        period_len = 1
    subs: list[SubLayer] = []
    zero3 = kind != "decode"

    def norm(n: str) -> Norm:
        return Norm(f"{n}/norm", cfg.d_model, cfg.norm_type, cfg.norm_eps)

    for j in range(period_len):
        # ---- mixer ----
        if cfg.family == "ssm" or (cfg.family == "hybrid" and not cfg.is_attn_layer(j)):
            n = f"l{j}_mamba"
            subs.append(SubLayer(n, "mamba", norm(n), MambaBlock(
                f"{n}/core", cfg.d_model, cfg.d_inner, cfg.ssm_state,
                cfg.ssm_conv, cfg.dt_rank,
                layout=layout if kind == "decode" else "megatron",
                scan_impl=cfg.ssm_scan_impl)))
        elif cfg.use_mla:
            n = f"l{j}_mla"
            subs.append(SubLayer(n, "mla", norm(n), MLAttention(
                f"{n}/core", cfg.d_model, cfg.num_heads, cfg.kv_lora_rank,
                cfg.mla_qk_nope, cfg.qk_rope_dim, cfg.mla_v_dim,
                layout="decode_rp" if kind == "decode" else "megatron",
                rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)))
        else:
            n = f"l{j}_attn"
            subs.append(SubLayer(n, "attn", norm(n), Attention(
                f"{n}/core", cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim, layout=layout, rope_theta=cfg.rope_theta,
                use_rope=cfg.rope_theta > 0, qkv_bias=cfg.qkv_bias,
                out_bias=cfg.attn_out_bias)))
        # ---- vlm cross-attention on the last layer of each period ----
        if cfg.family == "vlm" and j == period_len - 1:
            n = f"l{j}_cross"
            subs.append(SubLayer(n, "cross", norm(n), Attention(
                f"{n}/core", cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.head_dim, layout=layout, use_rope=False,
                is_cross=True, causal=False), gated=True))
        # ---- ffn ----
        if cfg.is_moe_layer(j):
            n = f"l{j}_moe"
            subs.append(SubLayer(n, "moe", norm(n), MoE(
                f"{n}/core", cfg.d_model, cfg.moe_num_experts, cfg.moe_top_k,
                cfg.moe_d_ff, num_shared=cfg.moe_num_shared,
                group_size=cfg.moe_group_size, capacity_factor=cfg.capacity_factor,
                placement=moe_place)))
        elif cfg.d_ff:
            n = f"l{j}_mlp"
            subs.append(SubLayer(n, "mlp", norm(n), Mlp(
                f"{n}/core", cfg.d_model, cfg.d_ff, cfg.mlp_activation,
                mode="gathered" if layout == "fsdp_sp" else "tp", zero3=zero3)))
    n_periods = cfg.num_layers // period_len
    return subs, n_periods


class LM:
    """Decoder-only language model over a repeating period stack."""

    def __init__(self, cfg: ModelConfig, topo: Topo, kind: str = "train"):
        assert kind in ("train", "prefill", "decode")
        self.cfg, self.topo, self.kind = cfg, topo, kind
        self.layout = _attn_layout(cfg, topo, kind)
        self.seq_sharded = self.layout == "fsdp_sp"
        self.period, self.n_periods = build_period(cfg, topo, kind)

        self.embedding = Embedding("embed", cfg.padded_vocab, cfg.d_model,
                                   tie=cfg.tie_embeddings,
                                   seq_sharded=self.seq_sharded)
        self.final_norm = Norm("final_norm", cfg.d_model, cfg.norm_type, cfg.norm_eps)
        store = ParamStore()
        self.embedding.register(store)
        self.final_norm.register(store)
        pstore = ParamStore()
        for sub in self.period:
            sub.register(pstore)
        store.stacked(self.n_periods, "layers", pstore)
        self.store = store
        self._pstore = pstore
        # per-period specs, re-applied inside the scan body: the transpose of
        # with_sharding_constraint constrains weight *cotangents* too, forcing
        # per-iteration reduce-scatter of ZeRO-sharded grads (without this the
        # stacked grad buffers materialize gathered: ~16x memory)
        self._period_pspecs = pstore.pspecs(topo)

    # ------------------------------------------------------------------
    def init_params(self, key: jax.Array) -> dict:
        return self.store.init(key)

    def param_shapes(self) -> dict:
        return self.store.shape_structs()

    def param_specs(self) -> dict:
        return self.store.pspecs(self.topo)

    # ------------------------------------------------------------------
    def _seq_axis(self):
        return "seq_tp" if self.seq_sharded else None

    def _memory(self, batch: dict) -> jax.Array | None:
        return batch.get("image_embeds")

    def _apply_period(self, p: dict, h, positions, memory, collect: bool):
        aux = jnp.zeros((), jnp.float32)
        kvs: dict[str, Any] = {}
        topo = self.topo
        for sub in self.period:
            sp = p[sub.name]
            # (§Perf C2, refuted & reverted: pre-gathering the bf16 residual
            # before the f32-internal norm did NOT shrink collectives — the
            # f32 comms are backward-pass cotangents — and cost +65% memory
            # from the extra materialized gather.)
            x = sub.norm(sp["norm"], h)
            if sub.kind in ("attn", "mla"):
                if collect:
                    out, kv = sub.block(sp["core"], x, positions, topo, return_kv=True)
                    if sub.kind == "mla":
                        kvs[sub.name] = {"ckv": kv[0], "krope": kv[1]}
                    else:
                        kvs[sub.name] = {"k": kv[0], "v": kv[1]}
                else:
                    out = sub.block(sp["core"], x, positions, topo)
            elif sub.kind == "cross":
                mem_pos = jnp.arange(memory.shape[1], dtype=jnp.int32)
                if collect:
                    out, kv = sub.block(sp["core"], x, positions, topo,
                                        memory=memory, memory_positions=mem_pos,
                                        return_kv=True)
                    kvs[sub.name] = {"k": kv[0], "v": kv[1]}
                else:
                    out = sub.block(sp["core"], x, positions, topo,
                                    memory=memory, memory_positions=mem_pos)
            elif sub.kind == "mamba":
                if collect:
                    out, (state, conv) = sub.block(sp["core"], x, positions, topo,
                                                   return_state=True)
                    kvs[sub.name] = {"state": state, "conv": conv}
                else:
                    out = sub.block(sp["core"], x, positions, topo)
            elif sub.kind == "moe":
                out, aux_i = sub.block(sp["core"], x, topo)
                aux = aux + aux_i
            else:  # mlp
                out = sub.block(sp["core"], x, topo)
            if sub.gated:
                out = jnp.tanh(sp["gate"].astype(jnp.float32)).astype(out.dtype) * out
            h = h + out
        # Megatron-SP-style boundary: the residual stream is sequence-sharded
        # over "model" between periods, so remat checkpoints 1/tp of it; XLA
        # inserts the AG/RS pair inside the (rematerialized) layer body.
        h = self.topo.shard(h, "batch", "seq_tp", None)
        return h, aux, kvs

    def _stack(self, params, h, positions, memory, collect: bool):
        def body(carry, layer_params):
            h, aux = carry
            if self.topo.active:
                layer_params = jax.tree.map(
                    jax.lax.with_sharding_constraint, layer_params,
                    self._period_pspecs)
            h, aux_i, kvs = self._apply_period(layer_params, h, positions,
                                               memory, collect)
            return (h, aux + aux_i), kvs

        body = maybe_remat(body, self.cfg.remat and self.kind == "train")
        (h, aux), kvs = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                     params["layers"])
        return h, aux, kvs

    # ------------------------------------------------------------------
    def loss(self, params: dict, batch: dict):
        cfg, topo = self.cfg, self.topo
        tokens, labels = batch["tokens"], batch["labels"]
        b, s = tokens.shape
        h = self.embedding.embed(params["embed"], tokens, topo)
        positions = jnp.arange(s, dtype=jnp.int32)
        h, aux, _ = self._stack(params, h, positions, self._memory(batch), False)
        h = self.final_norm(params["final_norm"], h)
        loss = chunked_ce_loss(self.embedding, params["embed"], h, labels,
                               cfg.vocab_size, topo)
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux_loss": aux}

    # ------------------------------------------------------------------
    def prefill(self, params: dict, batch: dict):
        cfg, topo = self.cfg, self.topo
        tokens = batch["tokens"]
        b, s = tokens.shape
        h = self.embedding.embed(params["embed"], tokens, topo)
        positions = jnp.arange(s, dtype=jnp.int32)
        h, _, kvs = self._stack(params, h, positions, self._memory(batch), True)
        h = self.final_norm(params["final_norm"], h)
        last = topo.shard(h[:, -1], "batch", None)
        logits = self.embedding.logits(params["embed"], last, topo)
        caches = self._shard_caches(kvs)
        return logits, caches

    def _shard_caches(self, kvs):
        out = {}
        for name, entry in kvs.items():
            se = {}
            for kname, v in entry.items():
                if kname in ("k", "v"):
                    se[kname] = self.topo.shard(v, None, "batch", "seq_tp", None, None)
                elif kname == "ckv" or kname == "krope":
                    se[kname] = self.topo.shard(v, None, "batch", "seq_tp", None)
                elif kname == "state":
                    se[kname] = self.topo.shard(v, None, "batch", "tp", None)
                else:  # conv tail
                    se[kname] = self.topo.shard(v, None, "batch", None, "tp")
            out[name] = se
        return out

    # ------------------------------------------------------------------
    def decode_step(self, params: dict, caches: dict, tokens: jax.Array,
                    t: jax.Array):
        """tokens: (b,) int32; t: scalar int32 position. Returns (logits, caches)."""
        cfg, topo = self.cfg, self.topo
        h = self.embedding.embed(params["embed"], tokens, topo)   # (b, d)

        def body(h, xs):
            lp, lc = xs
            new_c = {}
            for sub in self.period:
                sp = lp[sub.name]
                x = sub.norm(sp["norm"], h)
                if sub.kind == "attn":
                    out, (k_c, v_c) = sub.block.decode(
                        sp["core"], x, t, lc[sub.name]["k"], lc[sub.name]["v"], topo)
                    new_c[sub.name] = {"k": k_c, "v": v_c}
                elif sub.kind == "cross":
                    out, _ = sub.block.decode(
                        sp["core"], x, t, lc[sub.name]["k"], lc[sub.name]["v"], topo,
                        update_cache=False)
                    new_c[sub.name] = lc[sub.name]
                elif sub.kind == "mla":
                    out, (c_c, r_c) = sub.block.decode(
                        sp["core"], x, t, lc[sub.name]["ckv"], lc[sub.name]["krope"], topo)
                    new_c[sub.name] = {"ckv": c_c, "krope": r_c}
                elif sub.kind == "mamba":
                    out, (state, conv) = sub.block.decode(
                        sp["core"], x, t, lc[sub.name]["state"], lc[sub.name]["conv"], topo)
                    new_c[sub.name] = {"state": state, "conv": conv}
                elif sub.kind == "moe":
                    out, _ = sub.block(sp["core"], x, topo)
                else:
                    out = sub.block(sp["core"], x, topo)
                if sub.gated:
                    out = jnp.tanh(sp["gate"].astype(jnp.float32)).astype(out.dtype) * out
                h = h + out
            return h, new_c

        h, new_caches = jax.lax.scan(body, h, (params["layers"], caches))
        h = self.final_norm(params["final_norm"], h)
        logits = self.embedding.logits(params["embed"], h, topo)
        return logits, new_caches

    # ------------------------------------------------------------------
    def cache_shape_structs(self, batch: int, seq: int) -> dict:
        """ShapeDtypeStructs for decode caches (stacked over periods)."""
        cfg = self.cfg
        n = self.n_periods
        out = {}
        for sub in self.period:
            if sub.kind == "attn":
                kvd = (n, batch, seq, cfg.num_kv_heads, cfg.head_dim)
                out[sub.name] = {
                    "k": jax.ShapeDtypeStruct(kvd, jnp.bfloat16),
                    "v": jax.ShapeDtypeStruct(kvd, jnp.bfloat16)}
            elif sub.kind == "cross":
                kvd = (n, batch, cfg.num_image_tokens, cfg.num_kv_heads, cfg.head_dim)
                out[sub.name] = {
                    "k": jax.ShapeDtypeStruct(kvd, jnp.bfloat16),
                    "v": jax.ShapeDtypeStruct(kvd, jnp.bfloat16)}
            elif sub.kind == "mla":
                out[sub.name] = {
                    "ckv": jax.ShapeDtypeStruct((n, batch, seq, cfg.kv_lora_rank), jnp.bfloat16),
                    "krope": jax.ShapeDtypeStruct((n, batch, seq, cfg.qk_rope_dim), jnp.bfloat16)}
            elif sub.kind == "mamba":
                out[sub.name] = {
                    "state": jax.ShapeDtypeStruct((n, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
                    "conv": jax.ShapeDtypeStruct((n, batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.bfloat16)}
        return out

    def cache_pspecs(self, batch: int, seq: int) -> dict:
        """PartitionSpecs congruent with cache_shape_structs(batch, seq)."""
        topo = self.topo
        structs = self.cache_shape_structs(batch, seq)
        axes_by_key = {
            "k": (None, "batch", "seq_tp", None, None),
            "v": (None, "batch", "seq_tp", None, None),
            "ckv": (None, "batch", "seq_tp", None),
            "krope": (None, "batch", "seq_tp", None),
            "state": (None, "batch", "tp", None),
            "conv": (None, "batch", None, "tp"),
        }
        out = {}
        for name, entry in structs.items():
            out[name] = {
                key: topo.pspec(axes_by_key[key], st.shape)
                for key, st in entry.items()
            }
        return out
