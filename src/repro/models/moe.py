"""Mixture-of-Experts with GShard-style capacity-limited dense dispatch.

Two expert placements (picked per arch by divisibility, see DESIGN.md):
  * EP  — expert dim sharded over "model" (deepseek 160/16, jamba 16/16);
          expert d_model dim additionally ZeRO-sharded over ("pod","data").
  * TP  — experts replicated, expert d_ff sharded over "model"
          (granite: 40 experts don't divide 16).

Dispatch/combine are one-hot einsums (MXU-friendly, fully static shapes).
Tokens are grouped into (G, S) groups; capacity C = ceil(S*topk*cf / E).
Dropped tokens (over capacity) pass through the residual unchanged — the
standard capacity-dropping semantics.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, ParamStore, Topo


@dataclass(frozen=True)
class MoE:
    name: str
    d_model: int
    num_experts: int
    top_k: int
    d_ff: int
    num_shared: int = 0
    group_size: int = 256
    capacity_factor: float = 1.25
    placement: str = "ep"
    # ep         (train/prefill): experts over "model"; d dim ZeRO-3 sharded
    # gathered   (train/prefill, fsdp_sp archs): weights JIT-gathered, tokens
    #            sharded over all axes
    # ep_decode  (decode): experts over ("pod","data"), ff over "model",
    #            tokens replicated (single group) — fully-resident weights
    # tp_decode  (decode, small E): experts replicated, ff over "model"
    activation: str = "swiglu"

    @property
    def token_axis(self) -> str | None:
        return {"ep": "batch", "gathered": "all",
                "ep_decode": None, "tp_decode": "batch"}[self.placement]

    @property
    def expert_axis(self) -> str | None:
        return {"ep": "tp", "gathered": None,
                "ep_decode": "fsdp", "tp_decode": None}[self.placement]

    @property
    def ff_axis(self) -> str | None:
        return {"ep": None, "gathered": None,
                "ep_decode": "tp", "tp_decode": "tp"}[self.placement]

    def capacity(self, group_tokens: int) -> int:
        c = math.ceil(group_tokens * self.top_k * self.capacity_factor / self.num_experts)
        return max(c, 1)

    def register(self, store: ParamStore) -> None:
        d, E, f = self.d_model, self.num_experts, self.d_ff
        n = self.name
        if self.placement == "ep":
            ax_in = ("tp", "fsdp", None)       # (E, d, f)
            ax_out = ("tp", None, "fsdp")      # (E, f, d)
            ax_sh_in, ax_sh_out = ("fsdp", "tp"), ("tp", "fsdp")
        elif self.placement == "gathered":
            ax_in = (None, "fsdp", "tp")
            ax_out = (None, "tp", "fsdp")
            ax_sh_in, ax_sh_out = ("fsdp", "tp"), ("tp", "fsdp")
        elif self.placement == "ep_decode":
            ax_in = ("fsdp", None, "tp")
            ax_out = ("fsdp", "tp", None)
            ax_sh_in, ax_sh_out = (None, "tp"), ("tp", None)
        else:  # tp_decode
            ax_in = (None, None, "tp")
            ax_out = (None, "tp", None)
            ax_sh_in, ax_sh_out = (None, "tp"), ("tp", None)
        store.add(f"{n}/router", ParamDef((d, E), (None, None), scale=0.02))
        store.add(f"{n}/w_gate", ParamDef((E, d, f), ax_in))
        store.add(f"{n}/w_up", ParamDef((E, d, f), ax_in))
        store.add(f"{n}/w_down", ParamDef((E, f, d), ax_out))
        if self.num_shared:
            fs = self.num_shared * f
            store.add(f"{n}/ws_gate", ParamDef((d, fs), ax_sh_in))
            store.add(f"{n}/ws_up", ParamDef((d, fs), ax_sh_in))
            store.add(f"{n}/ws_down", ParamDef((fs, d), ax_sh_out))

    # ------------------------------------------------------------------
    def _route(self, p: dict, xg: jax.Array):
        """xg: (G, S, d) -> combine (G,S,E,C) bf16, dispatch mask, aux loss."""
        G, S, d = xg.shape
        E, k = self.num_experts, self.top_k
        C = self.capacity(S)
        # keep the big operand in bf16; accumulate in f32 (upcasting xg would
        # materialize the full token tensor in f32)
        logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(xg.dtype),
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)             # (G,S,E)
        topv, topi = jax.lax.top_k(probs, k)                # (G,S,k)
        topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

        # aux load-balancing loss (Switch): E * sum(frac_tokens * frac_probs)
        sel_onehot = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
        frac_tokens = jnp.mean(sel_onehot, axis=(0, 1))
        frac_probs = jnp.mean(probs, axis=(0, 1))
        aux = E * jnp.sum(frac_tokens * frac_probs)

        combine = jnp.zeros((G, S, E, C), jnp.float32)
        counts = jnp.zeros((G, E), jnp.float32)             # capacity used so far
        for j in range(k):
            mask_j = jax.nn.one_hot(topi[..., j], E, dtype=jnp.float32)   # (G,S,E)
            pos_j = counts[:, None, :] + jnp.cumsum(mask_j, axis=1) - mask_j
            keep = mask_j * (pos_j < C)
            onehot_pos = jax.nn.one_hot(pos_j.astype(jnp.int32), C, dtype=jnp.float32)
            combine = combine + keep[..., None] * onehot_pos * topv[..., j][..., None, None]
            counts = counts + jnp.sum(keep, axis=1)
        dispatch = (combine > 0).astype(xg.dtype)
        return combine.astype(jnp.float32), dispatch, aux

    def _experts(self, p: dict, xe: jax.Array, topo: Topo) -> jax.Array:
        """xe: (E, G, C, d) -> (E, G, C, d)."""
        xe = topo.shard(xe, self.expert_axis, self.token_axis, None, None)
        g = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"])
        u = jnp.einsum("egcd,edf->egcf", xe, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xe.dtype) * u
        h = topo.shard(h, self.expert_axis, self.token_axis, None, self.ff_axis)
        out = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
        return topo.shard(out, self.expert_axis, self.token_axis, None, None)

    def _shared(self, p: dict, x: jax.Array, topo: Topo) -> jax.Array:
        two_d = x.ndim == 2
        seq_ax = "seq_tp" if (self.placement == "gathered" and not two_d) else None
        ff_ax = None if self.placement == "gathered" else "tp"
        g = x @ p["ws_gate"]
        u = x @ p["ws_up"]
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        if two_d:
            h = topo.shard(h, "batch", ff_ax)
            return topo.shard(h @ p["ws_down"], "batch", None)
        h = topo.shard(h, "batch", seq_ax, ff_ax)
        out = h @ p["ws_down"]
        return topo.shard(out, "batch", seq_ax, None)

    def __call__(self, p: dict, x: jax.Array, topo: Topo):
        """x: (b, s, d) or (b, d) -> (out, aux_loss)."""
        two_d = x.ndim == 2
        xs = x[:, None, :] if two_d else x
        b, s, d = xs.shape
        T = b * s
        # group count must stay divisible by the token-sharding axes, and
        # S must divide T exactly (snap to the largest divisor)
        n_shards = max(topo.axis_size(self.token_axis), 1) if self.token_axis else 1
        S = min(self.group_size, max(T // n_shards, 1))
        while S > 1 and T % S:
            S -= 1
        G = T // S
        xg = xs.reshape(G, S, d)
        xg = topo.shard(xg, self.token_axis, None, None)
        combine, dispatch, aux = self._route(p, xg)
        xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
        ye = self._experts(p, xe, topo)
        yg = jnp.einsum("gsec,egcd->gsd", combine.astype(ye.dtype), ye)
        out = yg.reshape(b, s, d)
        seq_ax = "seq_tp" if (self.placement == "gathered" and s > 1) else None
        out = topo.shard(out, "batch", seq_ax, None)
        if self.num_shared:
            out = out + self._shared(p, xs, topo)
        if two_d:
            out = out[:, 0, :]
        return out, aux
