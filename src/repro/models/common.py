"""Shared model plumbing: logical-axis sharding, parameter stores, topology.

Sharding scheme (see DESIGN.md):

* Logical axes map to mesh axes via per-topology rules:
    - "batch"  -> ("pod", "data")      activations' batch dim
    - "tp"     -> "model"              tensor-parallel dim (heads / ff / vocab /
                                       d_inner / experts)
    - "fsdp"   -> ("pod", "data")      ZeRO-3-style parameter sharding dim;
                                       weights are gathered just-in-time by the
                                       XLA SPMD partitioner inside each scan step
    - "seq_tp" -> "model"              KV-cache sequence dim at decode, and
                                       q-sequence for seq-sharded attention
* Every rule application is divisibility-checked; a dim that does not divide
  the mesh axes falls back to replication for that dim (never errors).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig


# ---------------------------------------------------------------------------
# Topology: mesh + logical rules
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Topo:
    """Resolved mesh topology + logical->physical axis rules."""

    mesh_cfg: MeshConfig
    active: bool = True  # False -> all sharding constraints become no-ops

    # ------------------------------------------------------------------
    def axis_size(self, logical: str) -> int:
        phys = self._phys(logical)
        n = 1
        for a in phys:
            n *= self.mesh_cfg.shape[self.mesh_cfg.axis_names.index(a)]
        return n

    def _phys(self, logical: str) -> tuple[str, ...]:
        names = self.mesh_cfg.axis_names
        if logical in ("batch", "fsdp"):
            return tuple(a for a in ("pod", "data") if a in names)
        if logical in ("tp", "seq_tp"):
            return tuple(a for a in ("model",) if a in names)
        if logical == "all":
            return tuple(a for a in ("pod", "data", "model") if a in names)
        if logical == "none":
            return ()
        raise KeyError(f"unknown logical axis {logical!r}")

    def resolve(self, logical: str | None, dim_size: int) -> tuple[str, ...] | None:
        """Physical axes for a dim, or None if not divisible / unsharded.

        Multi-axis logicals fall back to a suffix of their axes when the full
        product does not divide (e.g. 16 experts over (pod=2, data=16) ->
        shard over data only)."""
        if logical is None:
            return None
        phys = self._phys(logical)
        while phys:
            n = 1
            for a in phys:
                n *= self.mesh_cfg.shape[self.mesh_cfg.axis_names.index(a)]
            if n > 0 and dim_size % n == 0:
                return phys
            phys = phys[1:]
        return None

    def pspec(self, axes: tuple[str | None, ...], shape: tuple[int, ...]) -> P:
        entries = []
        for logical, dim in zip(axes, shape):
            phys = self.resolve(logical, dim)
            if phys is None:
                entries.append(None)
            elif len(phys) == 1:
                entries.append(phys[0])
            else:
                entries.append(phys)
        # trim trailing Nones (canonical form)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def shard(self, x: jax.Array, *axes: str | None) -> jax.Array:
        """Apply a sharding constraint on activations (no-op when inactive)."""
        if not self.active:
            return x
        spec = self.pspec(tuple(axes), x.shape)
        return jax.lax.with_sharding_constraint(x, spec)


SMOKE_TOPO = Topo(MeshConfig(shape=(1, 1), axis_names=("data", "model")), active=False)


def make_mesh_from_config(mesh_cfg: MeshConfig) -> Mesh:
    return jax.make_mesh(
        mesh_cfg.shape,
        mesh_cfg.axis_names,
        axis_types=(jax.sharding.AxisType.Auto,) * len(mesh_cfg.axis_names),
    )


# ---------------------------------------------------------------------------
# Parameter store
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]       # logical axis per dim
    init: str = "normal"               # normal | zeros | ones | mamba_a | mamba_dt
    scale: float | None = None         # None -> 1/sqrt(fan_in)
    dtype: str = "bfloat16"

    def fan_in(self) -> int:
        return self.shape[0] if self.shape else 1


class ParamStore:
    """Collects ``ParamDef``s keyed by '/'-separated paths; materializes
    init values / shape structs / PartitionSpecs as congruent nested dicts."""

    def __init__(self) -> None:
        self.defs: dict[str, ParamDef] = {}

    def add(self, path: str, d: ParamDef) -> None:
        if path in self.defs:
            raise ValueError(f"duplicate param {path}")
        self.defs[path] = d

    def stacked(self, n: int, prefix: str, sub: "ParamStore") -> None:
        """Add all of ``sub``'s params with a leading stacking dim of ``n``."""
        for path, d in sub.defs.items():
            self.add(
                f"{prefix}/{path}",
                dataclasses.replace(d, shape=(n, *d.shape), axes=(None, *d.axes)),
            )

    # -- materialization ------------------------------------------------
    def _nest(self, leaves: dict[str, Any]) -> dict[str, Any]:
        tree: dict[str, Any] = {}
        for path, v in leaves.items():
            parts = path.split("/")
            node = tree
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = v
        return tree

    def shape_structs(self) -> dict[str, Any]:
        return self._nest(
            {
                p: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype))
                for p, d in self.defs.items()
            }
        )

    def pspecs(self, topo: Topo) -> dict[str, Any]:
        return self._nest({p: topo.pspec(d.axes, d.shape) for p, d in self.defs.items()})

    def shardings(self, mesh: Mesh, topo: Topo) -> dict[str, Any]:
        return self._nest(
            {
                p: NamedSharding(mesh, topo.pspec(d.axes, d.shape))
                for p, d in self.defs.items()
            }
        )

    def init(self, key: jax.Array) -> dict[str, Any]:
        leaves = {}
        paths = sorted(self.defs)
        keys = jax.random.split(key, max(len(paths), 1))
        for k, path in zip(keys, paths):
            leaves[path] = _init_param(k, self.defs[path])
        return self._nest(leaves)

    def num_params(self) -> int:
        return sum(int(np.prod(d.shape)) for d in self.defs.values())


def _init_param(key: jax.Array, d: ParamDef) -> jax.Array:
    dtype = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "mamba_a":
        # A_log init: log(1..d_state) broadcast over d_inner rows (mamba1)
        n = d.shape[-1]
        a = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), d.shape)
        return a.astype(dtype)
    if d.init == "mamba_dt":
        # dt bias: inverse-softplus of uniform dt in [1e-3, 1e-1]
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
        inv = dt + jnp.log(-jnp.expm1(-dt))
        return inv.astype(dtype)
    scale = d.scale if d.scale is not None else 1.0 / math.sqrt(max(d.fan_in(), 1))
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Misc numerics
# ---------------------------------------------------------------------------
def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, vocab_size: int
) -> jax.Array:
    """Mean CE over tokens, computed stably on (possibly vocab-sharded) logits.

    The gold logit is extracted with a one-hot contraction rather than
    ``take_along_axis`` so a vocab-sharded logits tensor reduces with a tiny
    psum instead of an all-gather.  ``labels`` outside [0, vocab_size) are
    masked out.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = (labels[..., None] == jnp.arange(v, dtype=labels.dtype)).astype(jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    mask = (labels >= 0) & (labels < vocab_size)
    loss = (lse - gold) * mask
    return jnp.sum(loss) / jnp.maximum(jnp.sum(mask), 1)


def maybe_remat(fn: Callable, enabled: bool) -> Callable:
    if not enabled:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)
