"""Attention: chunked (flash-style) jnp implementation + layout-aware blocks.

Three weight/activation layouts (chosen per arch/step kind, see DESIGN.md):

* ``megatron``  — q-heads column-parallel over "model" (requires H % tp == 0);
                  K/V activations replicated over model; wo row-parallel (one
                  psum). Used for train/prefill on head-divisible archs.
* ``fsdp_sp``   — all weights ZeRO-sharded and gathered JIT; q is
                  sequence-sharded over "model" for the attention core (no
                  redundant compute); used when H % tp != 0 (phi3, qwen2.5,
                  granite).
* ``decode_rp`` — row-parallel projections (input-dim over "model", tiny
                  psums); KV cache sequence-sharded over "model"; attention
                  uses grouped (GQA) einsums over the cache shards. Used for
                  all decode steps.

The pure-jnp chunked attention here is the oracle/compile path; the Pallas
kernel (kernels/flash_attention.py) is the TPU execution path.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import ParamDef, ParamStore, Topo
from repro.models.layers import apply_rope

_NEG = -1e30


def _pick_chunk(total_block_elems: int, seq: int, budget: int = 128 * 1024 * 1024) -> int:
    """kv-chunk so the f32 score block stays under ~512MB per device while
    keeping the number of scan steps (whose f32 acc carry is stacked by the
    scan backward) small."""
    c = 2048
    while c > 128 and total_block_elems * c > budget:
        c //= 2
    while seq % c:
        c //= 2
    return max(c, 1)


def chunked_attention(
    q: jax.Array,           # (b, sq, H, dh)  flat heads
    k: jax.Array,           # (b, skv, KV, dh)
    v: jax.Array,           # (b, skv, KV, dh)
    *,
    causal: bool,
    q_positions: jax.Array,     # (sq,) int32
    kv_positions: jax.Array,    # (skv,) int32
    topo: Topo,
    heads_sharded: bool,        # megatron mode: flat-head dim sharded on tp
    softmax_scale: float | None = None,
) -> jax.Array:
    b, sq, H, dh = q.shape
    skv, KV = k.shape[1], k.shape[2]
    dhv = v.shape[-1]           # MLA: value head dim may differ from qk dim
    qper = H // KV
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5

    # per-device score-block row count
    tp = topo.axis_size("tp")
    dp = topo.axis_size("batch")
    rows = max(b // max(dp, 1), 1) * (max(H // tp, 1) if heads_sharded else H) * sq
    ck = _pick_chunk(rows, skv)
    nk = skv // ck

    q32 = (q * scale).astype(q.dtype)
    ks = k.reshape(b, nk, ck, KV, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, ck, KV, dhv).transpose(1, 0, 2, 3, 4)
    kpos = kv_positions.reshape(nk, ck)

    def qshard(x):  # (b, sq, H, dh)-like activations
        if heads_sharded:
            return topo.shard(x, "batch", None, "tp", None)
        return topo.shard(x, "batch", "seq_tp", None, None)

    def sshard(x):  # (b, H, sq, ck) score blocks
        if heads_sharded:
            return topo.shard(x, "batch", "tp", None, None)
        return topo.shard(x, "batch", None, "seq_tp", None)

    qq = qshard(q32)

    def body(carry, xs):
        acc, m, l = carry
        k_c, v_c, kp = xs
        if qper > 1:
            k_f = jnp.repeat(k_c, qper, axis=2)
            v_f = jnp.repeat(v_c, qper, axis=2)
        else:
            k_f, v_f = k_c, v_c
        s = jnp.einsum("bqhd,bkhd->bhqk", qq, k_f,
                       preferred_element_type=jnp.float32)
        if causal:
            mask = q_positions[:, None] >= kp[None, :]
            s = jnp.where(mask[None, None], s, _NEG)
        s = sshard(s)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v_f,
                        preferred_element_type=jnp.float32)
        acc = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (acc, m_new, l), ()

    # remat: score blocks are recomputed during the backward pass instead of
    # being stacked across all nk steps (flash-attention-style memory)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    acc0 = qshard(jnp.zeros((b, sq, H, dhv), jnp.float32))
    m0 = jnp.full((b, H, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, H, sq), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(body, (acc0, m0, l0), (ks, vs, kpos))
    out = acc / jnp.maximum(l, 1e-20).transpose(0, 2, 1)[..., None]
    return qshard(out.astype(q.dtype))


def decode_attention(
    q: jax.Array,          # (b, H, dh)
    k_cache: jax.Array,    # (b, S, KV, dh)  seq-sharded over "model"
    v_cache: jax.Array,
    t: jax.Array,          # scalar int32: current position (mask > t)
    topo: Topo,
    softmax_scale: float | None = None,
) -> jax.Array:
    b, H, dh = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    qper = H // KV
    scale = softmax_scale if softmax_scale is not None else dh ** -0.5
    qg = (q * scale).reshape(b, KV, qper, dh)
    s = jnp.einsum("bkpd,bskd->bkps", qg, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(S, dtype=jnp.int32)
    s = jnp.where(pos[None, None, None, :] <= t, s, _NEG)
    s = topo.shard(s, "batch", None, None, "seq_tp")
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkps,bskd->bkpd", (p / l).astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Attention:
    name: str
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    layout: str                 # megatron | fsdp_sp | decode_rp
    rope_theta: float = 10_000.0
    use_rope: bool = True
    qkv_bias: bool = False
    out_bias: bool = False
    causal: bool = True
    is_cross: bool = False      # cross-attention: k/v from memory, no causal

    def register(self, store: ParamStore) -> None:
        d, H, KV, dh = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        if self.layout == "megatron":
            ax_q, ax_kv, ax_o = ("fsdp", "tp", None), ("fsdp", None, "tp"), ("tp", None, "fsdp")
        elif self.layout == "fsdp_sp":
            ax_q, ax_kv, ax_o = ("fsdp", None, "tp"), ("fsdp", None, "tp"), (None, "tp", "fsdp")
        else:  # decode_rp: row-parallel input dim
            ax_q, ax_kv, ax_o = ("tp", None, None), ("tp", None, None), (None, None, "tp")
        store.add(f"{self.name}/wq", ParamDef((d, H, dh), ax_q))
        store.add(f"{self.name}/wk", ParamDef((d, KV, dh), ax_kv))
        store.add(f"{self.name}/wv", ParamDef((d, KV, dh), ax_kv))
        store.add(f"{self.name}/wo", ParamDef((H, dh, d), ax_o))
        if self.qkv_bias:
            store.add(f"{self.name}/bq", ParamDef((H, dh), (None, None), init="zeros"))
            store.add(f"{self.name}/bk", ParamDef((KV, dh), (None, None), init="zeros"))
            store.add(f"{self.name}/bv", ParamDef((KV, dh), (None, None), init="zeros"))
        if self.out_bias:
            store.add(f"{self.name}/bo", ParamDef((d,), (None,), init="zeros"))

    # -- projections -----------------------------------------------------
    def _qkv(self, p: dict, x: jax.Array, mem: jax.Array | None, topo: Topo):
        src = mem if self.is_cross else x
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
        if self.qkv_bias:
            q = q + p["bq"]
            k = k + p["bk"]
            v = v + p["bv"]
        return q, k, v

    def _out(self, p: dict, o: jax.Array, topo: Topo) -> jax.Array:
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        if self.out_bias:
            out = out + p["bo"]
        # outputs stay sequence-sharded in every layout: the row-parallel
        # psum fuses into a reduce-scatter (half the all-reduce bytes) and
        # the residual stream remains seq-sharded across the block (§Perf C1)
        return topo.shard(out, "batch", "seq_tp", None)

    # -- full-sequence forward (train / prefill) -------------------------
    def __call__(
        self,
        p: dict,
        x: jax.Array,                    # (b, s, d)
        positions: jax.Array,            # (s,)
        topo: Topo,
        memory: jax.Array | None = None,  # cross-attention source (b, sm, d)
        memory_positions: jax.Array | None = None,
        return_kv: bool = False,
    ):
        q, k, v = self._qkv(p, x, memory, topo)
        kv_pos = memory_positions if self.is_cross else positions
        if self.use_rope and not self.is_cross:
            q = apply_rope(q, positions, self.rope_theta)
            k = apply_rope(k, kv_pos, self.rope_theta)
        heads_sharded = self.layout == "megatron"
        if heads_sharded:
            q = topo.shard(q, "batch", None, "tp", None)
            k = topo.shard(k, "batch", None, None, None)
            v = topo.shard(v, "batch", None, None, None)
        else:
            # fsdp_sp: q stays sequence-sharded; k/v gathered over seq
            q = topo.shard(q, "batch", "seq_tp", None, None)
            k = topo.shard(k, "batch", None, None, None)
            v = topo.shard(v, "batch", None, None, None)
        o = chunked_attention(
            q, k, v,
            causal=self.causal and not self.is_cross,
            q_positions=positions,
            kv_positions=kv_pos,
            topo=topo,
            heads_sharded=heads_sharded,
        )
        out = self._out(p, o, topo)
        if return_kv:
            return out, (k, v)
        return out

    # -- single-token decode against a sequence-sharded cache ------------
    def decode(
        self,
        p: dict,
        x: jax.Array,          # (b, d)
        t: jax.Array,          # scalar int32 current position
        k_cache: jax.Array,    # (b, S, KV, dh)
        v_cache: jax.Array,
        topo: Topo,
        update_cache: bool = True,
    ):
        b, d = x.shape
        xs = x[:, None]  # (b, 1, d)
        if self.is_cross:
            # cross-attention reads the (precomputed) memory cache; only q
            # is projected, no cache update.
            q = jnp.einsum("bsd,dhk->bshk", xs, p["wq"])
            if self.qkv_bias:
                q = q + p["bq"]
            o = decode_attention(q[:, 0], k_cache, v_cache,
                                 jnp.asarray(k_cache.shape[1] - 1, jnp.int32), topo)
        else:
            q, k, v = self._qkv(p, xs, None, topo)
            if self.use_rope:
                pos = jnp.full((1,), t, jnp.int32)
                q = apply_rope(q, pos, self.rope_theta)
                k = apply_rope(k, pos, self.rope_theta)
            if update_cache:
                k_cache = jax.lax.dynamic_update_slice(
                    k_cache, k.astype(k_cache.dtype), (0, t, 0, 0))
                v_cache = jax.lax.dynamic_update_slice(
                    v_cache, v.astype(v_cache.dtype), (0, t, 0, 0))
            o = decode_attention(q[:, 0], k_cache, v_cache, t, topo)
        # single flattened dot (see MLA decode note; same weight-AG hazard)
        b_, H_, dh_ = o.shape
        d_ = p["wo"].shape[-1]
        out = o.reshape(b_, H_ * dh_) @ p["wo"].reshape(H_ * dh_, d_)
        out = topo.shard(out, "batch", "tp")
        if self.out_bias:
            out = out + p["bo"]
        out = topo.shard(out, "batch", None)
        return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): latent KV compression
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MLAttention:
    name: str
    d_model: int
    num_heads: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int
    layout: str                # megatron | decode_rp
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    @property
    def qk_dim(self) -> int:
        return self.qk_nope_dim + self.qk_rope_dim

    def register(self, store: ParamStore) -> None:
        d, H = self.d_model, self.num_heads
        lora, rope = self.kv_lora_rank, self.qk_rope_dim
        if self.layout == "megatron":
            ax_q = ("fsdp", "tp", None)
            ax_kvb = (None, "tp", None)
            ax_o = ("tp", None, "fsdp")
        else:  # decode: heads replicated (cache is seq-sharded), row-parallel in d
            ax_q = ("tp", None, None)
            ax_kvb = (None, None, "tp")
            ax_o = (None, None, "tp")
        store.add(f"{self.name}/wq", ParamDef((d, H, self.qk_dim), ax_q))
        store.add(f"{self.name}/w_kva",
                  ParamDef((d, lora + rope), ("fsdp" if self.layout == "megatron" else "tp", None)))
        store.add(f"{self.name}/kv_norm", ParamDef((lora,), (None,), init="ones"))
        store.add(f"{self.name}/w_kvb",
                  ParamDef((lora, H, self.qk_nope_dim + self.v_head_dim), ax_kvb))
        store.add(f"{self.name}/wo", ParamDef((H, self.v_head_dim, d), ax_o))

    def _latent(self, p: dict, x: jax.Array):
        """x (b,s,d) -> normalized latent c (b,s,lora), roped k_rope (b,s,rope)."""
        kva = jnp.einsum("bsd,dr->bsr", x, p["w_kva"])
        c, k_rope = jnp.split(kva, [self.kv_lora_rank], axis=-1)
        cf = c.astype(jnp.float32)
        c = (cf * jax.lax.rsqrt(jnp.mean(cf * cf, -1, keepdims=True) + self.norm_eps)
             * p["kv_norm"].astype(jnp.float32)).astype(x.dtype)
        return c, k_rope

    def __call__(self, p: dict, x: jax.Array, positions: jax.Array, topo: Topo,
                 return_kv: bool = False, **_):
        b, s, d = x.shape
        H = self.num_heads
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        q_nope, q_rope = jnp.split(q, [self.qk_nope_dim], axis=-1)
        q_rope = apply_rope(q_rope, positions, self.rope_theta)
        c, k_rope = self._latent(p, x)
        k_rope = apply_rope(k_rope[:, :, None, :], positions, self.rope_theta)
        kvb = jnp.einsum("bsr,rhk->bshk", c, p["w_kvb"])
        k_nope, v = jnp.split(kvb, [self.qk_nope_dim], axis=-1)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, H, self.qk_rope_dim))], axis=-1)
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        qf = topo.shard(qf, "batch", None, "tp", None)
        o = chunked_attention(
            qf, k, v, causal=True, q_positions=positions, kv_positions=positions,
            topo=topo, heads_sharded=self.layout == "megatron",
            softmax_scale=self.qk_dim ** -0.5)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        out = topo.shard(out, "batch", None, None)
        if return_kv:
            return out, (c, k_rope[:, :, 0, :])
        return out

    def decode(self, p: dict, x: jax.Array, t: jax.Array,
               c_cache: jax.Array,      # (b, S, lora)   seq-sharded
               rope_cache: jax.Array,   # (b, S, rope)
               topo: Topo):
        """Absorbed-MLA decode: scores/values computed in latent space."""
        b, d = x.shape
        H, lora = self.num_heads, self.kv_lora_rank
        q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
        q_nope, q_rope = jnp.split(q, [self.qk_nope_dim], axis=-1)
        pos = jnp.full((1,), t, jnp.int32)
        q_rope = apply_rope(q_rope[:, None], pos, self.rope_theta)[:, 0]
        c_new, k_rope_new = self._latent(p, x[:, None])
        k_rope_new = apply_rope(k_rope_new[:, :, None, :], pos, self.rope_theta)[:, :, 0, :]
        c_cache = jax.lax.dynamic_update_slice(
            c_cache, c_new.astype(c_cache.dtype), (0, t, 0))
        rope_cache = jax.lax.dynamic_update_slice(
            rope_cache, k_rope_new.astype(rope_cache.dtype), (0, t, 0))
        wk, wv = jnp.split(p["w_kvb"], [self.qk_nope_dim], axis=-1)
        H_, lora_ = self.num_heads, self.kv_lora_rank
        q_eff = jnp.einsum("bhn,rhn->bhr", q_nope, wk)       # absorb W_UK
        s = (jnp.einsum("bhr,bsr->bhs", q_eff, c_cache, preferred_element_type=jnp.float32)
             + jnp.einsum("bhr,bsr->bhs", q_rope, rope_cache, preferred_element_type=jnp.float32))
        s = s * (self.qk_dim ** -0.5)
        S = c_cache.shape[1]
        posv = jnp.arange(S, dtype=jnp.int32)
        s = jnp.where(posv[None, None, :] <= t, s, _NEG)
        s = topo.shard(s, "batch", None, "seq_tp")
        m = jnp.max(s, -1, keepdims=True)
        pr = jnp.exp(s - m)
        pr = pr / jnp.sum(pr, -1, keepdims=True)
        o_lat = jnp.einsum("bhs,bsr->bhr", pr.astype(c_cache.dtype), c_cache,
                           preferred_element_type=jnp.float32).astype(x.dtype)
        o = jnp.einsum("bhr,rhv->bhv", o_lat, wv)            # absorb W_UV
        # flatten (h, v) so the output projection is ONE dot — the einsum
        # form decomposes into a (b, H, d) partial that XLA then all-gathers
        # (measured 18.75 GiB/step at decode_32k; §Perf D1)
        v_dim = self.v_head_dim
        out = o.reshape(b, H_ * v_dim) @ p["wo"].reshape(H_ * v_dim, d)
        # pin the dot output d-sharded so the partitioner gathers the small
        # (b, d) activation, not the 320 MB weight (§Perf D1: 18.75 GiB/step)
        out = topo.shard(out, "batch", "tp")
        out = topo.shard(out, "batch", None)
        return out, (c_cache, rope_cache)
