from repro.analysis.hardware import FREQ_SWEEP, V5E, ChipSpec
from repro.analysis.hlo import Cost, HloCostAnalyzer, analyze_hlo_text
from repro.analysis.roofline import RooflineReport, build_report, model_flops
