"""Three-term roofline from the parsed dry-run artifact (assignment §Roofline).

    T_compute    = FLOPs / (chips x peak)       [parsed HLO is per-device, so
    T_memory     = bytes / (chips x HBM bw)      chips divide out: terms are
    T_collective = coll_bytes / (links x bw)     computed per device directly]

MODEL_FLOPS = 6*N*D (train, active params) / 2*N*D (prefill) / decode:
2*N_active*batch + cache reads — the ratio MODEL_FLOPS / HLO_FLOPs exposes
remat & redundancy waste.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.analysis.hardware import ChipSpec, V5E
from repro.analysis.hlo import Cost
from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops: float                 # per device
    hbm_bytes: float             # upper (CPU-fusion) estimate
    hbm_bytes_min: float         # lower (TPU-fusion) estimate — used for terms
    coll_bytes: dict
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float           # global useful flops
    useful_ratio: float          # model_flops / (flops * chips)
    unresolved_loops: int = 0
    note: str = ""

    @property
    def step_time(self) -> float:
        """No-overlap upper bound on step time."""
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def bound_time(self) -> float:
        """Perfect-overlap lower bound (max of terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    model_bytes: float = 0.0     # minimal useful HBM traffic per device

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant roofline achieved: the time an ideal
        machine needs for the *useful* work (max of useful-compute and
        useful-memory time) over the no-overlap step time of the compiled
        program. 1.0 = every byte/flop moved was necessary and at peak."""
        if self.step_time <= 0:
            return 0.0
        t_useful = max(self.model_flops / self.n_chips / V5E.peak_flops_bf16,
                       self.model_bytes / V5E.hbm_bw)
        return min(t_useful / self.step_time, 1.0)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips, "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_min": self.hbm_bytes_min,
            "coll_bytes": self.coll_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops, "useful_ratio": self.useful_ratio,
            "model_bytes": self.model_bytes,
            "roofline_fraction": self.roofline_fraction,
            "step_time_noverlap": self.step_time,
            "bound_time": self.bound_time,
            "unresolved_loops": self.unresolved_loops,
            "note": self.note,
        }


def model_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                           n_chips: int, tp: int = 16) -> float:
    """Minimal useful HBM traffic per device per step (2-byte weights).

    train:   3 weight passes (fwd, bwd, update) + optimizer moments r/w +
             ~12 bytes/token/layer/d of activation traffic
    prefill: 1 weight pass + kv-cache write + ~6 B/tok/layer/d activations
    decode:  weights resident/TP read once + cache read + one slot written
    """
    n_total = cfg.param_count()
    n_active = cfg.active_param_count()
    d, L = cfg.d_model, cfg.num_layers
    if shape.kind == "train":
        w = n_total / n_chips * (3 * 2 + 2 * 8)       # bf16 x3 + m,v fp32 r/w
        act = shape.tokens / n_chips * d * L * 12
        return w + act
    if shape.kind == "prefill":
        w = n_total / n_chips * 2
        act = shape.tokens / n_chips * d * L * 6
        kv = shape.tokens / n_chips * 2 * max(cfg.num_kv_heads, 1) * \
            max(cfg.head_dim, 1) * 2
        return w + act + kv
    # decode
    w = n_active * 2 / tp
    s = shape.seq_len
    if cfg.family == "ssm":
        cache = L * cfg.d_inner * cfg.ssm_state * 4 * shape.global_batch
    elif cfg.use_mla:
        cache = L * s * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2 * shape.global_batch
    else:
        n_attn = L // cfg.attn_period if (cfg.family == "hybrid" and cfg.attn_period) else L
        cache = n_attn * s * 2 * cfg.num_kv_heads * cfg.head_dim * 2 * shape.global_batch
    return w + cache / n_chips  # cache sharded over all chips (batch x seq)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence + attention over the cache
    base = 2.0 * n_active * shape.global_batch
    s = shape.seq_len
    attn = 0.0
    if cfg.num_heads:
        n_attn = cfg.num_layers
        if cfg.family == "hybrid" and cfg.attn_period:
            n_attn = cfg.num_layers // cfg.attn_period
        attn = 4.0 * shape.global_batch * s * cfg.num_heads * \
            max(cfg.head_dim, 1) * n_attn
    return base + attn


def build_report(cost: Cost, cfg: ModelConfig, shape: ShapeConfig,
                 mesh_name: str, n_chips: int,
                 spec: ChipSpec = V5E, note: str = "") -> RooflineReport:
    t_c = cost.flops / spec.peak_flops_bf16
    # memory term from the TPU-fusion-aware lower estimate (the raw CPU-HLO
    # byte count inflates elementwise traffic TPU fusion would eliminate)
    t_m = (cost.hbm_bytes_min or cost.hbm_bytes) / spec.hbm_bw
    t_x = cost.collective_total / (spec.ici_links * spec.ici_link_bw)
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mb = model_bytes_per_device(cfg, shape, n_chips)
    useful = mf / max(cost.flops * n_chips, 1e-9)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        flops=cost.flops, hbm_bytes=cost.hbm_bytes,
        hbm_bytes_min=cost.hbm_bytes_min or cost.hbm_bytes,
        coll_bytes=dict(cost.coll_bytes),
        t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dominant,
        model_flops=mf, useful_ratio=useful, model_bytes=mb,
        unresolved_loops=cost.unresolved_loops, note=note,
    )
