"""Target hardware constants used by the roofline analysis and the
power/performance simulator.  The container is CPU-only; these describe the
TARGET chips.  The primary target stays the TPU v5e of the original repro
(197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI); ``CHIP_MODELS`` adds
two more generations so the fleet layer can model heterogeneous pods.

Per-instance silicon variability ("Not All GPUs Are Created Equal",
arXiv:2208.11035) is expressed through two multiplicative fields on
``ChipSpec``:

  * ``perf_scale``  — scales the achievable compute/bandwidth at a given
    normalized frequency (process-corner frequency variation);
  * ``power_scale`` — scales the power drawn at a given activity level
    (leakage/efficiency variation).

Both default to exactly 1.0, which is bit-exact with the pre-fleet model
(multiplying by 1.0 is an IEEE identity); ``repro.fleet.DeviceInventory``
draws seeded per-device values around 1.0.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # bytes/s
    hbm_bytes: float = 16 * 2**30
    ici_link_bw: float = 50e9                # bytes/s per link (one direction)
    ici_links: int = 4                       # 2D torus: 4 links per chip
    # power model (OCP OAI-style sustained/excursion structure, DESIGN.md §2)
    tdp_w: float = 200.0
    idle_w: float = 60.0
    max_excursion: float = 2.0               # x TDP, OCP spec ceiling
    # normalized DVFS range (maps the paper's 1300..2100 MHz sweep)
    f_min: float = 0.6
    f_max: float = 1.0
    v_min: float = 0.72                      # V(f_min)/V(f_max)
    # per-instance silicon variability (1.0 = the nominal chip)
    perf_scale: float = 1.0
    power_scale: float = 1.0

    @property
    def machine_balance(self) -> float:
        """FLOP per HBM byte at the ridge point."""
        return self.peak_flops_bf16 / self.hbm_bw

    @property
    def effective_tdp_w(self) -> float:
        """The nameplate TDP rescaled by this instance's power variability:
        the normalization base that makes profiles device-portable (a trace
        divided by it recovers the workload's intrinsic relative curve)."""
        return self.tdp_w * self.power_scale

    def voltage(self, f: float) -> float:
        """Normalized V(f), linear between (f_min, v_min) and (f_max, 1)."""
        f = min(max(f, self.f_min), self.f_max)
        t = (f - self.f_min) / (self.f_max - self.f_min)
        return self.v_min + (1.0 - self.v_min) * t


V5E = ChipSpec()

# A bigger HBM-rich training chip and a newer-generation serving chip.
# Numbers follow the public v5p/v6e (Trillium) datasheet ballpark; power
# curves reuse the same OCP structure with per-model TDP/idle.
V5P = ChipSpec(name="tpu-v5p", peak_flops_bf16=459e12, hbm_bw=2765e9,
               hbm_bytes=95 * 2**30, ici_link_bw=100e9, ici_links=6,
               tdp_w=350.0, idle_w=95.0)
V6E = ChipSpec(name="tpu-v6e", peak_flops_bf16=918e12, hbm_bw=1640e9,
               hbm_bytes=32 * 2**30, ici_link_bw=100e9, ici_links=4,
               tdp_w=300.0, idle_w=80.0)

# the chip-model registry the fleet inventory draws from
CHIP_MODELS: dict[str, ChipSpec] = {s.name: s for s in (V5E, V5P, V6E)}

# the frequency sweep used for reference profiling (9 points, like the
# paper's 1300->2100 MHz in 100 MHz steps)
FREQ_SWEEP = tuple(round(0.6 + 0.05 * i, 2) for i in range(9))
