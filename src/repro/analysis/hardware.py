"""Target hardware constants (TPU v5e) used by the roofline analysis and the
power/performance simulator.  The container is CPU-only; these describe the
TARGET, per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # bytes/s
    hbm_bytes: float = 16 * 2**30
    ici_link_bw: float = 50e9                # bytes/s per link (one direction)
    ici_links: int = 4                       # 2D torus: 4 links per chip
    # power model (OCP OAI-style sustained/excursion structure, DESIGN.md §2)
    tdp_w: float = 200.0
    idle_w: float = 60.0
    max_excursion: float = 2.0               # x TDP, OCP spec ceiling
    # normalized DVFS range (maps the paper's 1300..2100 MHz sweep)
    f_min: float = 0.6
    f_max: float = 1.0
    v_min: float = 0.72                      # V(f_min)/V(f_max)

    @property
    def machine_balance(self) -> float:
        """FLOP per HBM byte at the ridge point."""
        return self.peak_flops_bf16 / self.hbm_bw

    def voltage(self, f: float) -> float:
        """Normalized V(f), linear between (f_min, v_min) and (f_max, 1)."""
        f = min(max(f, self.f_min), self.f_max)
        t = (f - self.f_min) / (self.f_max - self.f_min)
        return self.v_min + (1.0 - self.v_min) * t


V5E = ChipSpec()

# the frequency sweep used for reference profiling (9 points, like the
# paper's 1300->2100 MHz in 100 MHz steps)
FREQ_SWEEP = tuple(round(0.6 + 0.05 * i, 2) for i in range(9))
