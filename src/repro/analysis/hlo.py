"""While-loop-aware HLO text cost analysis.

``compiled.cost_analysis()`` counts a while (scan) body ONCE — verified in
this container — so scanned-layer models under-report FLOPs by ~L x, and
collective bytes inside scan bodies would be under-counted the same way.
This parser walks the post-SPMD-partitioning HLO text:

  * per-instruction FLOPs: dot (from result shape x contracting dims),
    convolution (approx), elementwise ops (element count)
  * HBM bytes: operand+result sizes of top-level instructions; fusion
    interiors don't touch HBM (params/result of the fusion call do)
  * collective bytes by kind (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute), with while-body costs multiplied by
    the loop trip count (parsed from the loop-condition constant)

All numbers are per-device (the module is the per-device SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# elementwise-ish ops counted as 1 flop / element (transcendentals as 4)
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "negate", "abs", "floor", "ceil",
    "round-nearest-afz", "clamp",
}
_TRANS_OPS = {"exponential", "log", "rsqrt", "sqrt", "tanh", "logistic",
              "power", "sine", "cosine", "expm1", "log1p"}


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in re.findall(r"([a-z0-9]+)\[([0-9,]*)\]", type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = re.search(r"[a-z0-9]+\[([0-9,]*)\]", type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(1).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = re.search(r"[a-z0-9]+\[([0-9,]*)\]", type_str)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str
    args: str = ""


@dataclass
class Computation:
    name: str
    instrs: dict[str, Instr] = field(default_factory=dict)
    order: list[str] = field(default_factory=list)


@dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0        # upper estimate: CPU-grade fusion (each
                                  # top-level op's operands+results)
    hbm_bytes_min: float = 0.0    # lower estimate: TPU-grade fusion (only
                                  # dots/reduces/collectives/gathers/DUS and
                                  # fusions containing them materialize)
    coll_bytes: dict = field(default_factory=dict)
    transcendentals: float = 0.0
    unresolved_loops: int = 0

    def add(self, other: "Cost", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.hbm_bytes += other.hbm_bytes * times
        self.hbm_bytes_min += other.hbm_bytes_min * times
        self.transcendentals += other.transcendentals * times
        self.unresolved_loops += other.unresolved_loops
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * times

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())


# ops whose operands/results genuinely move through HBM even on TPU
_MATERIALIZE_OPS = {
    "dot", "convolution", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "sort", "copy", "concatenate", "pad",
    "reduce", "transpose",
}


# type is either a tuple "(...)" (no nested parens; may contain /*index=N*/
# comments) or a plain "dtype[dims]{layout}"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        m = _COMP_RE.match(line.strip())
        if m and not line.startswith(" "):
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, tstr, op, args, attrs = mi.groups()
        operands = re.findall(r"%([\w\.\-]+)", args)
        ins = Instr(name, tstr, op, operands, attrs, args=args)
        if cur is not None:
            cur.instrs[name] = ins
            cur.order.append(name)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _called(attrs: str, key: str) -> str | None:
    m = re.search(key + r"=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.type_str)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    lhs = comp.instrs.get(ins.operands[0]) if ins.operands else None
    if not m or lhs is None:
        return 2.0 * out_elems  # fallback
    dims = _shape_dims(lhs.type_str)
    contract = 1
    for idx in m.group(1).split(","):
        if idx and int(idx) < len(dims):
            contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(ins.type_str)
    rhs = comp.instrs.get(ins.operands[1]) if len(ins.operands) > 1 else None
    if rhs is None:
        return 2.0 * out_elems
    kdims = _shape_dims(rhs.type_str)
    # dim_labels like b01f_01io->b01f : kernel = spatial... i, o
    m = re.search(r"dim_labels=([\w]+)_([\w]+)->", ins.attrs)
    if m and kdims:
        klabels = m.group(2)
        prod = 1
        for lab, dim in zip(klabels, kdims):
            if lab not in ("o",):
                prod *= dim
        return 2.0 * out_elems * prod
    return 2.0 * out_elems * (kdims[0] if kdims else 1)


def _trip_count_text(cond: Computation) -> int | None:
    """Trip count = the positive scalar constant bound in the tiny loop
    condition (CPU XLA wraps the compare in a fusion, so we just scan the
    condition computation for s32[] constants and take the max)."""
    best = None
    for nm in cond.order:
        ins = cond.instrs[nm]
        if ins.op != "constant" or "[]" not in ins.type_str:
            continue
        m = re.fullmatch(r"\s*(-?[0-9]+)\s*", ins.args or "")
        if m:
            v = int(m.group(1))
            if v > 0 and (best is None or v > best):
                best = v
    return best


class HloCostAnalyzer:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: dict[tuple[str, bool], Cost] = {}

    def cost(self) -> Cost:
        return self._comp_cost(self.entry, top=True)

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str, top: bool) -> Cost:
        key = (name, top)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        for nm in comp.order:
            ins = comp.instrs[nm]
            total.add(self._instr_cost(ins, comp, top))
        self._memo[key] = total
        return total

    def _instr_cost(self, ins: Instr, comp: Computation, top: bool) -> Cost:
        c = Cost()
        op = ins.op
        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "after-all", "iota", "partition-id", "replica-id"):
            return c
        if op == "while":
            body = _called(ins.attrs, "body")
            cond = _called(ins.attrs, "condition")
            trips = None
            if cond and cond in self.comps:
                trips = _trip_count_text(self.comps[cond])
            inner = Cost()
            if body:
                inner.add(self._comp_cost(body, top=True))
            if cond:
                inner.add(self._comp_cost(cond, top=True))
            if trips is None:
                trips = 1
                c.unresolved_loops += 1
            c.add(inner, times=float(trips))
            return c
        if op in ("call", "async-start", "async-done"):
            callee = _called(ins.attrs, "calls") or _called(ins.attrs, "to_apply")
            if callee:
                c.add(self._comp_cost(callee, top=True))
            return c
        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs)
            names = re.findall(r"%?([\w\.\-]+)", branches[0]) if branches else []
            if not names:
                names = [x for x in re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)",
                                               ins.attrs)]
            sub = [self._comp_cost(b, top=True) for b in names if b in self.comps]
            if sub:
                worst = max(sub, key=lambda s: s.flops + s.hbm_bytes)
                c.add(worst)
            return c
        if op.startswith("fusion"):
            callee = _called(ins.attrs, "calls")
            heavy = False
            if callee:
                inner = self._comp_cost(callee, top=False)
                c.flops += inner.flops
                c.transcendentals += inner.transcendentals
                heavy = self._has_heavy_op(callee)
                for k, v in inner.coll_bytes.items():
                    c.coll_bytes[k] = c.coll_bytes.get(k, 0.0) + v
            # HBM traffic: computed from the fusion interior — parameters
            # consumed via dynamic-slice are charged at slice size, updates
            # at update size, and DUS-aliased outputs are free (in-place).
            traffic = self._fusion_traffic(ins, comp, callee)
            c.hbm_bytes += traffic
            # TPU estimate: elementwise-only fusions get absorbed into their
            # producers/consumers; fusions with dots/gathers/etc. materialize
            if heavy:
                c.hbm_bytes_min += traffic
            return c
        if any(op.startswith(k) for k in COLLECTIVES):
            kind = next(k for k in COLLECTIVES if op.startswith(k))
            nbytes = max(_shape_bytes(ins.type_str), self._operand_bytes(ins, comp))
            mult = 2.0 if kind == "all-reduce" else 1.0
            c.coll_bytes[kind] = c.coll_bytes.get(kind, 0.0) + nbytes * mult
            traffic = self._operand_bytes(ins, comp) + _shape_bytes(ins.type_str)
            c.hbm_bytes += traffic
            c.hbm_bytes_min += traffic
            return c
        # compute ops
        if op == "dot":
            c.flops += _dot_flops(ins, comp)
        elif op == "convolution":
            c.flops += _conv_flops(ins, comp)
        elif op in _EW_OPS:
            c.flops += _shape_elems(ins.type_str)
        elif op in _TRANS_OPS:
            t = _shape_elems(ins.type_str)
            c.flops += 4.0 * t
            c.transcendentals += t
        elif op == "reduce":
            c.flops += max(self._operand_elems(ins, comp) - _shape_elems(ins.type_str), 0)
        # HBM bytes only for top-level (unfused) instructions
        if top and op not in ("fusion",):
            traffic = self._traffic(ins, comp)
            c.hbm_bytes += traffic
            if op in _MATERIALIZE_OPS:
                c.hbm_bytes_min += traffic
        return c

    def _traffic(self, ins: Instr, comp: Computation) -> float:
        """HBM traffic of one op. Slicing ops move only the slice: a
        dynamic-slice reads slice-many bytes (not its whole operand — scans
        slice their stacked xs every iteration) and a dynamic-update-slice
        writes the update in place (donated buffers alias on TPU)."""
        if ins.op == "dynamic-slice":
            return 2.0 * _shape_bytes(ins.type_str)
        if ins.op in ("dynamic-update-slice", "scatter"):
            upd = comp.instrs.get(ins.operands[1]) if len(ins.operands) > 1 else None
            upd_bytes = _shape_bytes(upd.type_str) if upd else _shape_bytes(ins.type_str)
            return 2.0 * upd_bytes
        if ins.op == "gather":
            return 2.0 * _shape_bytes(ins.type_str)
        return self._operand_bytes(ins, comp) + _shape_bytes(ins.type_str)

    def _has_heavy_op(self, comp_name: str) -> bool:
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        key = ("heavy", comp_name)
        if key in self._memo:
            return self._memo[key]
        heavy = False
        for nm in comp.order:
            ins = comp.instrs[nm]
            if ins.op in _MATERIALIZE_OPS and ins.op != "transpose":
                heavy = True
                break
            if ins.op.startswith("fusion"):
                callee = _called(ins.attrs, "calls")
                if callee and self._has_heavy_op(callee):
                    heavy = True
                    break
        self._memo[key] = heavy
        return heavy

    def _is_slicing(self, comp_name: str) -> bool:
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        return any(comp.instrs[nm].op in
                   ("dynamic-slice", "dynamic-update-slice", "gather")
                   for nm in comp.order)

    def _fusion_traffic(self, ins: Instr, comp: Computation,
                        callee: str | None) -> float:
        """HBM traffic of one fusion call, from its interior dataflow."""
        out_b = _shape_bytes(ins.type_str)
        fc = self.comps.get(callee) if callee else None
        if fc is None:
            return self._operand_bytes(ins, comp) + out_b
        def resolve(name: str) -> str:
            """Follow convert/bitcast/copy chains to the underlying value
            (CPU XLA roundtrips whole cache stacks through f32 converts
            before in-place updates; the slice semantics still hold)."""
            seen = 0
            while seen < 8:
                i3 = fc.instrs.get(name)
                if i3 is None or i3.op not in ("convert", "bitcast", "copy") \
                        or not i3.operands:
                    return name
                name = i3.operands[0]
                seen += 1
            return name

        sliced_params: set[str] = set()
        slice_traffic = 0.0
        has_dus = False
        for nm in fc.order:
            i2 = fc.instrs[nm]
            if i2.op == "dynamic-slice":
                slice_traffic += _shape_bytes(i2.type_str)          # slice read
                if i2.operands:
                    sliced_params.add(resolve(i2.operands[0]))
            elif i2.op == "dynamic-update-slice":
                has_dus = True
                if len(i2.operands) > 1:
                    upd = fc.instrs.get(resolve(i2.operands[1]))
                    ub = _shape_bytes(upd.type_str) if upd else 0.0
                    slice_traffic += 2.0 * ub                       # r update + w slice
                if i2.operands:
                    sliced_params.add(resolve(i2.operands[0]))
            elif i2.op == "gather":
                slice_traffic += _shape_bytes(i2.type_str)
                if i2.operands:
                    sliced_params.add(resolve(i2.operands[0]))
        # full reads for parameters not consumed via slicing
        param_traffic = 0.0
        for nm in fc.order:
            i2 = fc.instrs[nm]
            if i2.op == "parameter" and nm not in sliced_params:
                param_traffic += _shape_bytes(i2.type_str)
        # output write: free when the root updates an aliased buffer in place
        out_traffic = 0.0 if has_dus else out_b
        return slice_traffic + param_traffic + out_traffic

    def _one_operand_bytes(self, name: str, comp: Computation) -> float:
        src = comp.instrs.get(name)
        if src is None or src.op == "constant":
            return 0.0
        return _shape_bytes(src.type_str)

    def _operand_bytes(self, ins: Instr, comp: Computation) -> float:
        tot = 0.0
        for o in ins.operands:
            src = comp.instrs.get(o)
            if src is not None and src.op not in ("constant",):
                tot += self._value_bytes(src, comp)
        return tot

    def _value_bytes(self, src: Instr, comp: Computation) -> float:
        """Bytes of a value, resolved through dtype converts: CPU XLA
        upcasts every bf16 dot operand to f32 (no native bf16 matmul);
        on TPU the MXU consumes bf16 directly, so we charge the
        pre-convert width."""
        if src.op == "convert" and src.operands:
            inner = comp.instrs.get(src.operands[0])
            if inner is not None:
                return min(_shape_bytes(src.type_str),
                           _shape_bytes(inner.type_str))
        if src.op.startswith("fusion"):
            callee = _called(src.attrs, "calls")
            fc = self.comps.get(callee) if callee else None
            if fc is not None:
                ops = [fc.instrs[nm].op for nm in fc.order]
                real = [o for o in ops if o not in ("parameter", "convert",
                                                    "bitcast", "copy")]
                if not real:  # convert-only fusion: charge the input width
                    psizes = [_shape_bytes(fc.instrs[nm].type_str)
                              for nm in fc.order
                              if fc.instrs[nm].op == "parameter"]
                    if psizes:
                        return min(_shape_bytes(src.type_str), max(psizes))
        return _shape_bytes(src.type_str)

    def _operand_elems(self, ins: Instr, comp: Computation) -> float:
        tot = 0.0
        for o in ins.operands:
            src = comp.instrs.get(o)
            if src is not None:
                tot += _shape_elems(src.type_str)
        return tot


def analyze_hlo_text(text: str) -> Cost:
    return HloCostAnalyzer(text).cost()
