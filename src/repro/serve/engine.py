"""Batched serving engine: prefill once, decode tokens step by step.

The engine owns two model instances sharing parameter values: a ``prefill``
model (megatron/fsdp_sp layouts) and a ``decode`` model (row-parallel layouts
with sequence-sharded caches).  On hardware the weights would be laid out
twice (or re-materialized); on the CPU test path the shardings are inactive
and values are shared.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import Topo
from repro.models.model_zoo import build_model


@dataclass
class ServeStats:
    prefill_tokens: int = 0
    decode_steps: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, topo: Topo, max_len: int):
        self.cfg, self.topo, self.max_len = cfg, topo, max_len
        self.prefill_model = build_model(cfg, topo, kind="prefill")
        self.decode_model = build_model(cfg, topo, kind="decode")
        self._prefill = jax.jit(self.prefill_model.prefill)
        self._decode = jax.jit(self.decode_model.decode_step)
        self.stats = ServeStats()

    def init_params(self, key: jax.Array):
        return self.prefill_model.init_params(key)

    def _pad_caches(self, caches, batch: int, prompt_len: int,
                    memory_len: int | None = None):
        if self.cfg.is_encoder_decoder:
            structs = self.decode_model.cache_shape_structs(
                batch, self.max_len, memory_len=memory_len)
        else:
            structs = self.decode_model.cache_shape_structs(batch, self.max_len)

        def pad(c, st):
            pads = [(0, a - b) for a, b in zip(st.shape, c.shape)]
            return jnp.pad(c.astype(st.dtype), pads)

        return jax.tree.map(pad, caches, structs)

    def generate(self, params, batch: dict, num_tokens: int,
                 greedy: bool = True, key: jax.Array | None = None) -> np.ndarray:
        """batch: prefill inputs {"tokens": (b, s), ...} -> (b, num_tokens)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        if s + num_tokens > self.max_len:
            raise ValueError("prompt + generation exceeds engine max_len")
        if not greedy and key is None:
            raise ValueError("sampling (greedy=False) requires a PRNG key; "
                             "pass key=jax.random.key(...) or use greedy=True")
        logits, caches = self._prefill(params, batch)
        mem_len = batch["frames"].shape[1] if "frames" in batch else None
        caches = self._pad_caches(caches, b, s, memory_len=mem_len)
        self.stats.prefill_tokens += b * s
        out = []
        for i in range(num_tokens):
            logits = jnp.asarray(logits, jnp.float32)[:, :self.cfg.vocab_size]
            if greedy:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits).astype(jnp.int32)
            out.append(np.asarray(nxt))
            t = jnp.asarray(s + i, jnp.int32)
            logits, caches = self._decode(params, caches, nxt, t)
            self.stats.decode_steps += 1
        return np.stack(out, axis=1)
