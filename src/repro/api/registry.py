"""Named plugin registries for the session facade's policy axes.

Every policy knob a ``MinosSession`` exposes resolves through a registry, so
``MinosSession.from_config`` can construct a full session from plain names
and downstream code can add policies without touching the core:

  * ``OBJECTIVES`` — what cap a decision actuates.  Builtins are the paper's
    ``powercentric``/``perfcentric``; a custom objective is any function
    ``FreqSelection -> float`` registered via ``register_objective``.
  * ``ACTUATORS`` — how a cap reaches a device.  Builtins: ``sim`` (the
    recording ``SimActuator``, bound per device) and ``none`` (decide but
    do not actuate).  A custom actuator is a factory
    ``DeviceInstance | None -> FrequencyActuator | None``.
  * ``QUANTILES`` — which spike quantile of the neighbor's scaling data the
    scheduler provisions per chip.  Builtins: ``p90``/``p95``/``p99``; a
    custom quantile is any function ``FreqPoint -> float`` registered via
    ``register_quantile``.

Registered plugins flow through exactly the same controllers as the
builtins (``OnlineCapController``, ``PowerAwareScheduler``), so the
byte-identity guarantees of the direct paths carry over.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

from repro.core.algorithm1 import (PERFCENTRIC, POWERCENTRIC, FreqSelection,
                                   ObjectivePolicy)
from repro.core.classify import FreqPoint
from repro.sched.dvfs import SimActuator


@dataclass(frozen=True)
class QuantilePolicy:
    """A pluggable provisioning quantile: maps a neighbor ``FreqPoint`` to
    the relative per-chip power the scheduler reserves for a job."""
    name: str
    rel_fn: Callable[[FreqPoint], float] = field(compare=False)

    def __call__(self, fp: FreqPoint) -> float:
        return self.rel_fn(fp)


class Registry:
    """A string-keyed plugin table with decorator-style registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: dict[str, object] = {}

    def register(self, name: str, obj=None, *, replace: bool = False):
        """``register(name, obj)`` or ``@register(name)`` on a factory.
        Duplicate names raise unless ``replace=True``."""
        if obj is None:
            return lambda f: self.register(name, f, replace=replace)
        if not isinstance(name, str) or not name:
            raise ValueError(f"{self.kind} name must be a non-empty string, "
                             f"got {name!r}")
        if name in self._items and not replace:
            raise ValueError(f"{self.kind} {name!r} is already registered "
                             f"(pass replace=True to override)")
        self._items[name] = obj
        return obj

    def get(self, name: str):
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(f"unknown {self.kind} {name!r}; registered: "
                           f"{', '.join(self.names())}") from None

    def names(self) -> list[str]:
        return sorted(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def __len__(self) -> int:
        return len(self._items)


OBJECTIVES = Registry("objective")
OBJECTIVES.register("powercentric", POWERCENTRIC)
OBJECTIVES.register("perfcentric", PERFCENTRIC)

ACTUATORS = Registry("actuator")
ACTUATORS.register(
    "sim", lambda device=None: SimActuator.for_device(device)
    if device is not None else SimActuator())
ACTUATORS.register("none", lambda device=None: None)

QUANTILES = Registry("quantile")
for _q in ("p90", "p95", "p99"):
    # builtins stay plain strings: PowerAwareScheduler resolves them to the
    # matching FreqPoint attribute, the exact pre-facade code path
    QUANTILES.register(_q, _q)


def register_objective(name: str, cap_fn: Callable[[FreqSelection], float],
                       *, replace: bool = False) -> ObjectivePolicy:
    """Register a custom capping objective by name; returns its policy."""
    policy = ObjectivePolicy(name, cap_fn)
    OBJECTIVES.register(name, policy, replace=replace)
    return policy


def register_quantile(name: str, rel_fn: Callable[[FreqPoint], float],
                      *, replace: bool = False) -> QuantilePolicy:
    """Register a custom provisioning quantile by name; returns its policy."""
    policy = QuantilePolicy(name, rel_fn)
    QUANTILES.register(name, policy, replace=replace)
    return policy


def register_actuator(name: str, factory, *, replace: bool = False):
    """Register a custom actuator factory (``device -> actuator``) by name."""
    if not callable(factory):
        raise ValueError(f"actuator factory must be callable, got {factory!r}")
    ACTUATORS.register(name, factory, replace=replace)
    return factory
